"""BENCH_sweep.json trend tracker — the dense-sweep artifact diff.

The ``sweep`` suite's three hard divergence gates catch *correctness*
regressions; this tool catches *performance* regressions the gates
cannot see: a change that keeps fork==rerun cell-for-cell but quietly
makes the fork engine re-copy every snapshot would sail through CI
while the speedups collapse. Compare the current artifact's speedup
columns against the previous one and fail when any drops by more than
``--max-regression`` (default 2x — generous enough for shared-runner
noise, tight enough that an O(tail) -> O(full-run) slip cannot hide).

    python -m benchmarks.sweep_trend PREV.json NEW.json

Exit codes: 0 = ok (including "no previous artifact yet" — the first
run of a fresh cache seeds the baseline), 1 = regression. CI wires
this behind an actions/cache-restored copy of the last successful
run's BENCH_sweep.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

# the speedup columns BENCH_sweep.json has carried since schema v2
TREND_METRICS = ("speedup", "measure_speedup", "total_speedup")


def compare_speedups(prev: Dict, new: Dict,
                     max_regression: float = 2.0) -> List[str]:
    """Regression messages ([] = trend ok). Only ratios are compared —
    absolute seconds shift with host load, but fork-over-rerun and
    measure-over-fork are self-normalizing on the same host."""
    failures = []
    for metric in TREND_METRICS:
        if metric not in prev:
            continue  # older-schema baseline: nothing to compare yet
        if metric not in new:
            # a metric the baseline carried has vanished from the new
            # artifact — a schema drift that would otherwise silently
            # disable this gate forever
            failures.append(
                f"{metric}: present in previous artifact but missing "
                f"from the new one (schema drift disables the gate)")
            continue
        old_v, new_v = float(prev[metric]), float(new[metric])
        if old_v <= 0:
            continue
        if new_v < old_v / max_regression:
            failures.append(
                f"{metric}: {new_v:.2f}x vs previous {old_v:.2f}x "
                f"(> {max_regression:g}x regression)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev", help="previous BENCH_sweep.json (baseline)")
    ap.add_argument("new", help="current BENCH_sweep.json")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when a speedup drops by more than this "
                         "factor (default: 2.0)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.new):
        print(f"sweep_trend: current artifact {args.new} missing", flush=True)
        return 1
    with open(args.new) as fh:
        new = json.load(fh)
    if not os.path.exists(args.prev):
        print(f"sweep_trend: no previous artifact at {args.prev}; "
              f"seeding baseline from this run", flush=True)
        return 0
    with open(args.prev) as fh:
        prev = json.load(fh)
    if prev.get("smoke") != new.get("smoke"):
        print("sweep_trend: smoke/full mismatch between artifacts; "
              "skipping (not comparable)", flush=True)
        return 0

    failures = compare_speedups(prev, new, args.max_regression)
    for metric in TREND_METRICS:
        if metric in new:
            prev_s = f"{float(prev[metric]):.2f}x" if metric in prev else "-"
            print(f"sweep_trend: {metric} {float(new[metric]):.2f}x "
                  f"(previous {prev_s})", flush=True)
    if failures:
        print("sweep_trend: FAIL\n  " + "\n  ".join(failures), flush=True)
        return 1
    print("sweep_trend: ok", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
