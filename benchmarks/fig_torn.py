"""Beyond-paper figure: torn-write detection coverage vs line survival.

The paper's central claim is that algorithm knowledge can detect or
tolerate inconsistent NVM state after a crash — but a crash that loses
*every* dirty cache line never produces the interesting inconsistent
states. This suite sweeps the crash-state space those claims are about:
``CrashPlan.at_every_step(torn=TornSpec(fraction, seed, mode, samples))``
enumerates every crash step × survival fraction × seeded survival
sample through ``sweep(mode="measure")``, so each cell is one sampled
torn crash image (EasyCrash's sampling, WITCHER's enumeration) costing
O(restore + recover).

Reported per (workload, strategy, fraction, survival mode): the
``correctness_class`` census — where CG's invariant scan, ABFT's
checksums, and XSBench's counter/index comparison *detect* torn state
(``torn_detected``), where a mechanism tolerates it wholesale
(``consistent_rollback`` / ``scratch_restart``), and where torn state
slips into the recovered run (``torn_corrupt`` — e.g. surviving XSBench
counter increments past the persisted index that replay double-counts)
— plus the measure-mode byte-certification census (``state_certified``).

Gates (every run, smoke or full — ``check_torn_gates``):

  * the ``--workers`` sharded measure sweep merges to the identical
    cell list as the serial one;
  * every field a measure cell emits equals the full-execution fork
    cell (``measure_divergences``);
  * class/correctness coherence on the full-execution sweep: a torn
    cell classified anything but ``torn_corrupt`` must finalize
    correct, and a ``torn_corrupt`` cell must finalize incorrect —
    the classes really do partition safe from corrupted recoveries;
  * certification coherence: a byte-certified cell is never
    ``torn_corrupt``;
  * detection-coverage floor: undo-log and checkpoint mechanisms
    produce zero ``torn_corrupt`` cells at every fraction (rollback /
    restore discards torn state by construction).
"""

from __future__ import annotations

import os
from collections import Counter
from typing import Dict, List, Tuple

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, TornSpec, sweep
from repro.scenarios.costmodel import survivor_writeback_seconds

from .common import ART, Row, write_json

ARTIFACT = "fig_torn.json"
BENCH_JSON = os.path.join(ART, "BENCH_torn.json")

SEED = 23
FRACTIONS = (0.0, 0.25, 0.5, 0.75, 1.0)
SMOKE_FRACTIONS = (0.0, 0.5, 1.0)
SAMPLES = 3
SMOKE_SAMPLES = 2

WORKLOADS = (
    ("cg", {"n": 2048, "iters": 12, "seed": 5}),
    ("mm", {"n": 64, "k": 16, "seed": 2}),
    ("xsbench", {"lookups": 160, "grid_points": 1200, "n_nuclides": 8,
                 "n_materials": 6, "max_nuclides_per_material": 4,
                 "flush_every_frac": 0.05, "seed": 7}),
)
SMOKE_WORKLOADS = (
    ("cg", {"n": 512, "iters": 8, "seed": 5}),
    ("mm", {"n": 32, "k": 8, "seed": 2}),
    ("xsbench", {"lookups": 80, "grid_points": 600, "n_nuclides": 8,
                 "n_materials": 6, "max_nuclides_per_material": 4,
                 "flush_every_frac": 0.1, "seed": 7}),
)
STRATEGIES = ("adcc", "undo_log", "checkpoint_nvm@2")

# mechanisms that discard torn state by construction: their rollback /
# restore path must never let a torn crash image corrupt the resumed
# run, at any survival fraction (the coverage-floor gate)
WHOLESALE_STRATEGIES = ("undo_log", "checkpoint_nvm@2")

# classes in which no torn data reaches the resumed computation
SAFE_CLASSES = ("complete", "consistent_rollback", "scratch_restart",
                "torn_detected")


def _plans(fractions, samples) -> Tuple[CrashPlan, ...]:
    dense = tuple(
        CrashPlan.at_every_step(
            torn=TornSpec(fraction=f, seed=SEED, mode="random",
                          samples=samples))
        for f in fractions)
    # one eviction-order-consistent axis: queue-front lines persist
    # first, the ordering a real write-back cache would produce
    evict = (CrashPlan.at_every_step(
        torn=TornSpec(fraction=0.5, seed=SEED, mode="eviction")),)
    return (CrashPlan.no_crash(),) + dense + evict


def _sweep_kw(smoke: bool) -> Dict:
    wls, fr, s = ((SMOKE_WORKLOADS, SMOKE_FRACTIONS, SMOKE_SAMPLES)
                  if smoke else (WORKLOADS, FRACTIONS, SAMPLES))
    return dict(workloads=wls, strategies=STRATEGIES,
                plans=_plans(fr, s), cfg=NVMConfig(cache_bytes=1024 * 1024))


def _spec_of(cell) -> Tuple[str, float]:
    """(survival mode, fraction) of a torn cell, from its spec string."""
    mode, frac, _seed = cell.torn_survival.split(":", 2)
    return mode, float(frac[1:])


def check_torn_gates(kw: Dict, cells, workers: int) -> None:
    """The gate stack documented in the module docstring. ``cells`` is
    the serial-or-sharded measure-mode sweep of ``kw``. The sharding
    and measure==full cross-checks are the shared dense-gate core
    (``run_dense_cross_checks``); on top come the torn-specific
    class/correctness coherence gates."""
    from .scenarios_sweep import run_dense_cross_checks

    full = run_dense_cross_checks(kw, cells, workers)

    # explicit raises (not asserts): these are CI gates and must
    # survive python -O, like the shared dense-gate core
    for c in full:
        key = (c.workload, c.strategy, c.plan, c.crash_step,
               c.torn_survival)
        if c.correctness_class == "torn_corrupt":
            if c.correct:
                raise AssertionError(
                    f"cell classified torn_corrupt finalized CORRECT: {key}")
        elif not c.correct:
            raise AssertionError(
                f"cell classified {c.correctness_class} finalized "
                f"INCORRECT: {key}")
        if (c.strategy in WHOLESALE_STRATEGIES and c.crash_step is not None
                and c.correctness_class not in SAFE_CLASSES):
            raise AssertionError(
                f"wholesale mechanism let torn state through: {key} "
                f"class={c.correctness_class}")

    for m in cells:
        if m.state_certified and m.correctness_class == "torn_corrupt":
            raise AssertionError(
                "byte-certified cell classified torn_corrupt: "
                f"{(m.workload, m.strategy, m.crash_step, m.torn_survival)}")


def run(smoke: bool = None, workers: int = None,
        mode: str = "measure") -> List[Row]:
    from .scenarios_sweep import resolve_sweep_env

    smoke, workers = resolve_sweep_env(smoke, workers)
    kw = _sweep_kw(smoke)
    cells = sweep(mode=mode, workers=workers, **kw)
    # with mode="batched" the gate stack's alternate-workers comparison
    # pins the batched cells against a fresh measure-mode sweep
    # cell-for-cell, on top of the usual measure==full contract
    check_torn_gates(kw, cells, workers)

    # detection-coverage census per (workload, strategy, mode, fraction)
    coverage: Dict[Tuple, Counter] = {}
    certified: Dict[Tuple, Counter] = {}
    survivor_bytes: Dict[Tuple, int] = {}
    for c in cells:
        if c.torn_survival is None:
            continue
        key = (c.workload, c.strategy) + _spec_of(c)
        coverage.setdefault(key, Counter())[c.correctness_class] += 1
        certified.setdefault(key, Counter())[
            {True: "yes", False: "no", None: "n/a"}[c.state_certified]] += 1
        survivor_bytes[key] = (survivor_bytes.get(key, 0)
                               + c.info.get("torn_bytes_persisted", 0))

    rows = []
    for key in sorted(coverage):
        wl, strat, mode, frac = key
        census = coverage[key]
        total = sum(census.values())
        safe = sum(census[k] for k in SAFE_CLASSES)
        mean_bytes = survivor_bytes[key] / total
        wb_s = survivor_writeback_seconds(mean_bytes, kw["cfg"])
        prefix = f"fig_torn/{wl}/{strat}/{mode}/f={frac:g}"
        rows.append(Row(f"{prefix}/cells", total,
                        " ".join(f"{k}={v}" for k, v in sorted(census.items()))))
        rows.append(Row(f"{prefix}/safe_fraction", safe / total,
                        f"torn_corrupt={census.get('torn_corrupt', 0)}"))
        rows.append(Row(f"{prefix}/certified_cells",
                        certified[key].get("yes", 0),
                        " ".join(f"{k}={v}"
                                 for k, v in sorted(certified[key].items()))))
        rows.append(Row(f"{prefix}/mean_survivor_bytes", mean_bytes,
                        f"power-fail writeback ~{wb_s:.2e}s at NVM bw"))
    write_json(BENCH_JSON, {
        "schema": "repro.scenarios.torn/v1",
        "smoke": bool(smoke),
        "matrix": {
            "workloads": [[w, p] for w, p in kw["workloads"]],
            "strategies": list(STRATEGIES),
            "plans": [p.describe() for p in kw["plans"]],
        },
        "cells": [c.to_json_dict() for c in cells],
        "coverage": [
            {"workload": k[0], "strategy": k[1], "mode": k[2],
             "fraction": k[3], "classes": dict(coverage[k]),
             "certified": dict(certified[k])}
            for k in sorted(coverage)],
    })
    rows.append(Row("fig_torn/summary/cells", len(cells),
                    f"artifact={BENCH_JSON}"))
    return rows


def main(argv=None) -> None:
    from .common import dense_figure_cli
    dense_figure_cli(run, ARTIFACT, argv)


if __name__ == "__main__":
    main()
