"""Invariant registry — the "algorithm knowledge" the paper consults.

An :class:`Invariant` is a named predicate over the post-crash NVM view
of a set of data objects. The recovery engine (recovery.py) scans
candidate restart points and accepts the newest one whose invariants all
hold. Built-in invariant families:

  OrthogonalityInvariant   p^T q == 0                   (CG, Eq. 1)
  ResidualInvariant        r == b - A z                 (CG, Eq. 2)
  ChecksumInvariant        ABFT row/col sums hold       (MM, Eq. 6)
  ScalarChecksumInvariant  sum(x) == recorded checksum  (training state)

Tolerances are relative to data magnitude: the point is to distinguish
"torn write / stale garbage" from "valid iterate", and torn data misses
by many orders of magnitude.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from . import abft

__all__ = [
    "Invariant",
    "CheckResult",
    "OrthogonalityInvariant",
    "ResidualInvariant",
    "ChecksumInvariant",
    "ScalarChecksumInvariant",
    "InvariantSet",
]


@dataclasses.dataclass
class CheckResult:
    name: str
    ok: bool
    error: float  # scalar badness measure (0 when ok)
    detail: str = ""


class Invariant:
    name: str = "invariant"

    def check(self, data: Dict[str, np.ndarray]) -> CheckResult:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass
class OrthogonalityInvariant(Invariant):
    """|p^T q| / (|p||q|) <= tol  — CG Eq. 1."""

    p_key: str
    q_key: str
    tol: float = 1e-8
    name: str = "orthogonality"

    def check(self, data: Dict[str, np.ndarray]) -> CheckResult:
        p, q = data[self.p_key], data[self.q_key]
        denom = float(np.linalg.norm(p) * np.linalg.norm(q)) + 1e-300
        err = abs(float(p @ q)) / denom
        return CheckResult(self.name, err <= self.tol, err,
                           f"|p.q|/|p||q| = {err:.3e}")


@dataclasses.dataclass
class ResidualInvariant(Invariant):
    """||r - (b - A z)|| / ||b|| <= tol — CG Eq. 2. ``matvec`` computes
    A @ z so sparse A never needs densifying."""

    r_key: str
    z_key: str
    b: np.ndarray
    matvec: Callable[[np.ndarray], np.ndarray]
    tol: float = 1e-6
    name: str = "residual"

    def check(self, data: Dict[str, np.ndarray]) -> CheckResult:
        r, z = data[self.r_key], data[self.z_key]
        err = float(np.linalg.norm(r - (self.b - self.matvec(z))))
        rel = err / (float(np.linalg.norm(self.b)) + 1e-300)
        return CheckResult(self.name, rel <= self.tol, rel,
                           f"||r-(b-Az)||/||b|| = {rel:.3e}")


@dataclasses.dataclass
class ChecksumInvariant(Invariant):
    """ABFT row+column checksum relationships on a full-checksum matrix."""

    key: str
    rtol: float = 1e-8
    atol: float = 1e-6
    name: str = "abft_checksum"

    def check(self, data: Dict[str, np.ndarray]) -> CheckResult:
        Cf = data[self.key]
        row, col = abft.residuals(Cf)
        err = float(max(np.max(np.abs(row)), np.max(np.abs(col))))
        ok = abft.verify(Cf, self.rtol, self.atol)
        return CheckResult(self.name, ok, err, f"max checksum residual {err:.3e}")


@dataclasses.dataclass
class ScalarChecksumInvariant(Invariant):
    """sum(x) matches an independently persisted scalar checksum — the
    training-state invariant (checksums maintained incrementally because
    optimizer updates are linear in the applied step)."""

    key: str
    expected: float
    rtol: float = 1e-6
    atol: float = 1e-8
    name: str = "scalar_checksum"

    def check(self, data: Dict[str, np.ndarray]) -> CheckResult:
        got = float(np.sum(np.asarray(data[self.key], dtype=np.float64)))
        tol = self.atol + self.rtol * max(abs(self.expected), 1.0)
        err = abs(got - self.expected)
        return CheckResult(self.name, err <= tol, err,
                           f"sum={got:.9g} expected={self.expected:.9g}")


class InvariantSet:
    """All invariants must hold for a restart point to be accepted."""

    def __init__(self, invariants: Optional[List[Invariant]] = None):
        self.invariants: List[Invariant] = list(invariants or [])

    def add(self, inv: Invariant) -> "InvariantSet":
        self.invariants.append(inv)
        return self

    def check_all(self, data: Dict[str, np.ndarray]) -> List[CheckResult]:
        return [inv.check(data) for inv in self.invariants]

    def holds(self, data: Dict[str, np.ndarray]) -> bool:
        return all(res.ok for res in self.check_all(data))
