"""Pallas TPU kernel: tiled checksum-consistency detection.

The recovery path's hot loop (paper §III.C "detecting where to restart")
is a full pass over a checksummed matrix computing row and column sums to
compare against the embedded checksums. This kernel computes per-tile
row/column partial sums in one HBM pass; ops.py reduces the partials and
forms the residuals against the checksum row/column.

Grid (m/bm, n/bn); each step reduces a (bm, bn) VMEM tile into a
(bm, 1) row partial and a (1, bn) column partial — pure VPU work, memory
bound by design (arithmetic intensity ~2 flops/byte), so the roofline
target is HBM bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tile_sums_pallas"]


def _tile_sums_kernel(x_ref, rowp_ref, colp_ref):
    # accumulate in the output dtype (acc_dtype below): f32 for the TPU
    # VPU fast path, f64 when the batched sweep needs bit-stable verdicts
    x = x_ref[...].astype(rowp_ref.dtype)
    rowp_ref[...] = jnp.sum(x, axis=1, keepdims=True)
    colp_ref[...] = jnp.sum(x, axis=0, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "acc_dtype", "interpret"))
def tile_sums_pallas(x: jax.Array, *, bm: int = 128, bn: int = 128,
                     acc_dtype=jnp.float32, interpret: bool = False):
    """Row/col partial sums of x (m, n) with m % bm == n % bn == 0.
    Returns (row_partials (m, n/bn), col_partials (m/bm, n)), both
    ``acc_dtype`` (default f32 — the historical behavior)."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, f"unpadded ({m},{n}) vs ({bm},{bn})"
    mi, nj = m // bm, n // bn
    return pl.pallas_call(
        _tile_sums_kernel,
        grid=(mi, nj),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nj), acc_dtype),
            jax.ShapeDtypeStruct((mi, n), acc_dtype),
        ],
        interpret=interpret,
    )(x)
