"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths sharing one parameterization:

* ``moe_apply_dense`` — reference path: every expert computed on every
  token with mask-combine. O(T·E·F) compute, zero collectives. Used as
  the smoke-test/correctness oracle and for tiny reduced configs.

* ``moe_apply_ep`` — production path under ``jax.shard_map``: tokens
  sharded over every mesh axis, experts sharded over the EP axis
  ("model"). Per shard: top-k routing -> capacity-bucketed all_to_all to
  expert owners -> local ``jax.lax.ragged_dot`` grouped GEMM (sorted by
  local expert) -> all_to_all back -> weighted combine at the source.
  This is the TPU-native (GSPMD/ICI) analogue of the dispatch pipelines
  GPU MoE stacks build with NCCL all-to-alls; the collective bytes it
  emits are exactly what the roofline's collective term measures.

Capacity: each destination device receives at most
``ceil(T_loc * K * capacity_factor / ep)`` tokens; overflow assignments
are dropped (weights renormalized upstream make this a standard
capacity-drop MoE). Tests run with generous capacity and assert the EP
path matches the dense oracle exactly.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import Axes, Params, dense_init

__all__ = ["moe_init", "moe_apply_dense", "moe_apply_ep", "router_topk"]


def moe_init(cfg: ModelConfig, key) -> Tuple[Params, Axes]:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["router"], a["router"] = dense_init(ks[0], D, E, "embed", "experts_r",
                                          jnp.float32)

    def expert_stack(k, din, dout):
        sub = jax.random.split(k, E)
        w = jax.vmap(lambda kk: jax.random.normal(kk, (din, dout), jnp.float32)
                     * (2.0 / (din + dout)) ** 0.5)(sub)
        return w.astype(dtype)

    p["w_gate"] = expert_stack(ks[1], D, F)
    a["w_gate"] = ("experts", "embed", "mlp_e")
    p["w_up"] = expert_stack(ks[2], D, F)
    a["w_up"] = ("experts", "embed", "mlp_e")
    p["w_down"] = expert_stack(ks[3], F, D)
    a["w_down"] = ("experts", "mlp_e", "embed")
    return p, a


def router_topk(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """(weights (T,K) f32 renormalized, ids (T,K) int32) for tokens (T,D)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------

def moe_apply_dense(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """x: (T, D) -> (T, D). Computes every expert on every token."""
    dt = x.dtype
    weights, ids = router_topk(cfg, p["router"], x)      # (T,K)
    E = cfg.n_experts
    # (T, E) combine weights
    combine = jnp.zeros((x.shape[0], E), jnp.float32)
    combine = combine.at[jnp.arange(x.shape[0])[:, None], ids].add(weights)
    gate = jax.nn.silu(jnp.einsum("td,edf->tef", x, p["w_gate"].astype(dt)))
    up = jnp.einsum("td,edf->tef", x, p["w_up"].astype(dt))
    y = jnp.einsum("tef,efd->ted", gate * up, p["w_down"].astype(dt))
    return jnp.einsum("ted,te->td", y.astype(jnp.float32),
                      combine).astype(dt)


# ---------------------------------------------------------------------------
# expert-parallel production path
# ---------------------------------------------------------------------------

def _local_expert_ffn_ragged(x_sorted: jax.Array, group_sizes: jax.Array,
                             wg: jax.Array, wu: jax.Array, wd: jax.Array):
    """Grouped SwiGLU via jax.lax.ragged_dot. NOTE: the reference (CPU)
    lowering of ragged_dot is dense-per-group — E_loc x the useful flops
    (measured 24x on kimi-k2; §Perf iteration 7). Kept as an option for
    backends with native ragged support."""
    dt = x_sorted.dtype
    gate = jax.nn.silu(jax.lax.ragged_dot(x_sorted, wg.astype(dt), group_sizes))
    up = jax.lax.ragged_dot(x_sorted, wu.astype(dt), group_sizes)
    return jax.lax.ragged_dot(gate * up, wd.astype(dt), group_sizes)


def _local_expert_ffn(x_sorted: jax.Array, group_sizes: jax.Array,
                      wg: jax.Array, wu: jax.Array, wd: jax.Array,
                      block_factor: float = 2.0):
    """Equal-capacity grouped SwiGLU: scan over local experts, each
    processing a static ``cap``-row window of the expert-sorted rows
    (dynamic_slice at its group offset). Static shapes, MXU-aligned, and
    total flops = E_loc x cap x ffn ≈ block_factor x useful — vs the
    E_loc x dense cost of the reference ragged_dot lowering (§Perf
    iteration 7: 12x compute-term win on kimi-k2 train).

    Rows beyond ``cap`` within one expert's group are dropped (standard
    capacity semantics; combine weights upstream make this a no-op for
    the kept rows). Overlapping windows self-heal: expert e's masked
    zero tail is overwritten by expert e+1's correct rows.
    """
    R, D = x_sorted.shape
    E_loc = wg.shape[0]
    dt = x_sorted.dtype
    cap = int(-(-R * block_factor // E_loc))
    cap = max(8, ((cap + 7) // 8) * 8)           # sublane-aligned
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    # pad so dynamic_slice never clamps (clamping would misalign writes)
    x_pad = jnp.pad(x_sorted, ((0, cap), (0, 0)))
    y_pad = jnp.zeros((R + cap, D), dt)

    def body(y, inp):
        off, gs, wg_e, wu_e, wd_e = inp
        blk = jax.lax.dynamic_slice(x_pad, (off, 0), (cap, D))
        keep = (jnp.arange(cap) < gs)[:, None]
        h = jax.nn.silu(blk @ wg_e.astype(dt)) * (blk @ wu_e.astype(dt))
        out = jnp.where(keep, h @ wd_e.astype(dt), 0.0).astype(dt)
        return jax.lax.dynamic_update_slice(y, out, (off, 0)), None

    y_pad, _ = jax.lax.scan(
        body, y_pad,
        (offsets, group_sizes.astype(jnp.int32), wg, wu, wd))
    return y_pad[:R]


def _ep_shard_fn(cfg: ModelConfig, ep_axis: str, ep: int, capacity: int):
    """Builds the per-shard function executed under shard_map."""
    K = cfg.experts_per_token
    E = cfg.n_experts
    E_loc = E // ep

    def fn(x, router_w, wg, wu, wd):
        # x: (T, D) local tokens; wg/wu/wd: (E_loc, ., .) local experts
        T, D = x.shape
        weights, ids = router_topk(cfg, router_w, x)     # (T, K)
        fids = ids.reshape(-1)                           # (T*K,)
        fw = weights.reshape(-1)
        dest = fids // E_loc                             # owning device
        lid = fids % E_loc                               # local expert id

        # rank of each assignment within its destination bucket
        onehot = (dest[:, None] == jnp.arange(ep)[None, :]).astype(jnp.int32)
        rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                   dest[:, None], axis=1)[:, 0]
        keep = rank < capacity                           # capacity drop
        slot = dest * capacity + jnp.where(keep, rank, 0)

        # scatter token payloads + local-expert ids into send buffers
        tok = jnp.repeat(x, K, axis=0)                   # (T*K, D)
        send = jnp.zeros((ep * capacity, D), x.dtype)
        send = send.at[slot].set(jnp.where(keep[:, None], tok, 0.0),
                                 mode="drop")
        # empty/dropped slots carry lid = E_loc: a "trash group" that
        # sorts after every real expert and is never computed
        send_lid = jnp.full((ep * capacity,), E_loc, jnp.int32)
        send_lid = send_lid.at[slot].set(jnp.where(keep, lid, E_loc),
                                         mode="drop")

        # exchange with expert owners
        recv = jax.lax.all_to_all(send.reshape(ep, capacity, D), ep_axis,
                                  split_axis=0, concat_axis=0)
        recv_lid = jax.lax.all_to_all(send_lid.reshape(ep, capacity), ep_axis,
                                      split_axis=0, concat_axis=0)
        rx = recv.reshape(ep * capacity, D)
        rlid = recv_lid.reshape(ep * capacity)

        # grouped GEMM over local experts (sort by local expert id)
        order = jnp.argsort(rlid)
        inv = jnp.argsort(order)
        gs = jnp.bincount(rlid, length=E_loc).astype(jnp.int32)
        y_sorted = _local_expert_ffn(rx[order], gs, wg, wu, wd)
        y = y_sorted[inv]

        # return trip + combine at source
        back = jax.lax.all_to_all(y.reshape(ep, capacity, D), ep_axis,
                                  split_axis=0, concat_axis=0)
        flat = back.reshape(ep * capacity, D)
        y_assign = flat[slot] * (keep & True)[:, None].astype(flat.dtype)
        y_tok = (y_assign.astype(jnp.float32).reshape(T, K, D)
                 * fw.reshape(T, K, 1)).sum(axis=1)
        return y_tok.astype(x.dtype)

    return fn


def moe_apply_ep(cfg: ModelConfig, p: Params, x_tokens: jax.Array,
                 mesh: jax.sharding.Mesh, *,
                 token_axes: Tuple[str, ...], ep_axis: str = "model",
                 capacity: Optional[int] = None) -> jax.Array:
    """x_tokens: (N, D) global token view; N divisible by mesh.size.
    Experts sharded over ``ep_axis``; tokens over ``token_axes``."""
    ep = mesh.shape[ep_axis]
    assert cfg.n_experts % ep == 0, (cfg.n_experts, ep)
    n_total = 1
    for a in token_axes:
        n_total *= mesh.shape[a]
    n_tokens = x_tokens.shape[0]
    pad = (-n_tokens) % n_total  # decode batches can be < mesh size
    if pad:
        x_tokens = jnp.pad(x_tokens, ((0, pad), (0, 0)))
    T_loc = x_tokens.shape[0] // n_total
    if capacity is None:
        capacity = max(1, int(-(-T_loc * cfg.experts_per_token
                                * cfg.capacity_factor // ep)))

    fn = _ep_shard_fn(cfg, ep_axis, ep, capacity)
    tok_spec = P(token_axes, None)
    out = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(tok_spec, P(), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None)),
        out_specs=tok_spec,
        check_vma=False,
    )(x_tokens, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out[:n_tokens] if pad else out
