"""Device-math layer for the batched sweep engine (``sweep(mode="batched")``).

The batched engine (repro.scenarios.batched_engine) evaluates every
crash cell of a (workload, strategy) pair from host-side snapshots; the
only per-cell work that is numerically heavy is integrity checking —
CG's invariant backward-scan (orthogonality + residual per candidate
iteration) and ABFT's per-chunk checksum verification. This module
lifts exactly that math onto jax: the engine stacks every (cell,
candidate) / (cell, chunk) crash-image row of a whole sweep matrix and
gets the error magnitudes back from a handful of jit launches, routed
through the Pallas kernels (`repro.kernels`) on TPU and plain XLA
elsewhere.

Device results are used as a *screen*, not a verdict: accumulation
order on device differs from the host reference by a few ulps, so the
engine accepts a device verdict only outside a safety band around the
tolerance (certainly-ok / certainly-fail) and recomputes the borderline
sliver with the exact host code (`repro.core.invariants`,
`repro.core.abft`). That keeps batched cells bit-identical to
measure-mode cells while the overwhelming majority of checks never
touch the host path.

Everything is gated on jax being importable (``have_jax``): without it
the batched engine falls back to per-cell measure evaluation and this
module is never exercised.

Shapes are padded to a few fixed sizes (powers of two up to the
``CHUNK_ELEMS`` budget) so jit compiles a handful of kernels per
problem size instead of one per batch.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

try:  # soft: the engine falls back to host evaluation without jax
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    _JAX_IMPORT_ERROR: Optional[BaseException] = None
except Exception as exc:  # pragma: no cover - env without jax
    jax = None
    jnp = None
    enable_x64 = None
    _JAX_IMPORT_ERROR = exc

__all__ = ["have_jax", "jax_runtime_live", "cg_route",
           "cg_invariant_errors", "mm_chunk_stats",
           "CHUNK_ELEMS", "GEMM_MAX_N", "SPARSE_BLOCK_ROWS"]

# per-launch element budget: bounds device/host transfer buffers and
# keeps padded launch shapes to a handful of compiled variants
CHUNK_ELEMS = 1 << 25

# largest CG system routed through the dense symmetrized-operator GEMM
# (the TPU/Pallas route — densifying the CSR operator would dominate
# memory beyond this); bigger systems take the engine's per-cell
# fallback there. The sparse route has no such cliff and is ungated.
GEMM_MAX_N = 4096


def have_jax() -> bool:
    """Whether the jax device path is available in this process."""
    return jax is not None


def jax_runtime_live() -> bool:
    """Whether this process has already instantiated an XLA backend
    (device buffers, compilation threads, locks). Forking a process in
    that state deadlocks the children's device math — the sweep driver
    switches its worker pool to spawn-start when this is true."""
    if jax is None:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:  # pragma: no cover - private-API drift
        return True  # conservative: assume live, pay the spawn cost


def _require_jax() -> None:
    if jax is None:  # pragma: no cover - env without jax
        raise RuntimeError(
            f"jax unavailable for batched device math: {_JAX_IMPORT_ERROR}")


def _chunk_rows(total: int, elems_per_row: int) -> int:
    """Fixed launch row-count: the CHUNK_ELEMS budget, or the next power
    of two when the whole batch is smaller (so small batches reuse a
    log-many set of compiled shapes instead of one per batch size)."""
    cap = max(1, CHUNK_ELEMS // max(1, elems_per_row))
    if total >= cap:
        return cap
    c = 1
    while c < total:
        c <<= 1
    return c


# ---------------------------------------------------------------------------
# CG invariant errors (Eq. 1 orthogonality, Eq. 2 residual)
# ---------------------------------------------------------------------------

if jax is not None:

    def _cg_errors_from_Sz(P, Q, R, Z, b, Sz):
        pq = jnp.sum(P * Q, axis=1)
        denom = jnp.linalg.norm(P, axis=1) * jnp.linalg.norm(Q, axis=1) + 1e-300
        orth = jnp.abs(pq) / denom
        resid = jnp.linalg.norm(R - (b[None, :] - Sz), axis=1)
        rel = resid / (jnp.linalg.norm(b) + 1e-300)
        return orth, rel

    @functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
    def _cg_errors_dense_jit(P, Q, R, Z, b, S, *, use_pallas, interpret):
        from ...kernels.abft_matmul.ops import gemm_batch

        # S is the dense symmetrized operator 0.5*(A + A^T); stacking all
        # candidate z rows makes the residual matvecs one GEMM launch
        # through the Pallas fused-epilogue matmul (MXU route)
        Sz = gemm_batch(Z, S, acc_dtype=jnp.float64,
                        use_pallas=use_pallas, interpret=interpret)
        return _cg_errors_from_Sz(P, Q, R, Z, b, Sz)

    @jax.jit
    def _cg_errors_sparse_jit(P, Q, R, Z, b, vals, cols):
        # batched sparse matvec over the padded equal-width symmetrized
        # operator (vals/cols are (n, K) row slabs, zero-padded): pure
        # gather + multiply + reduce — O(nnz) work per candidate row
        # where the dense GEMM route does O(n^2), and no device scatter
        # (scatter serializes badly on CPU XLA). The MXU makes the dense
        # route the right call on TPU; sparse wins everywhere else by
        # the fill factor.
        Sz = jnp.sum(Z[:, cols] * vals[None, :, :], axis=-1)
        return _cg_errors_from_Sz(P, Q, R, Z, b, Sz)

    @functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
    def _mm_stats_jit(V, *, use_pallas, interpret):
        from ...kernels.checksum_verify.ops import tile_sums_batch

        data = V[:, :-1, :-1]
        row_sums, col_sums = tile_sums_batch(
            data, acc_dtype=jnp.float64,
            use_pallas=use_pallas, interpret=interpret)
        rowmax = jnp.max(jnp.abs(V[:, :-1, -1] - row_sums), axis=1)
        colmax = jnp.max(jnp.abs(V[:, -1, :-1] - col_sums), axis=1)
        absmax = jnp.max(jnp.abs(V), axis=(1, 2))
        nonzero = jnp.any(V != 0, axis=(1, 2))
        return nonzero, absmax, rowmax, colmax


def _pad_rows(block: np.ndarray, rows: int) -> np.ndarray:
    if block.shape[0] >= rows:
        return block
    # np.zeros + slice assign: np.pad's generic path is several times
    # slower and this sits on the per-launch hot path
    out = np.zeros((rows,) + block.shape[1:], dtype=block.dtype)
    out[:block.shape[0]] = block
    return out


# fixed sparse-route launch width: every chunk is padded to this many
# rows so jit compiles exactly one shape per (n, nnz), however the
# caller's batch/wave sizes vary
SPARSE_BLOCK_ROWS = 256


def cg_route(use_pallas: Optional[bool] = None) -> str:
    """Which residual-matvec route ``cg_invariant_errors`` will take:
    ``"dense"`` (Pallas fused-epilogue GEMM over the densified
    symmetrized operator — the MXU-native TPU route, subject to
    :data:`GEMM_MAX_N`) or ``"sparse"`` (batched CSR gather/scatter —
    O(nnz) per row, the right call on CPU/GPU XLA hosts)."""
    if use_pallas is None:
        from ...kernels.abft_matmul.ops import on_tpu
        use_pallas = on_tpu()
    return "dense" if use_pallas else "sparse"


def cg_invariant_errors(P: np.ndarray, Q: np.ndarray, R: np.ndarray,
                        Z: np.ndarray, b: np.ndarray, operator, *,
                        use_pallas: Optional[bool] = None,
                        interpret: bool = False
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched CG invariant error magnitudes over candidate rows.

    P/Q/R/Z are (T, n) stacks of post-crash overlay rows — one row per
    (cell, candidate iteration) pair. ``operator`` is the symmetrized
    system matrix S = 0.5*(A + A^T) in the representation matching
    :func:`cg_route`: ``("dense", S)`` densified, or
    ``("sparse", vals, cols)`` — (n, K) equal-width row slabs of S,
    rows zero-padded to the widest row (see
    :func:`~repro.scenarios.batched_engine._CGAdccEvaluator._operator`).
    Returns (orth_err (T,), resid_rel (T,)) as float64 numpy arrays:

      orth_err[t]  = |p.q| / (|p||q| + 1e-300)       (vs tol 1e-7)
      resid_rel[t] = ||r - (b - S z)|| / (||b|| + 1e-300)  (vs tol 1e-6)

    the exact quantities OrthogonalityInvariant / ResidualInvariant
    compare — up to device accumulation order, which is why callers
    apply a certainty band before trusting a verdict.
    """
    _require_jax()
    kind, *op = operator
    T, n = P.shape
    rows = (_chunk_rows(T, 4 * n) if kind == "dense"
            else min(SPARSE_BLOCK_ROWS, _chunk_rows(T, 4 * n)))
    orth = np.empty(T, dtype=np.float64)
    rel = np.empty(T, dtype=np.float64)
    with enable_x64():
        bj = jnp.asarray(np.asarray(b, dtype=np.float64))
        if kind == "dense":
            if use_pallas is None:
                from ...kernels.abft_matmul.ops import on_tpu
                use_pallas = on_tpu()
            opj = (jnp.asarray(np.asarray(op[0], dtype=np.float64)),)
        elif kind == "sparse":
            vals, cols = op
            opj = (jnp.asarray(np.asarray(vals, dtype=np.float64)),
                   jnp.asarray(np.asarray(cols, dtype=np.int32)))
        else:
            raise ValueError(f"unknown CG operator representation {kind!r}")
        for lo in range(0, T, rows):
            hi = min(lo + rows, T)
            blocks = (jnp.asarray(_pad_rows(P[lo:hi], rows)),
                      jnp.asarray(_pad_rows(Q[lo:hi], rows)),
                      jnp.asarray(_pad_rows(R[lo:hi], rows)),
                      jnp.asarray(_pad_rows(Z[lo:hi], rows)))
            if kind == "dense":
                o, r = _cg_errors_dense_jit(
                    *blocks, bj, *opj, use_pallas=bool(use_pallas),
                    interpret=bool(interpret))
            else:
                o, r = _cg_errors_sparse_jit(*blocks, bj, *opj)
            orth[lo:hi] = np.asarray(o)[:hi - lo]
            rel[lo:hi] = np.asarray(r)[:hi - lo]
    return orth, rel


def mm_chunk_stats(V: np.ndarray, *, use_pallas: Optional[bool] = None,
                   interpret: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched ABFT checksum statistics over full-checksum matrices.

    V is a (B, m, m) stack of post-crash chunk images (m = n+1 with the
    checksum row/column in place) — one slab per (cell, examined chunk)
    pair. Returns per-slab

      nonzero  any element != 0 (exact on device)
      absmax   max |V| (exact on device — no accumulation)
      rowmax   max row-checksum residual |V[:-1,-1] - sum(data, axis=1)|
      colmax   max col-checksum residual |V[-1,:-1] - sum(data, axis=0)|

    matching ``repro.core.abft.residuals``/``verify`` up to device
    summation order (callers apply a certainty band on rowmax/colmax;
    nonzero and the tolerance derived from absmax are exact).
    """
    _require_jax()
    if use_pallas is None:
        from ...kernels.abft_matmul.ops import on_tpu
        use_pallas = on_tpu()
    B, m, _ = V.shape
    rows = _chunk_rows(B, m * m)
    nonzero = np.empty(B, dtype=bool)
    absmax = np.empty(B, dtype=np.float64)
    rowmax = np.empty(B, dtype=np.float64)
    colmax = np.empty(B, dtype=np.float64)
    with enable_x64():
        for lo in range(0, B, rows):
            hi = min(lo + rows, B)
            nz, am, rm, cm = _mm_stats_jit(
                jnp.asarray(_pad_rows(V[lo:hi], rows)),
                use_pallas=bool(use_pallas), interpret=bool(interpret))
            nonzero[lo:hi] = np.asarray(nz)[:hi - lo]
            absmax[lo:hi] = np.asarray(am)[:hi - lo]
            rowmax[lo:hi] = np.asarray(rm)[:hi - lo]
            colmax[lo:hi] = np.asarray(cm)[:hi - lo]
    return nonzero, absmax, rowmax, colmax
