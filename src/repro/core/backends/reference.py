"""Exact per-entry LRU/FIFO backend — the semantic oracle.

This is the original ``VolatileCache`` (an ``OrderedDict`` walked one
entry at a time), kept as the reference implementation that the
vectorized backend must match byte-for-byte on any trace. It is the
right choice for small caches / short traces and for equivalence
testing; for large sweeps use ``VectorizedBackend``.

Two deliberate changes from the pre-backend implementation:

* ``drain()`` now goes through the same eviction bookkeeping as
  capacity evictions, so drained entries count in ``lines_evicted`` and
  their writebacks are charged like any other eviction (the old copy
  of the loop silently skipped both);
* all stats for one operation are aggregated and charged once through
  :meth:`TrafficStats.charge_batch`, making stats bit-identical across
  backends (per-entry float accumulation orders would differ).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from .base import (LineSurvival, OpAccumulator as _OpAcc, select_survivors,
                   select_survivor_words)

__all__ = ["ReferenceLRUBackend"]


class ReferenceLRUBackend:
    """Fully-associative LRU (or FIFO) write-back cache, entry at a time.

    Keys are ``(region, entry_index)`` where an *entry* covers
    ``sector_lines`` consecutive cache lines of that region. Only
    occupancy and dirtiness are tracked — the newest data lives in the
    registered truth arrays; the store's image holds whatever has been
    written back.
    """

    kind = "reference"

    def __init__(self, store, cfg):
        self.store = store
        self.cfg = cfg
        self.capacity_lines = max(1, cfg.cache_bytes // cfg.line_bytes)
        # value = dirty flag; weight per entry is a per-region constant
        self._lru: "OrderedDict[Tuple[str, int], bool]" = OrderedDict()
        self._weight_used = 0
        self._truth: Dict[str, np.ndarray] = {}
        self._sector_lines: Dict[str, int] = {}

    # -- registration ------------------------------------------------------
    def register(self, name: str, truth_flat: np.ndarray,
                 sector_lines: int = 1) -> None:
        self._truth[name] = truth_flat
        self._sector_lines[name] = max(1, int(sector_lines))

    def unregister(self, name: str) -> None:
        self._truth.pop(name, None)
        stale = [k for k in self._lru if k[0] == name]
        w = self._sector_lines.get(name, 1)
        for k in stale:
            del self._lru[k]
            self._weight_used -= w
        self._sector_lines.pop(name, None)

    # -- geometry ----------------------------------------------------------
    def _elems_per_entry(self, name: str) -> int:
        epl = max(1, self.cfg.line_bytes // self._truth[name].itemsize)
        return epl * self._sector_lines[name]

    def _entry_range(self, name: str, lo: int, hi: int) -> range:
        epe = self._elems_per_entry(name)
        return range(lo // epe, (hi - 1) // epe + 1) if hi > lo else range(0)

    # -- internals ----------------------------------------------------------
    def _evict_one(self, acc: _OpAcc) -> None:
        (name, entry), dirty = self._lru.popitem(last=False)
        self._weight_used -= self._sector_lines[name]
        if dirty:
            acc.wb_bytes += self._writeback_entry(name, entry)
        acc.evict_lines += self._sector_lines[name]

    def _writeback_entry(self, name: str, entry: int) -> int:
        truth = self._truth[name]
        epe = self._elems_per_entry(name)
        lo = entry * epe
        hi = min(lo + epe, truth.shape[0])
        if hi > lo:
            self.store.persist(name, lo, hi, truth)
            return (hi - lo) * truth.itemsize
        return 0

    def _touch(self, name: str, entry: int, dirty: bool, acc: _OpAcc) -> None:
        key = (name, entry)
        if self.cfg.replacement == "fifo":
            # FIFO: hits update dirtiness in place (no reordering), so hot
            # lines age out periodically like victims of set conflicts
            prev = self._lru.get(key)
            if prev is not None:
                if dirty and not prev:
                    self._lru[key] = True
                return
            w = self._sector_lines[name]
            while self._weight_used + w > self.capacity_lines and self._lru:
                self._evict_one(acc)
            self._weight_used += w
            self._lru[key] = dirty
            return
        prev = self._lru.pop(key, None)
        if prev is None:
            w = self._sector_lines[name]
            while self._weight_used + w > self.capacity_lines and self._lru:
                self._evict_one(acc)
            self._weight_used += w
        self._lru[key] = dirty or bool(prev)

    # -- program-visible operations ------------------------------------------
    def write(self, name: str, lo: int, hi: int) -> None:
        acc = _OpAcc()
        for entry in self._entry_range(name, lo, hi):
            self._touch(name, entry, dirty=True, acc=acc)
        self.store.stats.charge_batch(
            self.cfg, write_bytes=acc.wb_bytes, evict_lines=acc.evict_lines)

    def read(self, name: str, lo: int, hi: int) -> None:
        acc = _OpAcc()
        for entry in self._entry_range(name, lo, hi):
            if (name, entry) not in self._lru:
                acc.read_entries += 1
            self._touch(name, entry, dirty=False, acc=acc)
        epe = self._elems_per_entry(name)
        itemsize = self._truth[name].itemsize
        self.store.stats.charge_batch(
            self.cfg, write_bytes=acc.wb_bytes,
            read_bytes=acc.read_entries * epe * itemsize,
            evict_lines=acc.evict_lines)

    def flush(self, name: str, lo: int = 0, hi: Optional[int] = None) -> None:
        if hi is None:
            hi = self._truth[name].shape[0]
        entries = self._entry_range(name, lo, hi)
        sector = self._sector_lines[name]
        itemsize = self._truth[name].itemsize
        epe = self._elems_per_entry(name)
        wb_bytes = 0
        clean = 0
        for entry in entries:
            key = (name, entry)
            dirty = self._lru.pop(key, None)
            if dirty is not None:
                self._weight_used -= sector
            if dirty:
                wb_bytes += self._writeback_entry(name, entry)
            else:
                # clean/absent flush still occupies the memory pipeline
                clean += 1
        self.store.stats.charge_batch(
            self.cfg, write_bytes=wb_bytes,
            flush_lines=len(entries) * sector,
            clean_flush_bytes=clean * epe * itemsize)

    def drain(self) -> None:
        acc = _OpAcc()
        while self._lru:
            self._evict_one(acc)
        self.store.stats.charge_batch(
            self.cfg, write_bytes=acc.wb_bytes, evict_lines=acc.evict_lines)

    def crash(self, survival: Optional[LineSurvival] = None) -> int:
        dirty = self.dirty_eviction_order()
        if survival is not None and survival.granularity == "word":
            return self._crash_words(dirty, survival)
        survivors = select_survivors(dirty, survival)
        if survivors:
            nbytes = 0
            for name, entry in survivors:
                nbytes += self._writeback_entry(name, entry)
            self.store.stats.note_torn_persist(nbytes, len(survivors))
        self._lru.clear()
        self._weight_used = 0
        return len(dirty) - len(survivors)

    def _crash_words(self, dirty, survival: LineSurvival) -> int:
        """Word-granularity torn crash: individual machine words of the
        dirty entries persist (sub-line WITCHER crash states). An entry
        counts as lost only if none of its words made it."""
        words = select_survivor_words(dirty, survival, self.entry_geometry)
        if words:
            nbytes = 0
            for name, _entry, lo, hi in words:
                truth = self._truth[name]
                self.store.persist(name, lo, hi, truth)
                nbytes += (hi - lo) * truth.itemsize
            self.store.stats.note_torn_persist(nbytes, len(words))
        touched = {(name, entry) for name, entry, _lo, _hi in words}
        self._lru.clear()
        self._weight_used = 0
        return len(dirty) - len(touched)

    # -- snapshot / fork ----------------------------------------------------
    def snapshot(self) -> object:
        # keys are (name, entry) tuples and values plain bools, so one
        # OrderedDict copy is an exact deep capture incl. recency order
        return (OrderedDict(self._lru), self._weight_used)

    def restore(self, snap: object) -> None:
        lru, weight = snap
        self._lru = OrderedDict(lru)
        self._weight_used = weight

    # -- introspection ------------------------------------------------------
    @property
    def occupancy_lines(self) -> int:
        return self._weight_used

    def dirty_entries(self, name: str) -> np.ndarray:
        out = sorted(e for (n, e), d in self._lru.items() if n == name and d)
        return np.asarray(out, dtype=np.int64)

    def has_dirty(self, name: str) -> bool:
        return any(d for (n, _e), d in self._lru.items() if n == name)

    def dirty_eviction_order(self):
        # OrderedDict iteration order IS the eviction order (front =
        # next victim), so the dirty keys in place are the canonical
        # eviction_order input select_survivors expects
        return [key for key, d in self._lru.items() if d]

    def entry_geometry(self, name: str):
        truth = self._truth[name]
        return self._elems_per_entry(name), truth.shape[0], truth.itemsize
