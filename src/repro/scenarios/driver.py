"""Scenario driver: one loop that runs any Workload under any
ConsistencyStrategy against any CrashPlan, and a batched sweep.

``run_scenario`` is the uniform experiment harness the paper's
per-algorithm drivers used to hand-roll: set up, step, optionally crash
(at a step boundary, or *torn* — inside the boundary, before the
strategy's persistence hook; with a ``TornSpec`` the torn crash also
persists a seeded subset of the dirty cache lines, see
repro.scenarios.crashplan), recover through the strategy, resume, and
report a :class:`ScenarioResult` with overhead / recompute / correctness
/ traffic fields that mean the same thing in every cell. Line-survival
cells carry the extended ``torn_detected`` / ``torn_corrupt``
correctness classes (:func:`classify_recovery`).

``sweep`` expands a workloads × strategies × crash-plans matrix
(seeded ``random`` plans contribute one cell per sampled crash point),
runs every cell on the vectorized emulation backend, and optionally
writes the ``BENCH_scenarios.json`` artifact. Two execution engines:

  engine="fork"  (default) the prefix-sharing engine in
                 :mod:`repro.scenarios.sweep_engine`: each (workload,
                 strategy) pair runs forward ONCE, snapshots are
                 captured at the union of the plans' crash points, and
                 every cell forks from its snapshot — crash, recover,
                 run only the tail. O(tail) per cell.
  engine="rerun" the from-scratch baseline: every cell re-executes its
                 whole prefix on a fresh workload. O(full run) per
                 cell; kept as the oracle the fork engine must match
                 cell-for-cell (tests/benchmarks enforce it).

Orthogonal to the engine, two execution *modes*:

  mode="full"    (default) every crashed cell recovers, re-executes the
                 tail, and runs ``finalize()`` — the complete
                 ScenarioResult including end-of-run correctness,
                 metrics, and traffic.
  mode="measure" the EasyCrash/WITCHER crash-image-inspection shape:
                 crashed cells stop after strategy recovery and
                 *compute* the recompute-cost and correctness-class
                 fields from the recovered state + the cost model —
                 no tail execution, no ``finalize()``. Each crashed
                 cell costs O(restore + recover) instead of O(tail),
                 which is what makes exhaustive dense sweeps
                 (``CrashPlan.at_every_step()`` over every strategy)
                 cheap. Measured cells omit the fields only a full run
                 defines (:data:`FULL_RUN_FIELDS`); every field they DO
                 emit is identical to the full-execution cell
                 (``measure_divergence_fields`` is the checker; tests
                 and the ``sweep_timing`` CI gate enforce it).
                 ``no_crash`` cells always run full (their "tail" is
                 empty, so finalize is the only cost).

``workers=N`` shards the (workload, strategy) pairs of a sweep across
N processes — pairs are fully independent (fork-engine snapshots are
per-emulator), results merge back in deterministic pair-major order,
and ``workers=1`` is byte-identical to the serial path.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.nvm import NestedCrashFault, NVMConfig
from .crashplan import CrashPlan, CrashPoint
from .strategies import STRATEGIES, ConsistencyStrategy, make_strategy
from .workloads import (WORKLOADS, Workload, make_workload,
                        unknown_name_error)

__all__ = ["ScenarioResult", "run_scenario", "sweep", "DEFAULT_SWEEP_PLANS",
           "AVG_STEP_JITTER_FLOOR", "SWEEP_ENGINES", "SWEEP_MODES",
           "WALL_CLOCK_FIELDS", "FULL_RUN_FIELDS", "FORK_ONLY_FIELDS",
           "deterministic_cell_dict", "measure_divergence_fields",
           "classify_recovery"]

# Below this measured mean step wall-time, per-step timing is dominated
# by timer resolution / interpreter jitter, so ``avg_step_seconds``
# falls back to the emulator's deterministic modeled per-step cost
# (which also makes fork- and rerun-engine results comparable bit for
# bit at smoke sizes).
AVG_STEP_JITTER_FLOOR = 1e-3

SWEEP_ENGINES = ("fork", "rerun")
SWEEP_MODES = ("full", "measure", "batched")

# ScenarioResult fields derived from host wall-clock measurement.
# Everything else is deterministic — modeled seconds, traffic counts,
# recompute/restart bookkeeping, correctness — and must come out
# IDENTICAL from both sweep engines (tests + the sweep_timing
# benchmark's divergence gate enforce it). avg_step_seconds /
# resume_seconds are wall-derived only above AVG_STEP_JITTER_FLOOR,
# but whether the floor triggers is itself a wall-clock fact, so the
# engine-invariance contract excludes all three.
WALL_CLOCK_FIELDS = ("wall_seconds", "avg_step_seconds", "resume_seconds")

# ScenarioResult fields only a FULL execution (tail replay + finalize)
# defines: end-of-run correctness/metrics, end-of-run traffic counters,
# and the emulator's total modeled seconds. mode="measure" cells stop
# at strategy recovery, set these to None, and ``to_json_dict`` omits
# them — so a measured cell dict is a strict subset of the full cell
# dict, equal on every shared deterministic field.
FULL_RUN_FIELDS = ("correct", "metrics", "traffic", "modeled_total_seconds")

# Fields only the FORK engine can compute: byte-certification diffs the
# recovered state against the golden-prefix snapshot at the restart
# point, and only the fork engine holds those snapshots. Excluded from
# the engine-invariance contract the same way wall-clock fields are.
FORK_ONLY_FIELDS = ("state_certified",)


def deterministic_cell_dict(res: "ScenarioResult") -> Dict[str, Any]:
    """``to_json_dict`` minus :data:`WALL_CLOCK_FIELDS` and
    :data:`FORK_ONLY_FIELDS` — the payload on which fork- and
    rerun-engine sweeps must agree cell-for-cell."""
    d = res.to_json_dict()
    for f in WALL_CLOCK_FIELDS + FORK_ONLY_FIELDS:
        d.pop(f, None)
    return d


def measure_divergence_fields(measured: "ScenarioResult",
                              full: "ScenarioResult") -> List[str]:
    """The measure-mode contract checker: every deterministic field a
    measured cell emits must exist in — and equal — the full-execution
    cell. Returns the offending field names ([] = contract holds)."""
    dm = deterministic_cell_dict(measured)
    df = deterministic_cell_dict(full)
    return sorted(k for k in dm if k not in df or dm[k] != df[k])


def _digests_equal(a, b) -> bool:
    """np.array_equal-aware dict equality for ``restart_digest`` values
    (shared by the fork engine's byte-certification and the fault
    campaigns' golden-cell comparison)."""
    if set(a) != set(b):
        return False
    for k, va in a.items():
        vb = b[k]
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            if not np.array_equal(np.asarray(va), np.asarray(vb)):
                return False
        elif va != vb:
            return False
    return True


def _crash_and_recover(wl: Workload, strat: ConsistencyStrategy,
                       point: CrashPoint,
                       recover: bool = True) -> Optional["RecoveryResult"]:
    """Crash at ``point`` and run strategy recovery, honoring the
    point's recovery-time :class:`~repro.scenarios.crashplan.FaultSpec`.

    Fault-free points (and ``recover=False``) keep the classic shape:
    one crash, one recovery. A faulted point first runs the *golden*
    pass — the identical crash with no fault, recovered once — records
    its restart bookkeeping and ``restart_digest``, and rewinds the
    workload+strategy to the pre-crash snapshot (``crash()`` is
    deterministic, so the faulted re-crash produces a byte-identical
    image). The faulted pass then injects the media fault (if any) into
    the post-crash image and retries recovery under the armed
    nested-crash trap, re-crashing with the spec's derived torn
    survival each time the trap fires, up to ``max_attempts``.

    Returns the final RecoveryResult annotated with the fault
    bookkeeping ``classify_recovery`` consumes (``recovery_attempts``,
    ``nested_crashes``, ``fault_words_injected``,
    ``recovery_golden_match``) — or None when recovery never completed
    within the attempt budget (the cell classifies ``unrecovered``)."""
    emu = wl.emu
    crash_step, torn = point.step, point.torn
    fault = point.fault
    if not recover:
        emu.crash(point.survival)
        return None
    if fault is None:
        emu.crash(point.survival)
        return strat.recover(crash_step, torn, point.survival)

    # golden pass: the single-crash cell this faulted cell is certified
    # against. The snapshot rewind restores emulator state (truth,
    # image, cache, stats) AND mechanism state, so the faulted pass
    # re-crashes from exactly the same pre-crash world.
    pre_wl = wl.snapshot()
    pre_strat = strat.snapshot()
    emu.crash(point.survival)
    golden = strat.recover(crash_step, torn, point.survival)
    golden_restart = (golden.restart_point, golden.resume_step)
    golden_digest = wl.restart_digest(golden.restart_point)
    wl.restore_snapshot(pre_wl)
    strat.restore_snapshot(pre_strat)

    # faulted pass
    emu.crash(point.survival)
    injected = []
    mf = fault.media_fault()
    if mf is not None:
        names = fault.resolve_poison_regions(
            r.name for r in wl.live_regions())
        if names:
            injected = emu.inject_media_fault(mf, names)
    rec = None
    firings = 0
    attempts = 0
    while attempts < fault.max_attempts:
        attempts += 1
        if fault.nested_after is not None and firings < fault.nested_crashes:
            emu.arm_nested_crash(fault.nested_after)
        try:
            rec = strat.recover(crash_step, torn, point.survival)
            emu.disarm_nested_crash()
            break
        except NestedCrashFault:
            firings += 1
            emu.crash(fault.nested_survival(firings))
    if rec is None:
        emu.disarm_nested_crash()
        return None

    rec.info["recovery_attempts"] = attempts
    if fault.nested_after is not None:
        rec.info["nested_crashes"] = firings
    if mf is not None:
        rec.info["fault_words_injected"] = len(injected)
    match = (rec.restart_point, rec.resume_step) == golden_restart
    if match:
        digest = wl.restart_digest(rec.restart_point)
        if digest is not None and golden_digest is not None:
            match = _digests_equal(digest, golden_digest)
    rec.info["recovery_golden_match"] = bool(match)
    return rec


def classify_recovery(crashed: bool, crash_step: Optional[int],
                      rec: Optional["RecoveryResult"],
                      survival=None) -> str:
    """Correctness class of a cell, computed from the recovered state's
    bookkeeping (the strategy's :class:`RecoveryResult`) — no tail
    execution required, so measure-mode cells carry it too:

      complete             the run never crashed
      unrecovered          crashed and recovery was not attempted
      scratch_restart      recovery restarts from step 0
      consistent_rollback  recovery resumed from a consistent earlier
                           point; deterministic tail replay re-derives
                           everything that was lost
      lost_updates         completed work was lost that replay will NOT
                           re-derive (steps_lost exceeds the steps the
                           tail re-executes — the XSBench Fig.-10
                           stale-counter shape)

    Serving-style workloads (the KV store) generalize ``lost_updates``
    through the ``Workload.audit_recovery`` hook, whose oracle-side
    violation counts in ``rec.info`` map to two classes checked before
    everything below — a recovered store that fails its clients is the
    dominant fact about the cell, whatever the restart bookkeeping says
    (WITCHER's crash-consistency bug taxonomy, applied to a request
    log):

      atomicity_violation  partially-applied state is reader-visible in
                           the recovered store (a torn value or slot a
                           non-validating reader would serve)
      durability_violation an acknowledged update is missing or stale
                           after recovery (the client was told the put
                           committed; the recovered store disagrees)

    For sub-step torn crashes (``survival`` is the crash point's
    :class:`~repro.core.backends.LineSurvival`), two classes report
    *detection coverage* — whether the mechanism's integrity machinery
    caught the inconsistent crash image:

      torn_detected        the mechanism positively identified torn
                           state and excluded or repaired it (CG's
                           invariant scan rejected versions, ABFT's
                           checksums flagged chunks, the undo log
                           rolled back / rejected a torn log-tail,
                           XSBench's counters disagreed with the index)
                           and the resume point loses nothing replay
                           cannot re-derive;
      torn_corrupt         torn state slipped into the recovered run:
                           either the strategy certifies the state
                           un-repairable (``info["state_corrupt"]``,
                           e.g. surviving counter increments past the
                           persisted index that replay double-counts)
                           or work was lost that replay cannot
                           re-derive (the lost_updates condition).

    Cells whose crash point carried a
    :class:`~repro.scenarios.crashplan.FaultSpec` are certified against
    the *golden* single-crash cell (same crash, no fault — see
    :func:`_crash_and_recover`) and classify through four fault classes,
    checked before everything above except ``unrecovered`` (a fault
    campaign's question — did recovery survive the fault, did the
    machinery see the corruption — outranks the ordinary bookkeeping,
    which the golden comparison already covers):

      recovery_idempotent  >= 1 nested crash interrupted recovery and
                           the retried recovery still landed on exactly
                           the golden cell's restart point and digest —
                           recovery is re-entrant here, proven not
                           assumed;
      recovery_diverged    the nested crash changed where (or on what
                           state) recovery landed — the WITCHER class
                           of crash-unsafe recovery code;
      fault_detected       silently corrupted post-crash state was
                           positively flagged by the mechanism's
                           integrity machinery (invariant scan, ABFT
                           checksums, undo-log CRCs, KV row checksums);
      fault_silent         the corruption was neither flagged nor
                           landed on golden-equivalent state: the
                           recovered run proceeds on bad data with no
                           signal — the coverage hole this class exists
                           to surface. (An injected fault that recovery
                           neither sees nor is affected by — e.g. a
                           poisoned version slot the backward scan never
                           visits — is harmless and falls through to the
                           ordinary classes.)
    """
    if not crashed or crash_step is None:
        return "complete"
    if rec is None:
        return "unrecovered"
    if int(rec.info.get("nested_crashes") or 0) > 0:
        return ("recovery_idempotent"
                if rec.info.get("recovery_golden_match")
                else "recovery_diverged")
    if int(rec.info.get("fault_words_injected") or 0) > 0:
        detected = bool(rec.info.get("torn_flagged")
                        or rec.info.get("state_corrupt")
                        or int(rec.info.get("log_entries_rejected") or 0) > 0
                        or int(rec.info.get("payload_crc_mismatches") or 0) > 0
                        or int(rec.info.get("slots_dropped") or 0) > 0
                        or int(rec.info.get("corrected_elements") or 0) > 0)
        if detected:
            return "fault_detected"
        if not rec.info.get("recovery_golden_match"):
            return "fault_silent"
        # injected but undetected AND golden-equivalent: harmless —
        # fall through to the ordinary classes
    if int(rec.info.get("atomicity_violations") or 0) > 0:
        return "atomicity_violation"
    if int(rec.info.get("durability_violations") or 0) > 0:
        return "durability_violation"
    torn_sub = survival is not None
    if torn_sub and rec.info.get("state_corrupt"):
        return "torn_corrupt"
    if rec.from_scratch or rec.restart_point < 0:
        return "scratch_restart"
    lost, redo = _recovery_bookkeeping(rec, crash_step)
    if lost > redo:
        return "torn_corrupt" if torn_sub else "lost_updates"
    if torn_sub and rec.info.get("torn_flagged"):
        return "torn_detected"
    return "consistent_rollback"


@dataclasses.dataclass
class ScenarioResult:
    """Uniform per-cell outcome (JSON-serializable via ``to_json_dict``).

    The fields in :data:`FULL_RUN_FIELDS` are ``None`` on mode="measure"
    cells (they require tail execution + ``finalize()``) and omitted
    from the JSON dict; everything else means the same thing in every
    cell regardless of engine or mode."""

    workload: str
    workload_params: Dict[str, Any]
    strategy: str
    plan: str
    crash_step: Optional[int]
    torn: bool
    # line-survival spec of a sub-step torn crash ("random:f0.5:s3");
    # None for boundary and bare-torn crashes. Part of the cell's
    # identity: multi-sample TornSpec plans emit several cells at the
    # same (plan, crash_step) that differ only here
    torn_survival: Optional[str]
    # fault campaign spec of the crash point ("nested:a3:f0.5:s0",
    # "poison:w2:s1:kv.index"); None for ordinary cells. Part of the
    # cell's identity, like torn_survival
    fault: Optional[str]
    steps_total: int
    steps_done: int
    restart_point: Optional[int]     # newest surviving step; -1 => scratch
    resume_step: Optional[int]
    steps_lost: int
    steps_recomputed: int
    detect_seconds: float
    resume_seconds: float
    # mean seconds per pre-crash step of the phase the crash landed in:
    # measured wall-clock when the mean is >= AVG_STEP_JITTER_FLOOR,
    # otherwise the emulator's modeled per-step seconds (wall timing at
    # smoke sizes is pure jitter; the modeled cost is deterministic)
    avg_step_seconds: float
    overhead_seconds: float          # modeled mechanism cost (cost model)
    modeled_total_seconds: Optional[float]  # emulator's total modeled seconds
    wall_seconds: float
    correct: Optional[bool]
    # recovered-state classification (see classify_recovery) — defined
    # in every mode, unlike the end-of-run ``correct`` bit
    correctness_class: str
    # measure-mode byte-certification (fork engine only): recovered
    # state byte-equals the golden-prefix digest at the restart point
    # (scratch restarts certify against the pre-step-0 snapshot). None
    # when not computable (rerun engine, full mode, or no golden
    # snapshot at the restart step)
    state_certified: Optional[bool]
    metrics: Optional[Dict[str, float]]
    traffic: Optional[Dict[str, int]]
    info: Dict[str, Any] = dataclasses.field(default_factory=dict, repr=False)

    def to_json_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("info")
        for f in FULL_RUN_FIELDS + FORK_ONLY_FIELDS + ("torn_survival",
                                                       "fault"):
            if d[f] is None:
                d.pop(f)
        return _jsonable(d)


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def _avg_step_seconds(wall_durs: Sequence[float],
                      modeled_durs: Sequence[float]) -> float:
    wall = sum(wall_durs) / max(1, len(wall_durs))
    if wall >= AVG_STEP_JITTER_FLOOR:
        return wall
    return sum(modeled_durs) / max(1, len(modeled_durs))


def _forward(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint
             ) -> Tuple[bool, List[float], List[float]]:
    """Run forward until completion or the crash point. Returns
    (crashed, per-step wall durations, per-step modeled-seconds deltas)
    — the modeled deltas are the deterministic counterpart the jitter
    floor falls back to. A torn crash's last entry covers only
    before_step+step (the persistence hook never ran)."""
    crash_step, torn = point.step, point.torn
    emu = wl.emu
    wall: List[float] = []
    modeled: List[float] = []
    crashed = False
    for i in range(wl.n_steps):
        ts = time.perf_counter()
        m0 = emu.modeled_seconds()
        strat.before_step(i)
        wl.step(i)
        if torn and crash_step == i:
            wall.append(time.perf_counter() - ts)
            modeled.append(emu.modeled_seconds() - m0)
            crashed = True
            break
        strat.after_step(i)
        wall.append(time.perf_counter() - ts)
        modeled.append(emu.modeled_seconds() - m0)
        if crash_step == i:
            crashed = True
            break
    return crashed, wall, modeled


def _crash_avg_step(wl: Workload, crash_step: Optional[int], crashed: bool,
                    wall_durs: Sequence[float],
                    modeled_durs: Sequence[float]) -> float:
    """Mean per-step seconds, normalized against the phase the crash
    landed in (loop-2 block additions are much cheaper than loop-1
    chunk multiplies)."""
    if not crashed:
        return _avg_step_seconds(wall_durs, modeled_durs)
    phase_rng = next((rng for rng in wl.phases().values()
                      if crash_step in rng), range(wl.n_steps))
    idx = [j for j in phase_rng if j < len(wall_durs)]
    return _avg_step_seconds([wall_durs[j] for j in idx],
                             [modeled_durs[j] for j in idx])


def _recovery_bookkeeping(rec, crash_step: int) -> Tuple[int, int]:
    """(steps_lost, steps_recomputed) from a RecoveryResult."""
    lost = rec.steps_lost if rec.steps_lost is not None else (
        crash_step - rec.restart_point if rec.restart_point >= 0
        else crash_step + 1)
    return lost, rec.redo_steps


def _finish(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint,
            plan_desc: str, recover: bool, crashed: bool,
            wall_durs: Sequence[float], modeled_durs: Sequence[float],
            t0: float) -> ScenarioResult:
    """Crash (if armed), recover, run the tail, finalize, and assemble
    the ScenarioResult. Shared verbatim by the rerun path (after its own
    forward pass) and the fork engine (after restoring a snapshot)."""
    crash_step, torn = point.step, point.torn
    emu = wl.emu
    n = wl.n_steps
    steps_run = (crash_step + 1) if crashed else n
    avg_step = _crash_avg_step(wl, crash_step, crashed, wall_durs,
                               modeled_durs)

    restart: Optional[int] = None
    resume: Optional[int] = None
    lost = 0
    redo = 0
    detect_s = 0.0
    rec = None
    rec_info: Dict[str, Any] = {}
    steps_done = n

    if crashed:
        rec = _crash_and_recover(wl, strat, point, recover)
        if rec is not None:
            # oracle-side audit of the recovered state (durability /
            # atomicity violation counts) BEFORE the tail replay papers
            # over what recovery actually produced
            wl.audit_recovery(rec, crash_step, torn)
            restart, resume = rec.restart_point, rec.resume_step
            detect_s = rec.detect_seconds
            lost, redo = _recovery_bookkeeping(rec, crash_step)
            rec_info = dict(rec.info)
            for j in range(rec.resume_step, n):
                strat.before_step(j)
                wl.step(j)
                strat.after_step(j)
        else:
            steps_done = crash_step + 1
            if recover:
                # recovery itself died (nested crashes exhausted every
                # attempt): nothing recovered, nothing replayed
                lost = crash_step + 1

    report = wl.finalize()
    overhead = strat.modeled_overhead_seconds(wl.step_cost_profile(),
                                              emu.cfg, steps_run)
    stats = emu.stats

    # a recovery the audit caught violating durability/atomicity is not
    # a correct run even when the deterministic tail replay re-derives a
    # clean end state — the clients already observed the violation
    violations = (int(rec_info.get("durability_violations") or 0)
                  + int(rec_info.get("atomicity_violations") or 0))
    info = dict(report.info)
    info.update(rec_info)
    return ScenarioResult(
        workload=wl.name, workload_params=wl.params(),
        strategy=strat.name, plan=plan_desc,
        crash_step=crash_step, torn=torn,
        torn_survival=(point.survival.describe()
                       if point.survival is not None else None),
        fault=(point.fault.describe() if point.fault is not None else None),
        steps_total=n, steps_done=steps_done,
        restart_point=restart, resume_step=resume,
        steps_lost=lost, steps_recomputed=redo,
        detect_seconds=detect_s, resume_seconds=avg_step * redo,
        avg_step_seconds=avg_step,
        overhead_seconds=overhead,
        modeled_total_seconds=emu.modeled_seconds(),
        wall_seconds=time.perf_counter() - t0,
        correct=report.correct and violations == 0,
        correctness_class=classify_recovery(crashed, crash_step, rec,
                                            point.survival),
        state_certified=None,
        metrics=dict(report.metrics),
        traffic={
            "nvm_bytes_written": stats.nvm_bytes_written,
            "nvm_bytes_read": stats.nvm_bytes_read,
            "lines_flushed": stats.lines_flushed,
            "lines_evicted": stats.lines_evicted,
            "torn_bytes_persisted": stats.torn_bytes_persisted,
            "torn_entries_persisted": stats.torn_entries_persisted,
        },
        info=info,
    )


def _measure(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint,
             plan_desc: str, wall_durs: Sequence[float],
             modeled_durs: Sequence[float], t0: float,
             certify=None) -> ScenarioResult:
    """The mode="measure" cell evaluator: crash, run strategy recovery,
    then *compute* every recompute/restart/cost field from the recovered
    state + the cost model — no tail execution, no ``finalize()``. The
    caller must hand us the workload positioned at the crash point (the
    fork engine restores a snapshot; the rerun engine just ran forward).

    ``certify`` (fork engine only) is a callable ``(RecoveryResult) ->
    Optional[bool]`` that byte-diffs the recovered state against the
    golden-prefix digest at the restart point — the ``state_certified``
    field. It may leave the workload in an arbitrary restored state;
    the measured cell is already fully determined by then.

    Only called for crashed cells — no_crash cells carry end-of-run
    correctness/metrics, which require ``finalize()``, so both engines
    route them through :func:`_finish` (whose "tail" is empty there)."""
    crash_step, torn = point.step, point.torn
    emu = wl.emu
    n = wl.n_steps
    avg_step = _crash_avg_step(wl, crash_step, True, wall_durs,
                               modeled_durs)

    torn_before = emu.stats.torn_bytes_persisted
    rec = _crash_and_recover(wl, strat, point)
    # the golden pass (fault cells) rewinds its own traffic via
    # restore_snapshot, so the delta covers exactly the faulted crash
    # plus any nested re-crashes
    torn_persisted = emu.stats.torn_bytes_persisted - torn_before
    if rec is not None:
        # audit BEFORE certify: the certification closure may restore
        # the workload to the golden state, and the audit must see what
        # recovery actually produced
        wl.audit_recovery(rec, crash_step, torn)
        lost, redo = _recovery_bookkeeping(rec, crash_step)
        restart, resume = rec.restart_point, rec.resume_step
        detect_s = rec.detect_seconds
        certified = certify(rec) if certify is not None else None
        info = dict(rec.info)
    else:
        # recovery died under nested crashes on every allowed attempt
        lost, redo = crash_step + 1, 0
        restart = resume = None
        detect_s = 0.0
        certified = None
        info = {}
    overhead = strat.modeled_overhead_seconds(wl.step_cost_profile(),
                                              emu.cfg, crash_step + 1)
    if point.survival is not None:
        # measure cells carry no end-of-run traffic dict; surface this
        # crash's in-flight writebacks for fig_torn's survivor budget
        info["torn_bytes_persisted"] = torn_persisted

    return ScenarioResult(
        workload=wl.name, workload_params=wl.params(),
        strategy=strat.name, plan=plan_desc,
        crash_step=crash_step, torn=torn,
        torn_survival=(point.survival.describe()
                       if point.survival is not None else None),
        fault=(point.fault.describe() if point.fault is not None else None),
        steps_total=n, steps_done=n,
        restart_point=restart, resume_step=resume,
        steps_lost=lost, steps_recomputed=redo,
        detect_seconds=detect_s, resume_seconds=avg_step * redo,
        avg_step_seconds=avg_step,
        overhead_seconds=overhead,
        modeled_total_seconds=None,
        wall_seconds=time.perf_counter() - t0,
        correct=None,
        correctness_class=classify_recovery(True, crash_step, rec,
                                            point.survival),
        state_certified=certified,
        metrics=None,
        traffic=None,
        info=info,
    )


def _run_point(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint,
               plan_desc: str, recover: bool,
               mode: str = "full") -> ScenarioResult:
    t0 = time.perf_counter()
    crashed, wall, modeled = _forward(wl, strat, point)
    if mode == "measure" and crashed:
        return _measure(wl, strat, point, plan_desc, wall, modeled, t0)
    return _finish(wl, strat, point, plan_desc, recover, crashed,
                   wall, modeled, t0)


def run_scenario(workload, strategy, plan: Optional[CrashPlan] = None,
                 cfg: Optional[NVMConfig] = None, *,
                 recover: bool = True) -> ScenarioResult:
    """Run one scenario cell.

    workload: Workload | "name" | ("name", {params})
    strategy: ConsistencyStrategy | "name" | "name@interval"
    plan:     CrashPlan (default: no_crash). Must resolve to a single
              crash point — use :func:`sweep` for batch (``random``) plans.
    """
    plan = plan or CrashPlan.no_crash()
    wl = make_workload(workload)
    strat = make_strategy(strategy)
    if wl.mode is None:
        wl.setup(cfg, "adcc" if strat.wants_adcc else "plain")
    elif strat.wants_adcc and wl.mode != "adcc":
        raise ValueError(f"workload set up in mode {wl.mode!r} cannot run "
                         f"the {strat.name!r} strategy")
    strat.attach(wl)
    points = plan.resolve(wl)
    if len(points) != 1:
        raise ValueError(
            f"plan {plan.describe()!r} resolves to {len(points)} crash "
            f"points; run_scenario takes exactly one (use sweep())")
    return _run_point(wl, strat, points[0], plan.describe(), recover)


DEFAULT_SWEEP_PLANS: Sequence[CrashPlan] = (
    CrashPlan.no_crash(),
    CrashPlan.at_fraction(0.3),
    CrashPlan.at_fraction(0.75, torn=True),
    CrashPlan.random(count=1, seed=0),
)


def _shard_grounded(grounded: List[Tuple[CrashPlan, List[CrashPoint]]],
                    shard: Tuple[int, int]
                    ) -> List[Tuple[CrashPlan, List[CrashPoint]]]:
    """This shard's contiguous slice of the pair's grounded crash
    points, flattened plan-major point-minor and regrouped by plan —
    concatenating every shard's results in shard order reproduces the
    serial cell list exactly."""
    index, count = shard
    flat = [(plan, point) for plan, points in grounded for point in points]
    lo = index * len(flat) // count
    hi = (index + 1) * len(flat) // count
    out: List[Tuple[CrashPlan, List[CrashPoint]]] = []
    for plan, point in flat[lo:hi]:
        if out and out[-1][0] is plan:
            out[-1][1].append(point)
        else:
            out.append((plan, [point]))
    return out


def _sweep_pair(wl_spec, strat_spec, plans: Sequence[CrashPlan],
                cfg: Optional[NVMConfig], engine: str, mode: str,
                progress=None, shard: Optional[Tuple[int, int]] = None,
                snapshot_budget_bytes: Optional[int] = None,
                snapshot_policy: str = "spill"
                ) -> Tuple[List[ScenarioResult], List[Dict[str, str]]]:
    """Run every cell of one (workload, strategy) pair. The unit of work
    both the serial loop and the multiprocess executor share — results
    come back in plan-major, point-minor order either way.

    ``shard=(i, k)`` evaluates only the i-th of k contiguous slices of
    the pair's grounded crash points (plan grounding is deterministic,
    so every shard derives the identical global cell order and its
    slice independently); each shard regenerates its own golden prefix,
    which the fork engine truncates at the shard's last crash point.
    Only shard 0 reports the pair's skipped plans — they are per-pair
    facts, not per-cell."""
    # late imports: both engines import this module (avoids the cycle)
    from .sweep_engine import run_pair_forked

    # one probe per (workload, strategy) pair grounds every plan
    probe = make_workload(wl_spec)
    strat = make_strategy(strat_spec)
    probe.setup(cfg, "adcc" if strat.wants_adcc else "plain")
    skipped: List[Dict[str, str]] = []
    grounded: List[Tuple[CrashPlan, List[CrashPoint]]] = []
    for plan in plans:
        try:
            grounded.append((plan, plan.resolve(probe)))
        except ValueError as exc:
            skipped.append({"workload": probe.name,
                            "strategy": strat.name,
                            "plan": plan.describe(),
                            "reason": str(exc)})
    if shard is not None:
        if shard[0] != 0:
            skipped = []
        grounded = _shard_grounded(grounded, shard)
    if not grounded:
        return [], skipped
    tier_kw = dict(snapshot_budget_bytes=snapshot_budget_bytes,
                   snapshot_policy=snapshot_policy)
    if engine == "fork":
        if mode == "batched":
            from .batched_engine import run_pair_batched
            return (run_pair_batched(probe, strat, grounded,
                                     progress=progress, **tier_kw), skipped)
        return (run_pair_forked(probe, strat, grounded, progress=progress,
                                mode=mode, **tier_kw), skipped)
    results: List[ScenarioResult] = []
    reuse: Optional[Tuple[Workload, ConsistencyStrategy]] = (probe, strat)
    for plan, points in grounded:
        for point in points:
            if reuse is not None:
                wl, st = reuse
                reuse = None
            else:
                wl = make_workload(wl_spec)
                st = make_strategy(strat_spec)
                wl.setup(cfg, "adcc" if st.wants_adcc else "plain")
            st.attach(wl)
            res = _run_point(wl, st, point, plan.describe(),
                             recover=True, mode=mode)
            results.append(res)
            if progress is not None:
                progress(res)
    return results, skipped


def _run_pair_job(job) -> Tuple[List[ScenarioResult], List[Dict[str, str]]]:
    """Top-level (picklable) worker entry for ``sweep(workers=N)``.

    A job is the classic 6-tuple ``(wl_spec, strat_spec, plans, cfg,
    engine, mode)`` — kept as-is so pair-shard journal fingerprints
    stay stable — optionally extended by a 7th options dict carrying
    ``shard`` (crash-point sharding) and the snapshot-tier knobs."""
    wl_spec, strat_spec, plans, cfg, engine, mode = job[:6]
    opts = job[6] if len(job) > 6 else {}
    return _sweep_pair(wl_spec, strat_spec, plans, cfg, engine, mode,
                       shard=opts.get("shard"),
                       snapshot_budget_bytes=opts.get(
                           "snapshot_budget_bytes"),
                       snapshot_policy=opts.get("snapshot_policy", "spill"))


def _check_parallelizable(workloads: Sequence, strategies: Sequence) -> None:
    """workers>1 ships pair specs to worker processes, so specs must be
    the picklable registry forms, not live instances."""
    for wl_spec in workloads:
        if isinstance(wl_spec, Workload):
            raise ValueError(
                "sweep(workers>1) requires registry workload specs "
                "('name' or ('name', {params})), not Workload instances")
    for strat_spec in strategies:
        if isinstance(strat_spec, ConsistencyStrategy):
            raise ValueError(
                "sweep(workers>1) requires strategy spec strings "
                "('name' or 'name@interval'), not instances")


def _validate_sweep_specs(workloads: Sequence, strategies: Sequence) -> None:
    """Fail a typo'd matrix up front in the parent — with the registered
    names and a closest-match suggestion — instead of a bare KeyError
    surfacing from (possibly) a worker process mid-sweep."""
    for wl_spec in workloads:
        if isinstance(wl_spec, Workload):
            continue
        name = wl_spec if isinstance(wl_spec, str) else wl_spec[0]
        if name not in WORKLOADS:
            raise unknown_name_error("workload", name, WORKLOADS)
    for strat_spec in strategies:
        if isinstance(strat_spec, ConsistencyStrategy):
            continue
        name = str(strat_spec).partition("@")[0]
        if name not in STRATEGIES:
            raise unknown_name_error("strategy", name, STRATEGIES)


def _degrade_job(job, reason: str):
    """Graceful-degradation hook for sharded sweeps: step a failed
    shard's evaluation mode down the cost/fragility ladder
    batched -> measure -> full. The batched evaluator leans on the jax
    runtime (the likeliest component to die or wedge in a worker);
    measure leans on per-cell snapshots; full is the plain rerun-style
    execution path. All three agree on every deterministic field, so a
    degraded shard changes how cells are computed, never what they say.
    Point-shard jobs degrade the same way — the trailing options dict
    (shard slice, snapshot-tier knobs) is preserved verbatim.
    """
    wl_spec, strat_spec, plans, cfg, engine, mode = job[:6]
    step_down = {"batched": "measure", "measure": "full"}
    nxt = step_down.get(mode)
    if nxt is None:
        return None
    return (wl_spec, strat_spec, plans, cfg, engine, nxt) + tuple(job[6:])


def sweep(workloads: Sequence = ("cg", "mm", "xsbench"),
          strategies: Sequence = ("none", "adcc", "undo_log",
                                  "checkpoint_hdd", "checkpoint_nvm",
                                  "checkpoint_nvm_dram"),
          plans: Sequence[CrashPlan] = DEFAULT_SWEEP_PLANS,
          cfg: Optional[NVMConfig] = None,
          out_json: Optional[str] = None,
          progress=None,
          engine: str = "fork",
          mode: str = "full",
          workers: int = 1,
          shard_timeout: Optional[float] = None,
          shard_retries: int = 2,
          journal: Optional[str] = None,
          chaos: Optional[Dict[int, str]] = None,
          snapshot_budget_bytes: Optional[int] = None,
          snapshot_policy: Optional[str] = None) -> List[ScenarioResult]:
    """Run the full workloads × strategies × crash-plans matrix.

    All plans of a (workload, strategy) pair are grounded against one
    probe workload; a seeded ``CrashPlan.random(count=k)`` contributes
    ``k`` cells. ``engine`` selects execution (module docstring):
    ``"fork"`` (default) runs each pair forward once and forks every
    cell from a snapshot at its crash point; ``"rerun"`` re-executes
    each cell from step 0 on a fresh workload instance. Both engines
    produce identical cells (modulo ``wall_seconds``); fork makes dense
    plans (``CrashPlan.at_every_step()``) tractable.

    ``mode="measure"`` stops each crashed cell after strategy recovery
    and computes the recompute/restart fields from the recovered state
    (module docstring) — the cell omits :data:`FULL_RUN_FIELDS`.

    ``mode="batched"`` (fork engine only) goes one step further: crashed
    cells are evaluated analytically from the fork snapshots — torn
    survivor selection replayed host-side, recovery derived from the
    post-crash image, and the heavy integrity math (CG invariants, ABFT
    checksums) dispatched as batched jax launches over ALL cells at once
    (:mod:`repro.scenarios.batched_engine`). Deterministic fields are
    identical to measure cells except ``state_certified`` (None — a
    :data:`FORK_ONLY_FIELDS` member, excluded from cell comparisons).
    Pairs the analytic evaluators don't cover fall back to per-cell
    measure evaluation, so batched mode is always safe to request.

    ``workers=N`` shards the (workload, strategy) pairs across N
    supervised processes (pairs are independent; snapshots are
    per-emulator) and merges results in deterministic pair-major order,
    so the cell list is identical to ``workers=1`` regardless of
    completion order. Requires picklable registry specs. ``progress``
    then fires per pair (in merge order) instead of per cell. When
    ``workers`` exceeds the pair count, the spare workers split
    individual pairs' crash points: each point-shard re-grounds the
    pair's plans (grounding is deterministic), takes its contiguous
    slice of the flattened cell list, and regenerates its own golden
    prefix — the merged cell list stays identical to serial
    cell-for-cell, and the journal/retry/chaos machinery covers
    point-shards exactly as it covers pair-shards.

    ``snapshot_budget_bytes`` (default ``REPRO_SNAPSHOT_BUDGET``) caps
    each pair's resident fork-snapshot footprint; over budget the
    least-recently-used snapshot payload is spilled to disk
    (``snapshot_policy="spill"``, the default, env
    ``REPRO_SNAPSHOT_POLICY``) or dropped and re-derived from the
    golden prefix on its next access (``"recompute"``) — see
    :class:`~repro.scenarios.sweep_engine.SnapshotTier`. Cells are
    byte-identical either way; the tier stats ride every cell as
    ``info["snapshot_tier"]``. The rerun engine takes no snapshots and
    ignores the knobs.

    Sharded sweeps self-heal (:mod:`repro.scenarios.pool`): each shard
    gets a wall-clock deadline (``shard_timeout`` seconds, default from
    ``REPRO_SWEEP_SHARD_TIMEOUT`` or 600), a worker that dies or hangs
    is re-dispatched with exponential backoff up to ``shard_retries``
    times, and a shard that keeps failing degrades its evaluation mode
    batched -> measure -> full before the sweep gives up.
    ``journal=<path>`` appends each completed shard to a jsonl journal
    so an interrupted sweep resumed with the same arguments re-executes
    only the missing shards (the journal is deleted on success).
    ``chaos={shard_index: "kill"|"hang"}`` injects a failure into that
    shard's first attempt — the hook the chaos gate uses to prove the
    healing loop, never set in production sweeps.

    ``out_json`` writes the ``BENCH_scenarios.json`` artifact:
    ``{"schema": ..., "cells": [<ScenarioResult>...], "skipped": [...]}``.

    A plan that cannot be grounded for some (workload, strategy) pair —
    e.g. ``at_phase("loop2", ...)`` against the single-loop plain-mode
    MM, or ``at_step(k)`` past a shorter workload's step count — skips
    that cell (recorded in ``skipped``) instead of aborting the matrix.
    """
    if engine not in SWEEP_ENGINES:
        raise ValueError(f"unknown sweep engine {engine!r}; "
                         f"choose from {SWEEP_ENGINES}")
    if mode not in SWEEP_MODES:
        raise ValueError(f"unknown sweep mode {mode!r}; "
                         f"choose from {SWEEP_MODES}")
    if mode == "batched" and engine != "fork":
        raise ValueError('mode="batched" requires engine="fork" — cells '
                         "are evaluated from fork snapshots")
    if workers < 1:
        raise ValueError("workers must be >= 1")
    _validate_sweep_specs(workloads, strategies)
    if snapshot_budget_bytes is None:
        env_budget = os.environ.get("REPRO_SNAPSHOT_BUDGET", "").strip()
        if env_budget:
            snapshot_budget_bytes = int(env_budget)
    if snapshot_policy is None:
        snapshot_policy = os.environ.get("REPRO_SNAPSHOT_POLICY", "spill")
    from .sweep_engine import SNAPSHOT_POLICIES
    if snapshot_policy not in SNAPSHOT_POLICIES:
        raise ValueError(f"unknown snapshot policy {snapshot_policy!r}; "
                         f"choose from {SNAPSHOT_POLICIES}")

    pairs = [(wl_spec, strat_spec)
             for wl_spec in workloads for strat_spec in strategies]
    results: List[ScenarioResult] = []
    skipped: List[Dict[str, str]] = []

    if workers > 1:
        # uniform contract: the spec requirement holds whenever sharding
        # was REQUESTED, even if a single-pair matrix ends up serial
        _check_parallelizable(workloads, strategies)
    if workers > 1:
        import multiprocessing as mp

        from .pool import run_sharded
        start = "fork" if "fork" in mp.get_all_start_methods() else "spawn"
        from ..core.backends.batched import jax_runtime_live
        # forking after this process has instantiated an XLA backend
        # deadlocks the children's device math (inherited locks whose
        # owner threads don't survive the fork), e.g. a serial batched
        # sweep followed by a sharded one. Checked for EVERY mode:
        # batched children launch jit evaluators, and with
        # REPRO_NVM_BACKEND=device even plain measure/full children run
        # device math inside the emulator forward pass.
        if jax_runtime_live():
            start = "spawn"
        if shard_timeout is None:
            shard_timeout = float(
                os.environ.get("REPRO_SWEEP_SHARD_TIMEOUT", "600"))
        # spare workers beyond the pair count split individual pairs'
        # crash points into contiguous point-shards
        shard_counts = [1] * len(pairs)
        if workers > len(pairs):
            base, extra = divmod(workers, len(pairs))
            shard_counts = [base + (1 if i < extra else 0)
                            for i in range(len(pairs))]
        tier_opts: Dict[str, Any] = {}
        if snapshot_budget_bytes is not None:
            tier_opts = {"snapshot_budget_bytes": snapshot_budget_bytes,
                         "snapshot_policy": snapshot_policy}
        jobs: List[tuple] = []
        for (w, s), k in zip(pairs, shard_counts):
            # an unsharded, untiered pair keeps the classic 6-tuple so
            # its journal fingerprint matches pre-point-sharding runs
            base_job = (w, s, tuple(plans), cfg, engine, mode)
            if k == 1:
                jobs.append(base_job + ((dict(tier_opts),)
                                        if tier_opts else ()))
            else:
                jobs.extend(base_job + (dict(tier_opts, shard=(i, k)),)
                            for i in range(k))
        # the merge is job-major (= pair-major, point-shard-minor, i.e.
        # plan-major point-minor within each pair) and deterministic no
        # matter which worker finishes first or how often one is healed
        for pair_results, pair_skipped in run_sharded(
                jobs, _run_pair_job, min(workers, len(jobs)),
                timeout=shard_timeout, retries=shard_retries,
                journal=journal, chaos=chaos, degrade=_degrade_job,
                start_method=start):
            results.extend(pair_results)
            skipped.extend(pair_skipped)
            if progress is not None:
                for res in pair_results:
                    progress(res)
    else:
        for wl_spec, strat_spec in pairs:
            pair_results, pair_skipped = _sweep_pair(
                wl_spec, strat_spec, plans, cfg, engine, mode,
                progress=progress,
                snapshot_budget_bytes=snapshot_budget_bytes,
                snapshot_policy=snapshot_policy)
            results.extend(pair_results)
            skipped.extend(pair_skipped)

    if out_json:
        write_scenarios_json(out_json, results, skipped=skipped)
    return results


def dump_json(path: str, payload) -> None:
    """The artifact writer (benchmarks/common.py re-exports it)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)


def write_scenarios_json(path: str, results: Iterable[ScenarioResult],
                         skipped: Optional[List[Dict[str, str]]] = None
                         ) -> None:
    dump_json(path, {
        "schema": "repro.scenarios.sweep/v1",
        "cells": [r.to_json_dict() for r in results],
        "skipped": skipped or [],
    })
