"""Paper Fig. 7: ABFT-MM recomputation cost for crashes in loop 1
(submatrix multiplication) and loop 2 (submatrix addition), across
matrix sizes. Expect: large matrices lose <= 1 chunk/row-block."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.algorithms.mm_abft import ABFTMatmul
from repro.core.nvm import NVMConfig

from .common import Row, emit

SIZES = [256, 512, 768, 1024]
CACHE = NVMConfig(cache_bytes=4 * 1024 * 1024)


def run() -> List[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for n in SIZES:
        k = n // 4
        A = rng.uniform(-1, 1, (n, n))
        B = rng.uniform(-1, 1, (n, n))
        for loop, it in [("loop1", 2), ("loop2", 2)]:
            mm = ABFTMatmul(A, B, k, CACHE)
            res = mm.run(crash_after=(loop, it))
            assert res.max_error < 1e-9, (n, loop, res.max_error)
            norm = ((res.detect_seconds + res.resume_seconds)
                    / max(res.avg_chunk_seconds, 1e-12))
            rows.append(Row(f"fig7/mm_recompute/n={n}/{loop}/chunks_lost",
                            res.chunks_lost,
                            f"corrected={res.corrected_elements} "
                            f"err={res.max_error:.1e}"))
            rows.append(Row(
                f"fig7/mm_recompute/n={n}/{loop}/normalized_recompute",
                norm, f"detect={res.detect_seconds:.4f}s"))
    return rows


def main() -> None:
    emit(run(), save_as="fig7_mm_recompute.json")


if __name__ == "__main__":
    main()
