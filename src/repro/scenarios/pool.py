"""Self-healing process pool for sharded sweeps.

``multiprocessing.Pool.imap`` — the previous ``sweep(workers=N)``
executor — has exactly the failure modes a crash-consistence harness
should not: a worker that segfaults poisons the pool, a hung worker
stalls the whole sweep forever, a ``KeyboardInterrupt`` in the parent
can strand orphan children, and an interrupted sweep restarts from cell
zero. :func:`run_sharded` replaces it with one supervised ``Process``
per shard and the same resilience loop the scenario layer studies:

* **detection** — each shard gets a wall-clock deadline; the supervisor
  multiplexes on result pipes *and* process sentinels, so a worker that
  dies (killed, segfault) or hangs (deadline exceeded) is classified
  within one poll interval;
* **retry** — failed shards are re-dispatched with exponential backoff
  (``backoff * 2**(attempt-1)``), up to ``retries`` re-runs; shard
  evaluation is deterministic, so a retry is byte-identical to a run
  that never failed;
* **graceful degradation** — when retries are exhausted (or the shard
  raised a real exception, which a retry would only repeat), an
  optional ``degrade`` hook maps the job to a cheaper equivalent (the
  sweep layer steps batched → measure → full) before giving up with
  :class:`ShardFailure`;
* **resume** — with ``journal=<path>``, every completed shard is
  appended to a jsonl journal keyed by a fingerprint of its job; a
  re-run with the same jobs preloads the completed shards and
  re-executes only the missing ones. The journal is guarded by an
  ``O_EXCL`` pid lockfile (stale locks from dead owners are taken
  over) and removed on success;
* **no orphans** — children run a parent-death watchdog thread
  (``os._exit`` the moment the parent vanishes), and the supervisor's
  ``finally`` terminates and joins every live child, so neither a
  parent ``KeyboardInterrupt`` nor a parent kill leaks processes or a
  stale journal lock.

``chaos={shard_index: "kill" | "hang"}`` injects those two failures
into a shard's *first* attempt — the test hook that proves the loop
above actually heals (tests/test_selfhealing_pool.py and the
``fig_faults --chaos`` gate).

Results come back as a list in job order regardless of completion
order, so sharded output is deterministic.
"""

from __future__ import annotations

import base64
import collections
import hashlib
import json
import multiprocessing
import multiprocessing.connection
import os
import pickle
import signal
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = ["ShardFailure", "job_fingerprint", "run_sharded"]

# how often the supervisor re-checks deadlines / backoff timers, and
# how often child watchdogs re-check the parent (seconds)
_POLL_SECONDS = 0.1
_WATCHDOG_SECONDS = 0.25


class ShardFailure(RuntimeError):
    """A shard exhausted every retry (and degradation, if any)."""

    def __init__(self, job_index: int, reason: str, detail: str = ""):
        self.job_index = job_index
        self.reason = reason
        self.detail = detail
        msg = f"shard {job_index} failed ({reason}) after all retries"
        if detail:
            msg += f":\n{detail}"
        super().__init__(msg)


def job_fingerprint(job) -> str:
    """Stable identity of a shard's work, for journal matching. ``repr``
    of the job tuple (registry spec strings, dataclass plans/configs) is
    deterministic across processes — unlike ``hash()``."""
    return hashlib.sha256(repr(job).encode()).hexdigest()[:16]


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def _acquire_journal_lock(lock_path: str) -> None:
    """``O_CREAT | O_EXCL`` pid lockfile. A lock whose owner pid is dead
    is stale (the owner was killed before its ``finally``) and is taken
    over instead of wedging every future resume."""
    while True:
        try:
            fd = os.open(lock_path, os.O_CREAT | os.O_EXCL | os.O_WRONLY,
                         0o644)
        except FileExistsError:
            try:
                with open(lock_path) as fh:
                    owner = int(fh.read().strip() or "0")
            except (OSError, ValueError):
                owner = 0
            if owner and owner != os.getpid() and _pid_alive(owner):
                raise RuntimeError(
                    f"sweep journal is locked by live pid {owner} "
                    f"({lock_path}); is another sweep writing it?")
            try:
                os.unlink(lock_path)     # stale: dead owner
            except FileNotFoundError:
                pass
            continue
        os.write(fd, str(os.getpid()).encode())
        os.close(fd)
        return


def _release_journal_lock(lock_path: str) -> None:
    try:
        os.unlink(lock_path)
    except FileNotFoundError:
        pass


def _load_journal(journal: str, jobs: Sequence) -> Dict[int, Any]:
    """Completed results from a previous interrupted run — only entries
    whose fingerprint still matches the job at that index (a changed
    matrix invalidates the cell, not the whole journal)."""
    done: Dict[int, Any] = {}
    if not os.path.exists(journal):
        return done
    prints = [job_fingerprint(j) for j in jobs]
    with open(journal) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
                idx = int(entry["job"])
                if 0 <= idx < len(jobs) and entry["fingerprint"] == prints[idx]:
                    done[idx] = pickle.loads(
                        base64.b64decode(entry["blob"]))
            except (KeyError, ValueError, pickle.UnpicklingError,
                    json.JSONDecodeError):
                continue     # torn tail of an interrupted append
    return done


def _append_journal(journal: str, idx: int, job, result) -> None:
    entry = {
        "job": idx,
        "fingerprint": job_fingerprint(job),
        "blob": base64.b64encode(pickle.dumps(result)).decode(),
    }
    with open(journal, "a") as fh:
        fh.write(json.dumps(entry) + "\n")
        fh.flush()
        os.fsync(fh.fileno())


def _watchdog() -> None:
    """Child-side parent-death watchdog: if the parent disappears (we
    get re-parented), exit immediately — no orphaned shard may keep
    burning CPU or holding the journal lock's owner alive."""
    parent = os.getppid()
    while True:
        time.sleep(_WATCHDOG_SECONDS)
        if os.getppid() != parent:
            os._exit(113)


def _shard_main(conn, worker_fn: Callable, job,
                chaos_action: Optional[str]) -> None:
    threading.Thread(target=_watchdog, daemon=True).start()
    if chaos_action == "kill":
        os.kill(os.getpid(), signal.SIGKILL)
    elif chaos_action == "hang":
        time.sleep(3600)
    try:
        result = worker_fn(job)
    except BaseException:
        try:
            conn.send(("error", traceback.format_exc()))
        finally:
            conn.close()
        return
    conn.send(("ok", result))
    conn.close()


class _Shard:
    __slots__ = ("index", "job", "attempt", "chaos", "proc", "conn",
                 "deadline", "ready_at")

    def __init__(self, index: int, job, chaos: Optional[str]):
        self.index = index
        self.job = job
        self.attempt = 0             # completed launch attempts
        self.chaos = chaos           # injected failure, first attempt only
        self.proc = None
        self.conn = None
        self.deadline: Optional[float] = None
        self.ready_at = 0.0          # backoff gate for the next launch


def run_sharded(jobs: Sequence, worker_fn: Callable, workers: int, *,
                timeout: Optional[float] = None,
                retries: int = 2,
                backoff: float = 0.5,
                journal: Optional[str] = None,
                chaos: Optional[Dict[int, str]] = None,
                degrade: Optional[Callable] = None,
                start_method: str = "fork",
                progress_cb: Optional[Callable[[Dict[str, Any]], None]] = None
                ) -> List[Any]:
    """Run ``worker_fn(job)`` for every job across ``workers`` processes
    with the supervision loop described in the module docstring. Returns
    results in job order.

    ``degrade(job, reason) -> job | None`` maps a failed job to a
    cheaper equivalent (attempts reset); ``None`` means no fallback
    left. ``chaos[i]`` ("kill" | "hang") is injected into shard ``i``'s
    first attempt. ``progress_cb`` receives one dict per supervision
    event ({"event": "done" | "retry" | "degrade" | "resumed", ...}).
    """
    if workers < 1:
        raise ValueError("workers must be >= 1")
    results: Dict[int, Any] = {}
    lock_path = (journal + ".lock") if journal else None
    if journal:
        _acquire_journal_lock(lock_path)
    try:
        if journal:
            for idx, res in _load_journal(journal, jobs).items():
                results[idx] = res
                if progress_cb is not None:
                    progress_cb({"event": "resumed", "job": idx})
        pending = collections.deque(
            _Shard(i, job, (chaos or {}).get(i))
            for i, job in enumerate(jobs) if i not in results)
        live: List[_Shard] = []
        ctx = multiprocessing.get_context(start_method)

        def launch(shard: _Shard) -> None:
            recv, send = ctx.Pipe(duplex=False)
            shard.proc = ctx.Process(
                target=_shard_main,
                args=(send, worker_fn, shard.job,
                      shard.chaos if shard.attempt == 0 else None),
                daemon=True)
            shard.proc.start()
            send.close()             # parent keeps only the read end
            shard.conn = recv
            shard.deadline = (time.monotonic() + timeout
                              if timeout is not None else None)
            live.append(shard)

        def reap(shard: _Shard) -> None:
            live.remove(shard)
            if shard.proc.is_alive():
                shard.proc.kill()
            shard.proc.join()
            shard.conn.close()
            shard.proc = shard.conn = shard.deadline = None

        def fail(shard: _Shard, reason: str, detail: str = "") -> None:
            """Retry -> degrade -> ShardFailure. Real worker exceptions
            skip the retry ladder — re-running identical code on an
            identical job only re-raises."""
            shard.attempt += 1
            retryable = reason in ("died", "timeout")
            if retryable and shard.attempt <= retries:
                delay = backoff * (2 ** (shard.attempt - 1))
                shard.ready_at = time.monotonic() + delay
                if progress_cb is not None:
                    progress_cb({"event": "retry", "job": shard.index,
                                 "reason": reason, "attempt": shard.attempt,
                                 "delay": delay})
                pending.append(shard)
                return
            if degrade is not None:
                downgraded = degrade(shard.job, reason)
                if downgraded is not None:
                    shard.job = downgraded
                    shard.attempt = 0
                    shard.chaos = None
                    shard.ready_at = 0.0
                    if progress_cb is not None:
                        progress_cb({"event": "degrade",
                                     "job": shard.index, "reason": reason})
                    pending.append(shard)
                    return
            raise ShardFailure(shard.index, reason, detail)

        def finish(shard: _Shard, result) -> None:
            results[shard.index] = result
            if journal:
                # fingerprint the job as RUN: a degraded shard's entry
                # must not satisfy a resume that asks for the original
                _append_journal(journal, shard.index, shard.job, result)
            if progress_cb is not None:
                progress_cb({"event": "done", "job": shard.index})

        while pending or live:
            now = time.monotonic()
            # launch every backoff-ready shard into free slots
            for _ in range(len(pending)):
                if len(live) >= workers:
                    break
                shard = pending.popleft()
                if shard.ready_at > now:
                    pending.append(shard)   # still backing off
                    continue
                launch(shard)
            if not live:
                time.sleep(_POLL_SECONDS)
                continue
            waitables = []
            for shard in live:
                waitables.append(shard.conn)
                waitables.append(shard.proc.sentinel)
            ready = multiprocessing.connection.wait(
                waitables, timeout=_POLL_SECONDS)
            ready_set = set(ready)
            for shard in list(live):
                if shard.conn in ready_set:
                    try:
                        outcome, payload = shard.conn.recv()
                    except (EOFError, OSError):
                        reap(shard)
                        fail(shard, "died")
                        continue
                    reap(shard)
                    if outcome == "ok":
                        finish(shard, payload)
                    else:
                        fail(shard, "error", payload)
                elif shard.proc.sentinel in ready_set:
                    # process exited without ever sending a result
                    reap(shard)
                    fail(shard, "died")
                elif (shard.deadline is not None
                      and time.monotonic() > shard.deadline):
                    reap(shard)
                    fail(shard, "timeout")
        ordered = [results[i] for i in range(len(jobs))]
        if journal:
            # complete: the journal has served its purpose
            try:
                os.unlink(journal)
            except FileNotFoundError:
                pass
        return ordered
    finally:
        # no orphans, no stale locks — whatever got us here
        for shard in list(locals().get("live") or []):
            if shard.proc is not None and shard.proc.is_alive():
                shard.proc.kill()
            if shard.proc is not None:
                shard.proc.join()
            if shard.conn is not None:
                shard.conn.close()
        if journal:
            _release_journal_lock(lock_path)
