"""Pluggable NVM cache-emulation backends.

``MemoryBackend`` (base.py) is the narrow protocol; three
implementations ship here:

* ``reference`` — :class:`ReferenceLRUBackend`, exact per-entry
  OrderedDict semantics; the oracle.
* ``vectorized`` — :class:`VectorizedBackend`, batched bitmap/stamp
  arrays; the default, byte-equivalent to the oracle and ~10-100x
  faster on range traffic.
* ``device`` — :class:`DeviceBackend`, the vectorized backend with
  large eviction-free span ops and queue-validity scans lifted onto
  jax-jit kernels; byte-equivalent to both, falls back to the
  vectorized host path without jax or under eviction pressure.

Select with ``NVMConfig(backend="...")`` or the ``REPRO_NVM_BACKEND``
environment variable. See README.md in this directory.
"""

from __future__ import annotations

from .base import (LineSurvival, MediaFault, MemoryBackend,
                   corrupt_image_words, select_survivors)
from .device import DeviceBackend
from .reference import ReferenceLRUBackend
from .vectorized import VectorizedBackend

__all__ = ["MemoryBackend", "LineSurvival", "select_survivors",
           "MediaFault", "corrupt_image_words",
           "ReferenceLRUBackend", "VectorizedBackend", "DeviceBackend",
           "BACKENDS", "make_backend"]

BACKENDS = {
    ReferenceLRUBackend.kind: ReferenceLRUBackend,
    VectorizedBackend.kind: VectorizedBackend,
    DeviceBackend.kind: DeviceBackend,
}


def make_backend(kind: str, store, cfg) -> MemoryBackend:
    try:
        cls = BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown NVM backend {kind!r}; available: {sorted(BACKENDS)}"
        ) from None
    return cls(store, cfg)
