"""ABFT matrix multiplication with crash consistence (§III.C, Fig. 6).

The original ABFT rank-k-update loop (Fig. 5) cannot establish restartable
state: C_f is overwritten every iteration and its checksums only hold at
iteration boundaries. The paper's extension (Fig. 6) decomposes it into

  loop 1 — submatrix multiplications:  C_s_temp = A_c[:, s-block] @ B_r[s-block, :]
           each C_s_temp carries full row+column checksums; only the
           checksums are flushed (one row + one column per chunk);
  loop 2 — row-blocked additions into C_temp whose *row* checksums are
           established once per k-row block, flushed, and never
           overwritten afterwards.

After a crash, the checksum relationships (Eq. 6) identify exactly which
C_s_temp chunks / C_temp row blocks are consistent in NVM; torn ones are
recomputed (or, when the damage is a single element, corrected in place).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import abft
from ..core.nvm import CrashEmulator, NVMConfig
from ..core.regions import PersistentRegion
from ..core.versioned import FlushedCounter

__all__ = ["ABFTMatmul", "MMRunResult"]


@dataclasses.dataclass
class MMRunResult:
    C: np.ndarray                      # the (n, n) result (checksums stripped)
    crashed_in: Optional[str]          # None | "loop1" | "loop2"
    chunks_lost: int                   # inconsistent chunks / row-blocks
    corrected_elements: int            # fixed via checksums w/o recompute
    detect_seconds: float
    resume_seconds: float
    avg_chunk_seconds: float
    modeled_overhead_seconds: float
    max_error: float                   # vs numpy oracle


class ABFTMatmul:
    """C = A @ B with ABFT checksums and ADCC over the crash emulator."""

    def __init__(self, A: np.ndarray, B: np.ndarray, k: int,
                 cfg: Optional[NVMConfig] = None):
        n = A.shape[0]
        assert A.shape == (n, n) and B.shape == (n, n), "square matrices"
        assert n % k == 0, "contraction dim must be divisible by rank k"
        self.n, self.k = n, k
        self.nchunks = n // k
        self.A, self.B = np.asarray(A, np.float64), np.asarray(B, np.float64)
        self.Ac = abft.encode_cols(self.A)     # (n+1, n)
        self.Br = abft.encode_rows(self.B)     # (n, n+1)
        self.emu = CrashEmulator(cfg or NVMConfig())
        # inputs in NVM (read-mostly, coarse sectors), persisted up-front
        self._rAc = self.emu.alloc("Ac", self.Ac.shape, np.float64,
                                   init=self.Ac, sector_lines=16)
        self._rBr = self.emu.alloc("Br", self.Br.shape, np.float64,
                                   init=self.Br, sector_lines=16)
        self._rAc.flush(); self._rBr.flush()
        # per-chunk temporaries, each (n+1, n+1) with full checksums
        self.C_s: List[PersistentRegion] = [
            self.emu.alloc(f"C_s{s}", (n + 1, n + 1), np.float64, sector_lines=8)
            for s in range(self.nchunks)
        ]
        # accumulation target with row checksums
        self.C_temp = self.emu.alloc("C_temp", (n + 1, n + 1), np.float64,
                                     sector_lines=8)
        self.counter = FlushedCounter(self.emu, "mm_iter")
        # row-block decomposition of loop 2 over the n+1 rows
        self.row_blocks: List[Tuple[int, int]] = []
        r0 = 0
        while r0 < n + 1:
            self.row_blocks.append((r0, min(r0 + k, n + 1)))
            r0 = self.row_blocks[-1][1]

    # -- the two loops ------------------------------------------------------
    def _loop1_chunk(self, s: int) -> None:
        """C_s_temp = Ac[:, s*k:(s+1)*k] @ Br[s*k:(s+1)*k, :] + flush its
        checksum row and column."""
        self.counter.set(s)  # which chunk we are in (one line flush)
        k, n = self.k, self.n
        self.emu.read("Ac", 0, self.Ac.size)                 # stream inputs
        self.emu.read("Br", s * k * (n + 1), (s + 1) * k * (n + 1))
        block = self.Ac[:, s * k:(s + 1) * k] @ self.Br[s * k:(s + 1) * k, :]
        reg = self.C_s[s]
        reg[...] = block
        # flush row checksums (last column) and column checksums (last row):
        # the last row is contiguous; the last column is flushed per row
        # block to respect row-major line spans.
        reg.flush((n, slice(None)))                    # checksum row
        for (lo, hi) in self.row_blocks:               # checksum column cells
            for i in range(lo, min(hi, n)):
                reg.flush((i, slice(n, n + 1)))

    def _loop2_block(self, bi: int) -> None:
        """C_temp[rows] = sum_s C_s[rows]; flush the block's row checksums."""
        self.counter.set(self.nchunks + bi)
        lo, hi = self.row_blocks[bi]
        acc = np.zeros((hi - lo, self.n + 1))
        for s in range(self.nchunks):
            self.emu.read(f"C_s{s}", lo * (self.n + 1), hi * (self.n + 1))
            acc += self.C_s[s].view[lo:hi, :]
        self.C_temp[lo:hi, :] = acc
        for i in range(lo, hi):                        # row checksum cells
            self.C_temp.flush((i, slice(self.n, self.n + 1)))

    # -- driver ---------------------------------------------------------------
    def run(self, crash_after: Optional[Tuple[str, int]] = None) -> MMRunResult:
        """Run the two-loop ABFT MM. ``crash_after=("loop1", s)`` crashes
        right after chunk s of loop 1 completes (paper's crash test 1);
        ``("loop2", b)`` after row-block b of loop 2 (crash test 2)."""
        t0 = time.perf_counter()
        crashed_in = None
        chunks_lost = 0
        corrected = 0
        detect_s = 0.0
        resume_chunks = 0

        s = 0
        while s < self.nchunks:
            self._loop1_chunk(s)
            if crash_after == ("loop1", s):
                crashed_in = "loop1"
                break
            s += 1
        loop1_done = s + (1 if crashed_in else 0)
        elapsed1 = time.perf_counter() - t0
        avg_chunk = elapsed1 / max(1, loop1_done)

        if crashed_in == "loop1":
            self.emu.crash()
            bad, corrected, detect_s = self._recover_loop1()
            chunks_lost = len(bad)
            for sb in bad:                     # recompute torn chunks
                self._loop1_chunk(sb)
            resume_chunks = len(bad)
            for s2 in range(loop1_done, self.nchunks):   # finish loop 1
                self._loop1_chunk(s2)

        # ---- loop 2 -----------------------------------------------------------
        t1 = time.perf_counter()
        b = 0
        while b < len(self.row_blocks):
            self._loop2_block(b)
            if crash_after == ("loop2", b) and crashed_in is None:
                crashed_in = "loop2"
                break
            b += 1
        blocks_done = b + (1 if crashed_in == "loop2" else 0)
        elapsed2 = time.perf_counter() - t1
        avg_block = elapsed2 / max(1, blocks_done)

        if crashed_in == "loop2":
            self.emu.crash()
            # loop-2 recomputation consumes the C_s chunks, whose *data*
            # relied on cache eviction — verify their checksums first and
            # recompute any chunk that had not fully reached NVM.
            bad_chunks, corrected, d1 = self._recover_loop1()
            for sb in bad_chunks:
                self._loop1_chunk(sb)
            bad_blocks, d2 = self._recover_loop2(blocks_done)
            detect_s = d1 + d2
            chunks_lost = len(bad_blocks)
            for bb in bad_blocks:
                self._loop2_block(bb)
            resume_chunks = len(bad_blocks)
            for b2 in range(blocks_done, len(self.row_blocks)):
                self._loop2_block(b2)
            avg_chunk = avg_block

        Cf = self.C_temp.view.copy()
        C = abft.strip(Cf)
        oracle = self.A @ self.B
        max_err = float(np.max(np.abs(C - oracle)))
        return MMRunResult(
            C=C, crashed_in=crashed_in, chunks_lost=chunks_lost,
            corrected_elements=corrected, detect_seconds=detect_s,
            resume_seconds=avg_chunk * resume_chunks, avg_chunk_seconds=avg_chunk,
            modeled_overhead_seconds=self.emu.modeled_seconds(), max_error=max_err,
        )

    # -- recovery ---------------------------------------------------------------
    def _recover_loop1(self) -> Tuple[List[int], int, float]:
        """Verify every C_s_temp in NVM via its checksums; single-element
        damage is corrected in place, torn chunks are reported for
        recomputation. Returns (bad chunk ids, corrected count, seconds)."""
        bad: List[int] = []
        corrected = 0
        nbytes = 0
        upper = self.counter.nvm_value()  # chunks beyond this were never run
        for s in range(min(upper + 1, self.nchunks)):
            view = self.C_s[s].nvm
            nbytes += view.nbytes
            # an all-zero image means *nothing* of a started chunk reached
            # NVM — checksums hold trivially but the chunk is lost
            if np.any(view != 0) and abft.verify(view, rtol=1e-9, atol=1e-6):
                # consistent in NVM: reload it as truth
                self.C_s[s][...] = view
                continue
            fixed, nfix = abft.correct_single_error(view, rtol=1e-9, atol=1e-6)
            if fixed is not None:
                self.C_s[s][...] = fixed
                corrected += nfix
            else:
                bad.append(s)
        return bad, corrected, nbytes / self.emu.cfg.read_bw

    def _recover_loop2(self, blocks_started: int) -> Tuple[List[int], float]:
        """Row checksums of C_temp decide which row blocks are consistent."""
        view = self.C_temp.nvm
        n = self.n
        row_resid = view[:, n] - view[:, :n].sum(axis=1)
        scale = max(float(np.max(np.abs(view))), 1.0)
        tol = 1e-6 + 1e-9 * scale
        bad: List[int] = []
        for bi, (lo, hi) in enumerate(self.row_blocks[:blocks_started]):
            rows = row_resid[lo:hi]
            # all-zero row blocks of a *started* block are fully lost
            # (checksum relations hold trivially on zeros)
            if np.any(np.abs(rows) > tol) or not np.any(view[lo:hi, :] != 0):
                bad.append(bi)
            else:
                self.C_temp[lo:hi, :] = view[lo:hi, :]
        # (C_s chunk integrity is re-established by _recover_loop1 before
        # this runs — see run(); reloading them here would clobber chunks
        # that were just recomputed into truth.)
        return bad, view.nbytes / self.emu.cfg.read_bw
