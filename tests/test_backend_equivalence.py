"""Backend equivalence: VectorizedBackend must match ReferenceLRUBackend
byte-for-byte — post-crash NVM images, traffic stats, occupancy, and
dirty sets — on randomized read/write/flush/drain/crash traces, for both
``lru`` and ``fifo`` replacement.

The trace generator leans on the regimes where the two implementations
can diverge: caches a few lines big (constant eviction pressure, the
intra-op dynamic-miss interleaving), sector weights > 1, partial last
entries, multi-region interleaving, and spans from single elements to
whole regions. Deterministic seeds, no hypothesis dependency.

The snapshot/restore tests extend the same randomized machinery to the
fork protocol: a suffix trace replayed after ``restore()`` must land in
a state bit-identical (traffic stats incl. modeled seconds, NVM images,
dirty sets, truth) to a from-scratch replay of prefix+suffix — on all
backends, across repeated restores of the same snapshot.

The ``device`` backend (jax-jit bulk transitions) is held to the same
oracle: with ``MIN_DEVICE_ENTRIES`` forced to 1 every eviction-free
span op takes the device kernels, and the traces' tiny caches keep the
speculative-launch/host-fallback boundary under constant pressure.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.backends import LineSurvival, MediaFault
from repro.core.nvm import CrashEmulator, NVMConfig


@pytest.fixture
def device_hot(monkeypatch):
    """Route every eviction-free span op through the device kernels
    regardless of size (the padded jit path compiles to log-many
    shapes, so this stays fast)."""
    from repro.core.backends.device import DeviceBackend
    monkeypatch.setattr(DeviceBackend, "MIN_DEVICE_ENTRIES", 1)


def _make_pair(rng, replacement, kinds=("reference", "vectorized")):
    """Two emulators of the given backend kinds with identical geometry
    and identical randomized regions."""
    cache_lines = int(rng.integers(1, 10))
    line_bytes = int(rng.choice([32, 64]))
    cfg = dict(cache_bytes=cache_lines * line_bytes, line_bytes=line_bytes,
               replacement=replacement)
    ref = CrashEmulator(NVMConfig(backend=kinds[0], **cfg))
    vec = CrashEmulator(NVMConfig(backend=kinds[1], **cfg))
    regions = []
    for i in range(int(rng.integers(2, 5))):
        n = int(rng.integers(1, 600))
        dtype = [np.float64, np.int32, np.int64][int(rng.integers(0, 3))]
        sector = int(rng.choice([1, 1, 2, 4]))
        name = f"r{i}"
        r_ref = ref.alloc(name, (n,), dtype, sector_lines=sector)
        r_vec = vec.alloc(name, (n,), dtype, sector_lines=sector)
        regions.append((name, n, dtype, r_ref, r_vec))
    return ref, vec, regions


def _assert_same(ref: CrashEmulator, vec: CrashEmulator, regions, ctx: str):
    s_ref, s_vec = ref.stats, vec.stats
    for field in dataclasses.fields(s_ref):
        a = getattr(s_ref, field.name)
        b = getattr(s_vec, field.name)
        assert a == b, f"{ctx}: stats.{field.name}: ref={a} vec={b}"
    assert ref.backend.occupancy_lines == vec.backend.occupancy_lines, ctx
    for name, _, _, _, _ in regions:
        assert np.array_equal(ref.store.image[name], vec.store.image[name]), \
            f"{ctx}: NVM image of {name!r} differs"
        assert np.array_equal(ref.backend.dirty_entries(name),
                              vec.backend.dirty_entries(name)), \
            f"{ctx}: dirty set of {name!r} differs"


def _run_trace(seed: int, replacement: str, n_ops: int = 120,
               kinds=("reference", "vectorized")) -> None:
    rng = np.random.default_rng(seed)
    ref, vec, regions = _make_pair(rng, replacement, kinds)
    for step in range(n_ops):
        name, n, dtype, r_ref, r_vec = \
            regions[int(rng.integers(0, len(regions)))]
        op = rng.random()
        ctx = f"seed={seed} {replacement} step={step} region={name}"
        if op < 0.45:  # write a random span
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            val = rng.integers(0, 1000, size=hi - lo).astype(dtype)
            r_ref[lo:hi] = val
            r_vec[lo:hi] = val
        elif op < 0.75:  # read a random span
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            a = r_ref[lo:hi]
            b = r_vec[lo:hi]
            assert np.array_equal(a, b), ctx
        elif op < 0.90:  # flush a span or everything
            if rng.random() < 0.5:
                r_ref.flush()
                r_vec.flush()
            else:
                lo = int(rng.integers(0, n))
                hi = int(rng.integers(lo + 1, n + 1))
                r_ref.flush(slice(lo, hi))
                r_vec.flush(slice(lo, hi))
        elif op < 0.96:  # crash: both lose the same bytes
            lost_ref = ref.crash()
            lost_vec = vec.crash()
            assert lost_ref == lost_vec, ctx
            for nm, _, _, a, b in regions:
                assert np.array_equal(a.view, b.view), f"{ctx}: {nm} post-crash"
        else:  # drain (now stats-visible: evictions counted)
            ref.drain()
            vec.drain()
        _assert_same(ref, vec, regions, ctx)
    ref.drain()
    vec.drain()
    _assert_same(ref, vec, regions, f"seed={seed} {replacement} final-drain")


@pytest.mark.parametrize("replacement", ["lru", "fifo"])
@pytest.mark.parametrize("seed", range(25))
def test_randomized_trace_equivalence(seed, replacement):
    _run_trace(seed, replacement)


@pytest.mark.parametrize("replacement", ["lru", "fifo"])
@pytest.mark.parametrize("seed", range(12))
def test_randomized_trace_device_equivalence(seed, replacement, device_hot):
    """DeviceBackend vs VectorizedBackend on the same oracle traces:
    every eviction-free op takes the jit kernels, every op under
    pressure takes the host fallback, and the states must never
    diverge at the boundary."""
    _run_trace(seed, replacement, kinds=("vectorized", "device"))


@pytest.mark.parametrize("granularity", ["line", "word"])
@pytest.mark.parametrize("seed", range(6))
def test_device_survival_crashes_equivalent(seed, granularity, device_hot):
    """Torn (partial-survival) crashes at line and word granularity
    leave vectorized and device backends byte-identical: survivor
    selection reads the dirty queue and stamps the device path wrote."""
    rng = np.random.default_rng(7000 + seed)
    vec, dev, regions = _make_pair(rng, ("lru", "fifo")[seed % 2],
                                   kinds=("vectorized", "device"))
    for step in range(60):
        name, n, dtype, r_vec, r_dev = \
            regions[int(rng.integers(0, len(regions)))]
        ctx = f"seed={seed} {granularity} step={step} region={name}"
        op = rng.random()
        if op < 0.6:
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            val = rng.integers(0, 1000, size=hi - lo).astype(dtype)
            r_vec[lo:hi] = val
            r_dev[lo:hi] = val
        elif op < 0.8:
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            assert np.array_equal(r_vec[lo:hi], r_dev[lo:hi]), ctx
        else:
            survival = LineSurvival(
                fraction=float(rng.choice([0.0, 0.25, 0.5, 0.75, 1.0])),
                seed=int(rng.integers(0, 1 << 16)),
                mode=str(rng.choice(["random", "eviction"])),
                granularity=granularity)
            lost_vec = vec.crash(survival)
            lost_dev = dev.crash(survival)
            assert lost_vec == lost_dev, (ctx, survival)
            for nm, _, _, a, b in regions:
                assert np.array_equal(a.view, b.view), f"{ctx}: {nm}"
        _assert_same(vec, dev, regions, ctx)


def test_device_media_fault_byte_identical(device_hot, monkeypatch):
    """Same MediaFault spec, same corrupted post-crash bytes, whether
    the forward pass ran on the vectorized host path or the device
    kernels."""
    views = {}
    for backend in ("vectorized", "device"):
        monkeypatch.setenv("REPRO_NVM_BACKEND", backend)
        emu = CrashEmulator(NVMConfig(cache_bytes=256, line_bytes=64))
        assert emu.backend.kind == backend
        r = emu.alloc("x", (64,))
        r[...] = np.arange(64.0)
        r.flush()
        emu.crash()
        spans = emu.inject_media_fault(MediaFault(words=5, seed=3))
        views[backend] = (spans, np.array(r.view))
    vec_spans, vec_view = views["vectorized"]
    dev_spans, dev_view = views["device"]
    assert vec_spans == dev_spans
    assert np.array_equal(vec_view, dev_view)


# ---------------------------------------------------------------------------
# snapshot/restore: fork protocol equivalence (PR 3)
# ---------------------------------------------------------------------------

def _make_trace(seed, n_ops=120):
    """Deterministic (cfg kwargs, region specs, op list) so the same
    trace can be replayed on any number of fresh or restored emulators.
    Ops mirror the randomized-equivalence mix, minus reads' return-value
    checks (truth equality is part of the state fingerprint)."""
    rng = np.random.default_rng(seed)
    cache_lines = int(rng.integers(1, 10))
    line_bytes = int(rng.choice([32, 64]))
    cfg = dict(cache_bytes=cache_lines * line_bytes, line_bytes=line_bytes,
               replacement=("lru", "fifo")[seed % 2])
    specs = []
    for i in range(int(rng.integers(2, 5))):
        n = int(rng.integers(1, 600))
        dtype = [np.float64, np.int32, np.int64][int(rng.integers(0, 3))]
        sector = int(rng.choice([1, 1, 2, 4]))
        specs.append((f"r{i}", n, dtype, sector))
    ops = []
    for _ in range(n_ops):
        name, n, dtype, _ = specs[int(rng.integers(0, len(specs)))]
        p = rng.random()
        if p < 0.45:
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            ops.append(("write", name, lo, hi,
                        rng.integers(0, 1000, size=hi - lo).astype(dtype)))
        elif p < 0.75:
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            ops.append(("read", name, lo, hi, None))
        elif p < 0.90:
            if rng.random() < 0.5:
                ops.append(("flush", name, 0, n, None))
            else:
                lo = int(rng.integers(0, n))
                hi = int(rng.integers(lo + 1, n + 1))
                ops.append(("flush", name, lo, hi, None))
        elif p < 0.96:
            ops.append(("crash", None, 0, 0, None))
        else:
            ops.append(("drain", None, 0, 0, None))
    return cfg, specs, ops


def _build(backend, cfg, specs):
    emu = CrashEmulator(NVMConfig(backend=backend, **cfg))
    regions = {name: emu.alloc(name, (n,), dtype, sector_lines=sector)
               for name, n, dtype, sector in specs}
    return emu, regions


def _apply(emu, regions, ops):
    for kind, name, lo, hi, val in ops:
        if kind == "write":
            regions[name][lo:hi] = val
        elif kind == "read":
            regions[name][lo:hi]
        elif kind == "flush":
            regions[name].flush(slice(lo, hi))
        elif kind == "crash":
            emu.crash()
        else:
            emu.drain()


def _state(emu, specs):
    """Full observable state: stats (incl. float modeled seconds), NVM
    images, truth arrays, dirty sets, occupancy, crashed flag."""
    return (dataclasses.astuple(emu.stats),
            tuple(emu.store.image[name].tobytes() for name, *_ in specs),
            tuple(emu.truth_flat(name).tobytes() for name, *_ in specs),
            tuple(emu.backend.dirty_entries(name).tobytes()
                  for name, *_ in specs),
            emu.backend.occupancy_lines,
            emu.crashed)


@pytest.mark.parametrize("backend", ["reference", "vectorized", "device"])
@pytest.mark.parametrize("seed", range(10))
def test_snapshot_restore_matches_scratch_replay(seed, backend, device_hot):
    cfg, specs, ops = _make_trace(seed)
    cut = len(ops) // 2
    emu, regions = _build(backend, cfg, specs)
    _apply(emu, regions, ops[:cut])
    snap = emu.snapshot()
    mid_state = _state(emu, specs)
    _apply(emu, regions, ops[cut:])
    end_state = _state(emu, specs)

    # restore rewinds to the capture point exactly
    emu.restore(snap)
    assert _state(emu, specs) == mid_state

    # a replayed suffix lands bit-identical to the straight-through run
    _apply(emu, regions, ops[cut:])
    assert _state(emu, specs) == end_state

    # ... and to a from-scratch replay of prefix+suffix
    emu2, regions2 = _build(backend, cfg, specs)
    _apply(emu2, regions2, ops)
    assert _state(emu2, specs) == end_state

    # snapshots are immutable: a second restore of the same snapshot
    # still reproduces the capture point
    emu.restore(snap)
    assert _state(emu, specs) == mid_state


@pytest.mark.parametrize("backend", ["reference", "vectorized", "device"])
def test_snapshot_capture_does_not_perturb_trace(backend, device_hot):
    """Interleaving snapshot() captures into a running trace must not
    change any observable state vs the same trace without captures."""
    cfg, specs, ops = _make_trace(3, n_ops=80)
    plain, plain_regions = _build(backend, cfg, specs)
    _apply(plain, plain_regions, ops)
    snapped, snapped_regions = _build(backend, cfg, specs)
    for i, op in enumerate(ops):
        _apply(snapped, snapped_regions, [op])
        if i % 7 == 0:
            snapped.snapshot()
    assert _state(snapped, specs) == _state(plain, specs)


def test_restore_into_wrong_emulator_raises():
    cfg, specs, ops = _make_trace(1, n_ops=10)
    emu, regions = _build("vectorized", cfg, specs)
    snap = emu.snapshot()
    other = CrashEmulator(NVMConfig(backend="vectorized", **cfg))
    other.alloc("unrelated", (8,))
    with pytest.raises(ValueError):
        other.restore(snap)


@pytest.mark.parametrize("other", ["vectorized", "device"])
@pytest.mark.parametrize("replacement", ["lru", "fifo"])
def test_streaming_cyclic_pressure(replacement, other, device_hot):
    """Cyclic full-range writes over a region 2x the cache: every op
    evicts not-yet-touched entries of its own range (the dynamic-miss
    path), which is exactly where a batched implementation can diverge
    (and where the device backend must decline its speculative launch)."""
    cfg = dict(cache_bytes=4 * 64, line_bytes=64, replacement=replacement)
    ref = CrashEmulator(NVMConfig(backend="reference", **cfg))
    vec = CrashEmulator(NVMConfig(backend=other, **cfg))
    n = 8 * 8  # 8 lines of float64
    r_ref = ref.alloc("x", (n,))
    r_vec = vec.alloc("x", (n,))
    regions = [("x", n, np.float64, r_ref, r_vec)]
    for sweep in range(6):
        val = np.arange(n, dtype=np.float64) + 100 * sweep
        r_ref[...] = val
        r_vec[...] = val
        _assert_same(ref, vec, regions, f"sweep={sweep}")
    ref.crash()
    vec.crash()
    _assert_same(ref, vec, regions, "post-crash")
    assert np.array_equal(r_ref.view, r_vec.view)


@pytest.mark.parametrize("other", ["vectorized", "device"])
@pytest.mark.parametrize("replacement", ["lru", "fifo"])
def test_single_entry_larger_than_cache(replacement, other, device_hot):
    """A sector entry heavier than the whole cache: only the newest
    entry stays resident, everything else must be written back."""
    cfg = dict(cache_bytes=2 * 64, line_bytes=64, replacement=replacement)
    ref = CrashEmulator(NVMConfig(backend="reference", **cfg))
    vec = CrashEmulator(NVMConfig(backend=other, **cfg))
    n = 8 * 16
    r_ref = ref.alloc("big", (n,), sector_lines=4)
    r_vec = vec.alloc("big", (n,), sector_lines=4)
    regions = [("big", n, np.float64, r_ref, r_vec)]
    val = np.arange(n, dtype=np.float64)
    r_ref[...] = val
    r_vec[...] = val
    _assert_same(ref, vec, regions, "oversized-entry write")
    ref.crash()
    vec.crash()
    _assert_same(ref, vec, regions, "oversized-entry post-crash")
