"""Emulator throughput microbenchmark: reference vs vectorized vs
device backend.

Replays one deterministic mixed read/write/flush trace over a large
region (default: 1M float64 elements, cache sized at half the region so
there is real eviction pressure) against all three backends and reports
emulator ops/sec, touched elements/sec, and the speedups over the
reference oracle. Also cross-checks that every backend ends with a
byte-identical NVM image and identical traffic stats — a whole-trace
equivalence run at benchmark scale. (Under eviction pressure the device
backend legitimately falls back to the vectorized host path on most
ops; its streaming-regime win is measured by the
``device_prefix_speedup`` block in scenarios_sweep.)

Results land in ``benchmarks/artifacts/BENCH_emulator.json``.

Run: ``PYTHONPATH=src python -m benchmarks.emu_bench``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import List, Tuple

import numpy as np

from repro.core.nvm import CrashEmulator, NVMConfig

from .common import ART

REGION = "data"


def make_trace(n_elems: int, n_ops: int, seed: int
               ) -> List[Tuple[str, int, int]]:
    """(op, lo, hi) tuples: writes/reads dominate, flushes interleave."""
    rng = np.random.default_rng(seed)
    ops: List[Tuple[str, int, int]] = []
    for _ in range(n_ops):
        u = rng.random()
        span = int(rng.integers(2048, 16384))
        lo = int(rng.integers(0, max(1, n_elems - span)))
        hi = min(n_elems, lo + span)
        if u < 0.50:
            ops.append(("write", lo, hi))
        elif u < 0.80:
            ops.append(("read", lo, hi))
        elif u < 0.95:
            ops.append(("flush", lo, hi))
        else:
            ops.append(("flush", 0, n_elems))
    return ops


def run_backend(backend: str, n_elems: int, cache_bytes: int,
                trace, replacement: str):
    emu = CrashEmulator(NVMConfig(backend=backend, cache_bytes=cache_bytes,
                                  replacement=replacement))
    region = emu.alloc(REGION, (n_elems,), np.float64)
    region.view[:] = np.arange(n_elems, dtype=np.float64)  # truth, uncharged
    t0 = time.perf_counter()
    for op, lo, hi in trace:
        if op == "write":
            emu.write(REGION, lo, hi)
        elif op == "read":
            emu.read(REGION, lo, hi)
        else:
            emu.flush(REGION, lo, hi)
    emu.drain()
    elapsed = time.perf_counter() - t0
    return emu, elapsed


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--elements", type=int, default=1_000_000,
                    help="region size in float64 elements")
    ap.add_argument("--ops", type=int, default=2_000,
                    help="trace length in emulator operations")
    ap.add_argument("--cache-frac", type=float, default=0.5,
                    help="cache capacity as a fraction of the region bytes")
    ap.add_argument("--replacement", default="lru", choices=["lru", "fifo"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cache_bytes = max(64, int(args.elements * 8 * args.cache_frac))
    trace = make_trace(args.elements, args.ops, args.seed)
    touched = sum(hi - lo for _, lo, hi in trace)

    results = {}
    emus = {}
    for backend in ("reference", "vectorized", "device"):
        emu, elapsed = run_backend(backend, args.elements, cache_bytes,
                                   trace, args.replacement)
        emus[backend] = emu
        results[backend] = {
            "seconds": elapsed,
            "ops_per_sec": args.ops / elapsed,
            "elements_per_sec": touched / elapsed,
        }
        print(f"{backend:>11}: {elapsed:8.3f} s   "
              f"{results[backend]['ops_per_sec']:12.1f} ops/s   "
              f"{results[backend]['elements_per_sec']:.3g} elem/s")

    ref = emus["reference"]
    images_equal = all(
        bool(np.array_equal(ref.store.image[REGION],
                            emus[b].store.image[REGION]))
        for b in ("vectorized", "device"))
    stats_equal = all(
        dataclasses.asdict(ref.stats) == dataclasses.asdict(emus[b].stats)
        for b in ("vectorized", "device"))
    speedup = results["vectorized"]["ops_per_sec"] / \
        results["reference"]["ops_per_sec"]
    device_speedup = results["device"]["ops_per_sec"] / \
        results["reference"]["ops_per_sec"]
    device_vs_vectorized = results["device"]["ops_per_sec"] / \
        results["vectorized"]["ops_per_sec"]
    print(f"   vectorized speedup: {speedup:.1f}x   "
          f"device speedup: {device_speedup:.1f}x "
          f"({device_vs_vectorized:.2f}x vs vectorized)   "
          f"images_equal={images_equal} stats_equal={stats_equal}")

    payload = {
        "config": {
            "elements": args.elements, "ops": args.ops,
            "cache_bytes": cache_bytes, "replacement": args.replacement,
            "seed": args.seed, "touched_elements": touched,
        },
        "backends": results,
        "speedup": speedup,
        "device_speedup": device_speedup,
        "device_vs_vectorized": device_vs_vectorized,
        "images_equal": images_equal,
        "stats_equal": stats_equal,
    }
    os.makedirs(ART, exist_ok=True)
    out = os.path.join(ART, "BENCH_emulator.json")
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"wrote {out}")
    if not (images_equal and stats_equal):
        raise SystemExit("backend divergence at benchmark scale")


if __name__ == "__main__":
    main()
