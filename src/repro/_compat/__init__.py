"""Compatibility shims for optional third-party packages.

The runtime container pins a jax toolchain but does not ship every dev
dependency; modules here provide minimal stand-ins so the test suite
stays runnable (see hypothesis_shim).
"""
