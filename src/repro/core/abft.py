"""ABFT checksum algebra (paper §III.C, Eqs. 3-6).

Column-checksum encoding  A_c = [A ; v^T A]   (extra last row)
Row-checksum encoding     B_r = [B , B w]     (extra last column)
Full-checksum product     C_f = A_c @ B_r  =  [AB, ABw ; v^T AB, v^T A B w]

with v = w = ones. The checksum relationships (Eq. 6)

    C_f[m, j]  = sum_i C_f[i, j]      (column sums match the extra row)
    C_f[i, n]  = sum_j C_f[i, j]      (row sums match the extra column)

hold for any matrix produced by valid computation; a crash that leaves a
tile half-updated breaks them. A *single* corrupted element sits at the
intersection of the one inconsistent row and one inconsistent column and
can be corrected from either checksum; torn whole rows are detectable
(and recomputable row-wise) via the row checksum.

Everything here works on both numpy and jax.numpy arrays (the module
dispatches on the input), so the crash-emulator algorithms and the
Pallas reference oracles share one implementation.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

try:  # jnp available in all supported environments; keep import soft for tools
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None

__all__ = [
    "encode_cols",
    "encode_rows",
    "encode_full",
    "strip",
    "verify",
    "residuals",
    "correct_single_error",
    "vector_checksum",
]


def _xp(a):
    if jnp is not None and not isinstance(a, np.ndarray):
        return jnp
    return np


def encode_cols(A):
    """A (m,k) -> A_c (m+1,k): append column-sum row (Eq. 3)."""
    xp = _xp(A)
    return xp.concatenate([A, xp.sum(A, axis=0, keepdims=True)], axis=0)


def encode_rows(B):
    """B (k,n) -> B_r (k,n+1): append row-sum column (Eq. 4)."""
    xp = _xp(B)
    return xp.concatenate([B, xp.sum(B, axis=1, keepdims=True)], axis=1)


def encode_full(C):
    """C (m,n) -> C_f (m+1,n+1) with both checksums (Eq. 5 layout)."""
    return encode_rows(encode_cols(C))


def strip(Cf):
    """Drop the checksum row+column."""
    return Cf[:-1, :-1]


def residuals(Cf) -> Tuple[np.ndarray, np.ndarray]:
    """(row_resid (m,), col_resid (n,)):

    row_resid[i] = Cf[i, -1] - sum_j Cf[i, :-1]   (row checksum error)
    col_resid[j] = Cf[-1, j] - sum_i Cf[:-1, j]   (column checksum error)

    Note both residual vectors *exclude* the checksum row/col themselves,
    i.e. they cover the data block of C_f.
    """
    xp = _xp(Cf)
    row = Cf[:-1, -1] - xp.sum(Cf[:-1, :-1], axis=1)
    col = Cf[-1, :-1] - xp.sum(Cf[:-1, :-1], axis=0)
    return row, col


def verify(Cf, rtol: float = 1e-8, atol: float = 1e-6) -> bool:
    """True iff both checksum relationships hold (within fp tolerance,
    scaled by the magnitude of the data block)."""
    xp = _xp(Cf)
    row, col = residuals(Cf)
    scale = xp.maximum(xp.max(xp.abs(Cf)), 1.0)
    tol = atol + rtol * scale
    ok = (xp.max(xp.abs(row)) <= tol) & (xp.max(xp.abs(col)) <= tol)
    return bool(ok)


def correct_single_error(Cf, rtol: float = 1e-8, atol: float = 1e-6):
    """Detect-and-correct for a single corrupted data element (numpy only;
    recovery runs on host). Returns (corrected copy, n_corrected) or
    (None, -1) if the corruption pattern is not single-error correctable.
    """
    Cf = np.asarray(Cf).copy()
    row, col = residuals(Cf)
    scale = max(float(np.max(np.abs(Cf))), 1.0)
    tol = atol + rtol * scale
    bad_rows = np.nonzero(np.abs(row) > tol)[0]
    bad_cols = np.nonzero(np.abs(col) > tol)[0]
    if len(bad_rows) == 0 and len(bad_cols) == 0:
        return Cf, 0
    if len(bad_rows) == 1 and len(bad_cols) == 1:
        i, j = int(bad_rows[0]), int(bad_cols[0])
        # both residuals must agree on the error magnitude
        if abs(row[i] - col[j]) <= 2 * tol:
            Cf[i, j] += row[i]
            return Cf, 1
    # a corrupted *checksum* element (data intact) shows as one bad row
    # XOR one bad col; rebuild it from the data
    if len(bad_rows) == 1 and len(bad_cols) == 0:
        i = int(bad_rows[0])
        Cf[i, -1] = np.sum(Cf[i, :-1])
        return Cf, 1
    if len(bad_cols) == 1 and len(bad_rows) == 0:
        j = int(bad_cols[0])
        Cf[-1, j] = np.sum(Cf[:-1, j])
        return Cf, 1
    return None, -1


def vector_checksum(x):
    """Scalar checksum of a vector/tensor: sum of all elements. Linear,
    so it can be maintained incrementally across linear updates — the
    property the ADCC training-state layer relies on."""
    xp = _xp(x)
    return xp.sum(x, dtype=xp.float64 if xp is np else None)
