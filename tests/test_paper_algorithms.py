"""Behaviour tests for the paper's three algorithms (§III.B-D)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms.cg import ADCC_CG, make_spd_system, plain_cg
from repro.algorithms.mm_abft import ABFTMatmul
from repro.algorithms.xsbench import ADCC_XSBench, XSBenchConfig
from repro.core import abft
from repro.core.nvm import NVMConfig


SMALL_CACHE = NVMConfig(cache_bytes=1 * 1024 * 1024)


class TestCG:
    def test_no_crash_matches_plain_cg(self):
        A, b = make_spd_system(2048, seed=3)
        res = ADCC_CG(A, b, iters=10, cfg=SMALL_CACHE).run()
        assert np.allclose(res.z, plain_cg(A, b, 10), atol=1e-10)

    def test_cg_converges(self):
        A, b = make_spd_system(1024, seed=4)
        from repro.algorithms.cg import _sym_matvec
        z = plain_cg(A, b, 60)
        assert np.linalg.norm(b - _sym_matvec(A, z)) < 1e-6 * np.linalg.norm(b)

    def test_large_problem_loses_one_iteration(self):
        A, b = make_spd_system(32768, seed=5)
        res = ADCC_CG(A, b, iters=12, cfg=SMALL_CACHE).run(crash_at_iter=10)
        assert res.restart_iter is not None and res.restart_iter >= 8
        assert res.iterations_lost <= 2
        assert np.allclose(res.z, plain_cg(A, b, 12), atol=1e-8)

    def test_small_problem_may_lose_everything_but_recovers(self):
        A, b = make_spd_system(512, seed=6)
        res = ADCC_CG(A, b, iters=12, cfg=SMALL_CACHE).run(crash_at_iter=10)
        # tiny working set: nothing evicted; must restart from scratch...
        assert res.restart_iter == -1
        # ...and still produce the right answer
        assert np.allclose(res.z, plain_cg(A, b, 12), atol=1e-8)

    def test_recovery_never_accepts_inconsistent_iteration(self):
        A, b = make_spd_system(16384, seed=7)
        cg = ADCC_CG(A, b, iters=10, cfg=SMALL_CACHE)
        res = cg.run(crash_at_iter=8)
        if res.restart_iter >= 0:
            data = {
                "p_next": cg.p.nvm_version(res.restart_iter + 1),
                "q_cur": cg.q.nvm_version(res.restart_iter),
                "r_next": cg.r.nvm_version(res.restart_iter + 1),
                "z_next": cg.z.nvm_version(res.restart_iter + 1),
            }
            # re-verify the chosen iteration satisfies both invariants
            from repro.core.invariants import (InvariantSet,
                                               OrthogonalityInvariant,
                                               ResidualInvariant)
            from repro.algorithms.cg import _sym_matvec
            inv = InvariantSet([
                OrthogonalityInvariant("p_next", "q_cur", tol=1e-7),
                ResidualInvariant("r_next", "z_next", b=b,
                                  matvec=lambda x: _sym_matvec(A, x), tol=1e-6),
            ])
            assert inv.holds(data)

    def test_counter_flush_overhead_is_tiny(self):
        A, b = make_spd_system(8192, seed=8)
        cg = ADCC_CG(A, b, iters=10, cfg=SMALL_CACHE, emulate_reads=False)
        res = cg.run()
        # ADCC mechanism = per-iteration counter-line flush; modeled cost
        # must be microscopic vs any per-iteration data copy
        per_iter_flush = 10 * (64 / SMALL_CACHE.write_bw + SMALL_CACHE.flush_latency)
        checkpoint_cost = 10 * 4 * b.nbytes / SMALL_CACHE.write_bw
        assert per_iter_flush < 0.01 * checkpoint_cost


class TestABFTChecksums:
    @settings(max_examples=30, deadline=None)
    @given(m=st.integers(2, 24), k=st.integers(2, 24), n=st.integers(2, 24))
    def test_encode_product_has_valid_checksums(self, m, k, n):
        rng = np.random.default_rng(m * 1000 + k * 100 + n)
        A = rng.uniform(-1, 1, (m, k))
        B = rng.uniform(-1, 1, (k, n))
        Cf = abft.encode_cols(A) @ abft.encode_rows(B)
        assert abft.verify(Cf)
        assert np.allclose(abft.strip(Cf), A @ B)

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(3, 16), i=st.integers(0, 15), j=st.integers(0, 15),
           delta=st.floats(0.5, 100, allow_nan=False))
    def test_single_error_correction(self, n, i, j, delta):
        i, j = i % n, j % n
        rng = np.random.default_rng(n)
        C = rng.uniform(-1, 1, (n, n))
        Cf = abft.encode_full(C)
        Cf_bad = Cf.copy()
        Cf_bad[i, j] += delta
        fixed, nfix = abft.correct_single_error(Cf_bad)
        assert nfix == 1
        assert np.allclose(fixed, Cf, atol=1e-9)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(4, 12))
    def test_corrupted_checksum_cell_rebuilt(self, n):
        rng = np.random.default_rng(n + 99)
        Cf = abft.encode_full(rng.uniform(-1, 1, (n, n)))
        Cf[2, -1] += 3.0  # damage a row-checksum cell, data intact
        fixed, nfix = abft.correct_single_error(Cf)
        assert nfix == 1 and abft.verify(fixed)

    def test_torn_row_not_single_correctable(self):
        rng = np.random.default_rng(0)
        Cf = abft.encode_full(rng.uniform(-1, 1, (8, 8)))
        Cf[3, 0:5] = 0.0  # torn write: many elements in one row
        fixed, nfix = abft.correct_single_error(Cf)
        assert fixed is None and nfix == -1

    def test_vector_checksum_linear(self):
        rng = np.random.default_rng(1)
        x, y = rng.normal(size=100), rng.normal(size=100)
        a, bb = 0.3, 1.7
        assert np.isclose(abft.vector_checksum(a * x + bb * y),
                          a * abft.vector_checksum(x) + bb * abft.vector_checksum(y))


class TestABFTMatmul:
    CFG = NVMConfig(cache_bytes=2 * 1024 * 1024)

    def _mats(self, n, seed=0):
        rng = np.random.default_rng(seed)
        return rng.uniform(-1, 1, (n, n)), rng.uniform(-1, 1, (n, n))

    def test_no_crash_correct(self):
        A, B = self._mats(256)
        res = ABFTMatmul(A, B, 64, self.CFG).run()
        assert res.max_error < 1e-10

    @pytest.mark.parametrize("loop,it", [("loop1", 2), ("loop2", 2)])
    def test_crash_recovery_correct(self, loop, it):
        A, B = self._mats(256, seed=3)
        res = ABFTMatmul(A, B, 64, self.CFG).run(crash_after=(loop, it))
        assert res.crashed_in == loop
        assert res.max_error < 1e-10
        assert res.chunks_lost >= 1  # the in-flight chunk cannot survive

    def test_large_matrix_loses_at_most_one_chunk(self):
        A, B = self._mats(512, seed=4)
        res = ABFTMatmul(A, B, 128, self.CFG).run(crash_after=("loop1", 2))
        assert res.chunks_lost <= 2
        assert res.max_error < 1e-9

    def test_checksum_flush_cheaper_than_checkpoint(self):
        """The paper's headline: flushing checksums ≪ copying C_f."""
        n, k = 256, 64
        A, B = self._mats(n, seed=5)
        mm = ABFTMatmul(A, B, k, self.CFG)
        base = mm.emu.modeled_seconds()
        mm._loop1_chunk(0)
        adcc_cost = mm.emu.modeled_seconds() - base
        ckpt_cost = (n + 1) * (n + 1) * 8 / self.CFG.write_bw
        # per-chunk ADCC cost (checksum flushes) must be well under a full
        # C_f copy; the eviction traffic is shared by both schemes
        flush_only = (2 * (n + 1) * 8) / self.CFG.write_bw * 16  # sector slack
        assert flush_only < ckpt_cost


class TestXSBench:
    CFG = XSBenchConfig(lookups=20_000, grid_points=8_000, n_nuclides=16)
    NVM = NVMConfig(cache_bytes=512 * 1024, replacement="fifo")

    def test_fractions_uniform_no_crash(self):
        res = ADCC_XSBench(self.CFG, self.NVM, policy="selective").run()
        assert res.max_fraction_spread() < 0.02
        assert res.counts.sum() == self.CFG.lookups

    def test_selective_flush_restart_bitwise_correct(self):
        ok = ADCC_XSBench(self.CFG, self.NVM, policy="selective").run()
        crashed = ADCC_XSBench(self.CFG, self.NVM, policy="selective").run(
            crash_at=self.CFG.lookups // 10)
        assert np.array_equal(crashed.counts, ok.counts)
        assert np.allclose(crashed.macro_xs, ok.macro_xs)

    def test_basic_restart_loses_counts(self):
        crashed = ADCC_XSBench(self.CFG, self.NVM, policy="basic").run(
            crash_at=self.CFG.lookups // 10)
        assert crashed.counts.sum() < self.CFG.lookups
        assert crashed.iterations_lost > 0

    def test_selective_bounds_loss_by_flush_interval(self):
        res = ADCC_XSBench(self.CFG, self.NVM, policy="selective").run(
            crash_at=self.CFG.lookups // 10)
        flush_every = max(1, int(self.CFG.lookups * self.CFG.flush_every_frac))
        assert res.iterations_lost <= flush_every

    def test_counter_rng_deterministic(self):
        r1 = ADCC_XSBench(self.CFG, self.NVM, policy="selective").run()
        r2 = ADCC_XSBench(self.CFG, self.NVM, policy="selective").run()
        assert np.array_equal(r1.counts, r2.counts)


class TestRecoveryEngine:
    """Property tests for the backward-scan engine itself."""

    @settings(max_examples=25, deadline=None)
    @given(newest=st.integers(0, 20), good_at=st.integers(-1, 20))
    def test_accepts_newest_consistent(self, newest, good_at):
        from repro.core.invariants import Invariant, CheckResult, InvariantSet
        from repro.core.recovery import backward_scan
        good_at = min(good_at, newest)

        class At(Invariant):
            name = "at"

            def __init__(self, j):
                self.j = j

            def check(self, data):
                ok = self.j <= good_at
                return CheckResult("at", ok, 0.0 if ok else 1.0)

        out = backward_scan(newest, 0, lambda j: {},
                            lambda j: InvariantSet([At(j)]))
        if good_at >= 0:
            assert out.restart_point == good_at
            assert out.candidates_tested == newest - good_at + 1
        else:
            assert out.restart_point == -1

    def test_detection_cost_accumulates(self):
        import numpy as np
        from repro.core.invariants import (Invariant, CheckResult,
                                           InvariantSet)
        from repro.core.recovery import backward_scan

        class Never(Invariant):
            def check(self, data):
                return CheckResult("never", False, 1.0)

        out = backward_scan(4, 0, lambda j: {"x": np.zeros(10)},
                            lambda j: InvariantSet([Never()]),
                            charge_read_seconds=lambda d: 1.0)
        assert out.detection_seconds == 5.0
        assert not out.found
