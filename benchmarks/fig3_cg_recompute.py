"""Paper Fig. 3: CG recomputation cost vs input problem size.

Crash at a fixed iteration; recomputation time (detect + resume),
normalized by the average per-iteration time, and the number of
iterations lost — small problems fit in cache and lose everything,
large problems lose ~1 iteration.
"""

from __future__ import annotations

from typing import List

from repro.algorithms.cg import ADCC_CG, make_spd_system
from repro.core.nvm import NVMConfig

from .common import Row, emit

SIZES = [2048, 8192, 32768, 131072]   # paper: classes S, W, A, B/C
ITERS = 16
CRASH_AT = 14
CACHE = NVMConfig(cache_bytes=2 * 1024 * 1024)


def run() -> List[Row]:
    rows = []
    for n in SIZES:
        A, b = make_spd_system(n, nnz_per_row=8, seed=n)
        cg = ADCC_CG(A, b, iters=ITERS, cfg=CACHE)
        res = cg.run(crash_at_iter=CRASH_AT)
        lost = res.iterations_lost
        norm = ((res.detect_seconds + res.resume_seconds)
                / max(res.avg_iter_seconds, 1e-12))
        rows.append(Row(f"fig3/cg_recompute/n={n}/iters_lost", lost,
                        f"restart_iter={res.restart_iter}"))
        rows.append(Row(f"fig3/cg_recompute/n={n}/normalized_recompute",
                        norm,
                        f"detect={res.detect_seconds:.4f}s "
                        f"resume={res.resume_seconds:.4f}s"))
    return rows


def main() -> None:
    emit(run(), save_as="fig3_cg_recompute.json")


if __name__ == "__main__":
    main()
