"""Scenario driver: one loop that runs any Workload under any
ConsistencyStrategy against any CrashPlan, and a batched sweep.

``run_scenario`` is the uniform experiment harness the paper's
per-algorithm drivers used to hand-roll: set up, step, optionally crash
(at a step boundary, or *torn* — inside the boundary, before the
strategy's persistence hook), recover through the strategy, resume, and
report a :class:`ScenarioResult` with overhead / recompute / correctness
/ traffic fields that mean the same thing in every cell.

``sweep`` expands a workloads × strategies × crash-plans matrix
(seeded ``random`` plans contribute one cell per sampled crash point),
runs every cell on the vectorized emulation backend, and optionally
writes the ``BENCH_scenarios.json`` artifact. Two execution engines:

  engine="fork"  (default) the prefix-sharing engine in
                 :mod:`repro.scenarios.sweep_engine`: each (workload,
                 strategy) pair runs forward ONCE, snapshots are
                 captured at the union of the plans' crash points, and
                 every cell forks from its snapshot — crash, recover,
                 run only the tail. O(tail) per cell.
  engine="rerun" the from-scratch baseline: every cell re-executes its
                 whole prefix on a fresh workload. O(full run) per
                 cell; kept as the oracle the fork engine must match
                 cell-for-cell (tests/benchmarks enforce it).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.nvm import NVMConfig
from .crashplan import CrashPlan, CrashPoint
from .strategies import ConsistencyStrategy, make_strategy
from .workloads import Workload, make_workload

__all__ = ["ScenarioResult", "run_scenario", "sweep", "DEFAULT_SWEEP_PLANS",
           "AVG_STEP_JITTER_FLOOR", "SWEEP_ENGINES", "WALL_CLOCK_FIELDS",
           "deterministic_cell_dict"]

# Below this measured mean step wall-time, per-step timing is dominated
# by timer resolution / interpreter jitter, so ``avg_step_seconds``
# falls back to the emulator's deterministic modeled per-step cost
# (which also makes fork- and rerun-engine results comparable bit for
# bit at smoke sizes).
AVG_STEP_JITTER_FLOOR = 1e-3

SWEEP_ENGINES = ("fork", "rerun")

# ScenarioResult fields derived from host wall-clock measurement.
# Everything else is deterministic — modeled seconds, traffic counts,
# recompute/restart bookkeeping, correctness — and must come out
# IDENTICAL from both sweep engines (tests + the sweep_timing
# benchmark's divergence gate enforce it). avg_step_seconds /
# resume_seconds are wall-derived only above AVG_STEP_JITTER_FLOOR,
# but whether the floor triggers is itself a wall-clock fact, so the
# engine-invariance contract excludes all three.
WALL_CLOCK_FIELDS = ("wall_seconds", "avg_step_seconds", "resume_seconds")


def deterministic_cell_dict(res: "ScenarioResult") -> Dict[str, Any]:
    """``to_json_dict`` minus :data:`WALL_CLOCK_FIELDS` — the payload on
    which fork- and rerun-engine sweeps must agree cell-for-cell."""
    d = res.to_json_dict()
    for f in WALL_CLOCK_FIELDS:
        d.pop(f)
    return d


@dataclasses.dataclass
class ScenarioResult:
    """Uniform per-cell outcome (JSON-serializable via ``to_json_dict``)."""

    workload: str
    workload_params: Dict[str, Any]
    strategy: str
    plan: str
    crash_step: Optional[int]
    torn: bool
    steps_total: int
    steps_done: int
    restart_point: Optional[int]     # newest surviving step; -1 => scratch
    resume_step: Optional[int]
    steps_lost: int
    steps_recomputed: int
    detect_seconds: float
    resume_seconds: float
    # mean seconds per pre-crash step of the phase the crash landed in:
    # measured wall-clock when the mean is >= AVG_STEP_JITTER_FLOOR,
    # otherwise the emulator's modeled per-step seconds (wall timing at
    # smoke sizes is pure jitter; the modeled cost is deterministic)
    avg_step_seconds: float
    overhead_seconds: float          # modeled mechanism cost (cost model)
    modeled_total_seconds: float     # emulator's total modeled seconds
    wall_seconds: float
    correct: bool
    metrics: Dict[str, float]
    traffic: Dict[str, int]
    info: Dict[str, Any] = dataclasses.field(default_factory=dict, repr=False)

    def to_json_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("info")
        return _jsonable(d)


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def _avg_step_seconds(wall_durs: Sequence[float],
                      modeled_durs: Sequence[float]) -> float:
    wall = sum(wall_durs) / max(1, len(wall_durs))
    if wall >= AVG_STEP_JITTER_FLOOR:
        return wall
    return sum(modeled_durs) / max(1, len(modeled_durs))


def _forward(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint
             ) -> Tuple[bool, List[float], List[float]]:
    """Run forward until completion or the crash point. Returns
    (crashed, per-step wall durations, per-step modeled-seconds deltas)
    — the modeled deltas are the deterministic counterpart the jitter
    floor falls back to. A torn crash's last entry covers only
    before_step+step (the persistence hook never ran)."""
    crash_step, torn = point.step, point.torn
    emu = wl.emu
    wall: List[float] = []
    modeled: List[float] = []
    crashed = False
    for i in range(wl.n_steps):
        ts = time.perf_counter()
        m0 = emu.modeled_seconds()
        strat.before_step(i)
        wl.step(i)
        if torn and crash_step == i:
            wall.append(time.perf_counter() - ts)
            modeled.append(emu.modeled_seconds() - m0)
            crashed = True
            break
        strat.after_step(i)
        wall.append(time.perf_counter() - ts)
        modeled.append(emu.modeled_seconds() - m0)
        if crash_step == i:
            crashed = True
            break
    return crashed, wall, modeled


def _finish(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint,
            plan_desc: str, recover: bool, crashed: bool,
            wall_durs: Sequence[float], modeled_durs: Sequence[float],
            t0: float) -> ScenarioResult:
    """Crash (if armed), recover, run the tail, finalize, and assemble
    the ScenarioResult. Shared verbatim by the rerun path (after its own
    forward pass) and the fork engine (after restoring a snapshot)."""
    crash_step, torn = point.step, point.torn
    emu = wl.emu
    n = wl.n_steps
    steps_run = (crash_step + 1) if crashed else n
    # normalize recompute against the phase the crash landed in (loop-2
    # block additions are much cheaper than loop-1 chunk multiplies)
    if crashed:
        phase_rng = next((rng for rng in wl.phases().values()
                          if crash_step in rng), range(n))
        idx = [j for j in phase_rng if j < len(wall_durs)]
        avg_step = _avg_step_seconds([wall_durs[j] for j in idx],
                                     [modeled_durs[j] for j in idx])
    else:
        avg_step = _avg_step_seconds(wall_durs, modeled_durs)

    restart: Optional[int] = None
    resume: Optional[int] = None
    lost = 0
    redo = 0
    detect_s = 0.0
    rec_info: Dict[str, Any] = {}
    steps_done = n

    if crashed:
        emu.crash()
        if recover:
            rec = strat.recover(crash_step, torn)
            restart, resume = rec.restart_point, rec.resume_step
            detect_s, redo = rec.detect_seconds, rec.redo_steps
            lost = rec.steps_lost if rec.steps_lost is not None else (
                crash_step - restart if restart >= 0 else crash_step + 1)
            rec_info = dict(rec.info)
            for j in range(rec.resume_step, n):
                strat.before_step(j)
                wl.step(j)
                strat.after_step(j)
        else:
            steps_done = crash_step + 1

    report = wl.finalize()
    profile = wl.step_cost_profile()
    interval = strat.interval * (profile.interval_steps
                                 if strat.wants_adcc else 1)
    events = steps_run // max(1, interval)
    overhead = events * strat.modeled_step_seconds(profile, emu.cfg)
    stats = emu.stats

    info = dict(report.info)
    info.update(rec_info)
    return ScenarioResult(
        workload=wl.name, workload_params=wl.params(),
        strategy=strat.name, plan=plan_desc,
        crash_step=crash_step, torn=torn,
        steps_total=n, steps_done=steps_done,
        restart_point=restart, resume_step=resume,
        steps_lost=lost, steps_recomputed=redo,
        detect_seconds=detect_s, resume_seconds=avg_step * redo,
        avg_step_seconds=avg_step,
        overhead_seconds=overhead,
        modeled_total_seconds=emu.modeled_seconds(),
        wall_seconds=time.perf_counter() - t0,
        correct=report.correct, metrics=dict(report.metrics),
        traffic={
            "nvm_bytes_written": stats.nvm_bytes_written,
            "nvm_bytes_read": stats.nvm_bytes_read,
            "lines_flushed": stats.lines_flushed,
            "lines_evicted": stats.lines_evicted,
        },
        info=info,
    )


def _run_point(wl: Workload, strat: ConsistencyStrategy, point: CrashPoint,
               plan_desc: str, recover: bool) -> ScenarioResult:
    t0 = time.perf_counter()
    crashed, wall, modeled = _forward(wl, strat, point)
    return _finish(wl, strat, point, plan_desc, recover, crashed,
                   wall, modeled, t0)


def run_scenario(workload, strategy, plan: Optional[CrashPlan] = None,
                 cfg: Optional[NVMConfig] = None, *,
                 recover: bool = True) -> ScenarioResult:
    """Run one scenario cell.

    workload: Workload | "name" | ("name", {params})
    strategy: ConsistencyStrategy | "name" | "name@interval"
    plan:     CrashPlan (default: no_crash). Must resolve to a single
              crash point — use :func:`sweep` for batch (``random``) plans.
    """
    plan = plan or CrashPlan.no_crash()
    wl = make_workload(workload)
    strat = make_strategy(strategy)
    if wl.mode is None:
        wl.setup(cfg, "adcc" if strat.wants_adcc else "plain")
    elif strat.wants_adcc and wl.mode != "adcc":
        raise ValueError(f"workload set up in mode {wl.mode!r} cannot run "
                         f"the {strat.name!r} strategy")
    strat.attach(wl)
    points = plan.resolve(wl)
    if len(points) != 1:
        raise ValueError(
            f"plan {plan.describe()!r} resolves to {len(points)} crash "
            f"points; run_scenario takes exactly one (use sweep())")
    return _run_point(wl, strat, points[0], plan.describe(), recover)


DEFAULT_SWEEP_PLANS: Sequence[CrashPlan] = (
    CrashPlan.no_crash(),
    CrashPlan.at_fraction(0.3),
    CrashPlan.at_fraction(0.75, torn=True),
    CrashPlan.random(count=1, seed=0),
)


def sweep(workloads: Sequence = ("cg", "mm", "xsbench"),
          strategies: Sequence = ("none", "adcc", "undo_log",
                                  "checkpoint_hdd", "checkpoint_nvm",
                                  "checkpoint_nvm_dram"),
          plans: Sequence[CrashPlan] = DEFAULT_SWEEP_PLANS,
          cfg: Optional[NVMConfig] = None,
          out_json: Optional[str] = None,
          progress=None,
          engine: str = "fork") -> List[ScenarioResult]:
    """Run the full workloads × strategies × crash-plans matrix.

    All plans of a (workload, strategy) pair are grounded against one
    probe workload; a seeded ``CrashPlan.random(count=k)`` contributes
    ``k`` cells. ``engine`` selects execution (module docstring):
    ``"fork"`` (default) runs each pair forward once and forks every
    cell from a snapshot at its crash point; ``"rerun"`` re-executes
    each cell from step 0 on a fresh workload instance. Both engines
    produce identical cells (modulo ``wall_seconds``); fork makes dense
    plans (``CrashPlan.at_every_step()``) tractable.

    ``out_json`` writes the ``BENCH_scenarios.json`` artifact:
    ``{"schema": ..., "cells": [<ScenarioResult>...], "skipped": [...]}``.

    A plan that cannot be grounded for some (workload, strategy) pair —
    e.g. ``at_phase("loop2", ...)`` against the single-loop plain-mode
    MM, or ``at_step(k)`` past a shorter workload's step count — skips
    that cell (recorded in ``skipped``) instead of aborting the matrix.
    """
    if engine not in SWEEP_ENGINES:
        raise ValueError(f"unknown sweep engine {engine!r}; "
                         f"choose from {SWEEP_ENGINES}")
    from .sweep_engine import run_pair_forked  # late: avoids import cycle

    results: List[ScenarioResult] = []
    skipped: List[Dict[str, str]] = []
    for wl_spec in workloads:
        for strat_spec in strategies:
            # one probe per (workload, strategy) pair grounds every plan
            probe = make_workload(wl_spec)
            strat = make_strategy(strat_spec)
            probe.setup(cfg, "adcc" if strat.wants_adcc else "plain")
            grounded: List[Tuple[CrashPlan, List[CrashPoint]]] = []
            for plan in plans:
                try:
                    grounded.append((plan, plan.resolve(probe)))
                except ValueError as exc:
                    skipped.append({"workload": probe.name,
                                    "strategy": strat.name,
                                    "plan": plan.describe(),
                                    "reason": str(exc)})
            if not grounded:
                continue
            if engine == "fork":
                results.extend(
                    run_pair_forked(probe, strat, grounded,
                                    progress=progress))
                continue
            reuse: Optional[Tuple[Workload, ConsistencyStrategy]] = \
                (probe, strat)
            for plan, points in grounded:
                for point in points:
                    if reuse is not None:
                        wl, st = reuse
                        reuse = None
                    else:
                        wl = make_workload(wl_spec)
                        st = make_strategy(strat_spec)
                        wl.setup(cfg, "adcc" if st.wants_adcc else "plain")
                    st.attach(wl)
                    res = _run_point(wl, st, point, plan.describe(),
                                     recover=True)
                    results.append(res)
                    if progress is not None:
                        progress(res)
    if out_json:
        write_scenarios_json(out_json, results, skipped=skipped)
    return results


def dump_json(path: str, payload) -> None:
    """The artifact writer (benchmarks/common.py re-exports it)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)


def write_scenarios_json(path: str, results: Iterable[ScenarioResult],
                         skipped: Optional[List[Dict[str, str]]] = None
                         ) -> None:
    dump_json(path, {
        "schema": "repro.scenarios.sweep/v1",
        "cells": [r.to_json_dict() for r in results],
        "skipped": skipped or [],
    })
