"""Unit/property coverage for framework substrates added during the perf
work: grouped-GEMM MoE path, data pipeline resumability, loop-aware HLO
analyzer, schedules, sharding helpers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models import moe
from repro.optim.adamw import lr_schedule


class TestGroupedGemm:
    @settings(max_examples=15, deadline=None)
    @given(r=st.integers(8, 96), e=st.integers(1, 6), d=st.integers(4, 24),
           f=st.integers(4, 24), seed=st.integers(0, 100))
    def test_scan_grouped_matches_ragged(self, r, e, d, f, seed):
        rng = np.random.default_rng(seed)
        gs = rng.multinomial(r, np.ones(e) / e).astype(np.int32)
        x = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
        wg = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
        wu = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
        wd = jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32)
        ref = moe._local_expert_ffn_ragged(x, jnp.asarray(gs), wg, wu, wd)
        # block_factor large enough that no rows are dropped
        got = moe._local_expert_ffn(x, jnp.asarray(gs), wg, wu, wd,
                                    block_factor=float(e))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_capacity_drop_zeroes_overflow(self):
        rng = np.random.default_rng(0)
        e, d, f = 2, 8, 8
        gs = jnp.asarray([30, 2], jnp.int32)   # skewed group
        x = jnp.asarray(rng.normal(size=(32, d)), jnp.float32)
        w = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
        got = moe._local_expert_ffn(x, gs, w(e, d, f), w(e, d, f),
                                    w(e, f, d), block_factor=1.0)
        # cap = 16: rows 16..29 of group 0 are dropped -> exactly zero
        assert bool(jnp.all(got[16:30] == 0.0))
        assert bool(jnp.any(got[:16] != 0.0))


class TestPipeline:
    CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128)

    def test_batch_pure_function_of_step(self):
        p1 = SyntheticPipeline(self.CFG, batch=4, seq=16, seed=3)
        p2 = SyntheticPipeline(self.CFG, batch=4, seq=16, seed=3)
        for _ in range(3):
            next(p2)
        assert np.array_equal(p1.batch_at(7)["tokens"],
                              p2.batch_at(7)["tokens"])

    def test_cursor_resume_replays_stream(self):
        p1 = SyntheticPipeline(self.CFG, batch=4, seq=16, seed=1)
        seen = [next(p1)["tokens"] for _ in range(5)]
        cursor = p1.cursor()
        p2 = SyntheticPipeline(self.CFG, batch=4, seq=16, seed=999)
        p2.restore(cursor)
        nxt = next(p2)
        expect = SyntheticPipeline(self.CFG, batch=4, seq=16, seed=1).batch_at(5)
        assert np.array_equal(nxt["tokens"], expect["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = SyntheticPipeline(self.CFG, batch=2, seq=16, seed=0).batch_at(0)
        # labels[t] continues the same underlying stream as tokens[t+1]
        assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_different_seeds_differ(self):
        a = SyntheticPipeline(self.CFG, batch=2, seq=16, seed=0).batch_at(0)
        b = SyntheticPipeline(self.CFG, batch=2, seq=16, seed=1).batch_at(0)
        assert not np.array_equal(a["tokens"], b["tokens"])


class TestHloAnalyzer:
    def test_scan_trip_multiplication(self):
        import sys, os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        from benchmarks.hlo_analysis import analyze

        def make(L):
            def f(x, w):
                def body(h, _):
                    return h @ w, None
                h, _ = jax.lax.scan(body, x, None, length=L)
                return h
            return f

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        for L in (1, 3, 7):
            c = jax.jit(make(L)).lower(x, w).compile()
            costs = analyze(c.as_text())
            assert abs(costs.flops - 2 * 128 ** 3 * L) / (2 * 128 ** 3 * L) \
                < 1e-6, L

    def test_nested_scan(self):
        import sys, os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        from benchmarks.hlo_analysis import analyze

        def g(x, w):
            def outer(h, _):
                def inner(h2, _):
                    return h2 @ w, None
                h2, _ = jax.lax.scan(inner, h, None, length=3)
                return h2, None
            h, _ = jax.lax.scan(outer, x, None, length=5)
            return h

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = jax.jit(g).lower(x, w).compile()
        costs = analyze(c.as_text())
        assert abs(costs.flops / (2 * 64 ** 3 * 15) - 1) < 1e-6


class TestSchedules:
    def test_warmup_then_decay(self):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                           total_steps=100)
        lrs = [float(lr_schedule(tcfg, jnp.int32(s))) for s in range(100)]
        assert lrs[0] < lrs[5] < lrs[10]          # warmup rises
        assert lrs[10] == max(lrs)                 # peak at warmup end
        assert lrs[-1] < 0.2 * max(lrs)            # decays


class TestShardAct:
    def test_noop_without_mesh(self):
        from repro.models.layers import shard_act
        x = jnp.ones((4, 8, 16))
        assert shard_act(x, None) is x

    def test_applies_on_named_mesh(self):
        from repro.launch.mesh import single_device_mesh
        from repro.models.layers import shard_act
        mesh = single_device_mesh()
        x = jnp.ones((4, 8, 16))
        y = shard_act(x, mesh)
        assert y.shape == x.shape

    def test_skips_unshardable_batch(self):
        from repro.launch.mesh import single_device_mesh
        from repro.models.layers import shard_act
        mesh = single_device_mesh()
        x = jnp.ones((1, 8, 16))   # batch 1 still divisible by 1 -> applied
        assert shard_act(x, mesh).shape == x.shape
