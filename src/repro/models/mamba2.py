"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training path uses the chunked SSD formulation: within a chunk the
recurrence is materialized as a masked (semiseparable) attention-like
matmul — MXU-friendly — and across chunks a tiny ``lax.scan`` carries the
(heads, head_dim, state) SSM state. Decode is the O(1)-per-token
recurrent update — the reason the long_500k shape is runnable for the
ssm/hybrid archs and skipped for full-attention ones.

Layout follows the reference Mamba2: in_proj emits [z | x | B | C | dt],
depthwise causal conv (width 4) over [x | B | C], scalar-per-head decay
A, head-wise dt, D skip, gated RMSNorm-free SiLU(z) gate, out_proj.
Single B/C group (G=1).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Axes, Params, dense_init

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_decode_step",
           "mamba2_cache_init", "mamba2_dims"]


def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(d_inner, n_heads, conv_channels)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * cfg.ssm_state  # x, B, C get convolved
    return d_inner, nheads, conv_ch


def mamba2_init(cfg: ModelConfig, key) -> Tuple[Params, Axes]:
    D = cfg.d_model
    N = cfg.ssm_state
    d_inner, nheads, conv_ch = mamba2_dims(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    d_proj = 2 * d_inner + 2 * N + nheads  # z, x, B, C, dt
    p["in_proj"], a["in_proj"] = dense_init(ks[0], D, d_proj,
                                            "embed", "ssm_proj", dtype)
    p["conv_w"] = (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype)
    a["conv_w"] = ("conv_width", "ssm_conv")
    p["conv_b"] = jnp.zeros((conv_ch,), dtype)
    a["conv_b"] = ("ssm_conv",)
    # A in (-exp) parameterization, one scalar per head; dt bias for softplus
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32)
    a["A_log"] = ("ssm_heads",)
    p["dt_bias"] = jnp.full((nheads,), 0.5, jnp.float32)
    a["dt_bias"] = ("ssm_heads",)
    p["D_skip"] = jnp.ones((nheads,), jnp.float32)
    a["D_skip"] = ("ssm_heads",)
    p["out_proj"], a["out_proj"] = dense_init(ks[4], d_inner, D,
                                              "ssm_inner", "embed", dtype)
    return p, a


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, nheads, _ = mamba2_dims(cfg)
    N = cfg.ssm_state
    z, xs, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, xs, B, C, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. u: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(W):  # W=4: tiny unroll, fuses into one vectorized op
        out = out + pad[:, i:i + u.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _segsum(log_a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] =
    sum_{j < u <= i} log_a[..., u], -inf above the diagonal."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_apply(cfg: ModelConfig, p: Params, x_in: jax.Array) -> jax.Array:
    """Full-sequence SSD. x_in: (B, S, D) -> (B, S, D). S % chunk == 0
    (callers pad; all assigned shapes are powers of two)."""
    Bb, S, D = x_in.shape
    N = cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    d_inner, nheads, _ = mamba2_dims(cfg)
    hd = cfg.ssm_head_dim
    dt_ = x_in.dtype

    proj = x_in @ p["in_proj"].astype(dt_)
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"].astype(dt_),
                            p["conv_b"].astype(dt_))
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    log_a = dt * A[None, None, :]                                 # (B,S,H)

    nc = S // Q
    xh = xs.reshape(Bb, nc, Q, nheads, hd).astype(jnp.float32)
    Bc = Bm.reshape(Bb, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bb, nc, Q, N).astype(jnp.float32)
    la = log_a.reshape(Bb, nc, Q, nheads)
    dtc = dt.reshape(Bb, nc, Q, nheads)
    xdt = xh * dtc[..., None]                                     # fold dt in

    # ---- intra-chunk (quadratic within chunk, MXU matmuls) ---------------
    L = jnp.exp(_segsum(jnp.moveaxis(la, -1, -2)))   # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)   # (B,nc,Q,Q)
    y_intra = jnp.einsum("bchqk,bcqk,bckhp->bcqhp",
                         L, scores, xdt)

    # ---- chunk summaries + inter-chunk scan ------------------------------
    la_cum = jnp.cumsum(la, axis=2)                  # (B,nc,Q,H)
    la_tot = la_cum[:, :, -1, :]                     # (B,nc,H)
    decay_to_end = jnp.exp(la_tot[:, :, None, :] - la_cum)  # (B,nc,Q,H)
    # state contribution of each chunk: (B,nc,H,hd,N)
    S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_to_end, xdt)

    def scan_fn(state, inp):
        s_c, tot = inp                                # (B,H,hd,N), (B,H)
        new = state * jnp.exp(tot)[:, :, None, None] + s_c
        return new, state                             # emit state *entering*

    init = jnp.zeros((Bb, nheads, hd, N), jnp.float32)
    _, states_in = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(la_tot, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)         # (B,nc,H,hd,N)

    # inter-chunk output: C_t · decay(t) · state_in
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, jnp.exp(la_cum), states_in)

    y = (y_intra + y_inter).reshape(Bb, S, nheads, hd)
    y = y + xh.reshape(Bb, S, nheads, hd) * p["D_skip"][None, None, :, None]
    y = y.reshape(Bb, S, d_inner).astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def mamba2_cache_init(cfg: ModelConfig, batch: int):
    """SSM state + conv tail. O(1) in sequence length."""
    d_inner, nheads, conv_ch = mamba2_dims(cfg)
    dtype = jnp.float32
    cache = {
        "state": jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state),
                           dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch),
                          jnp.dtype(cfg.compute_dtype)),
    }
    axes = {
        "state": ("batch", "ssm_heads", "head_dim", "state"),
        "conv": ("batch", "conv_width", "ssm_conv"),
    }
    return cache, axes


def mamba2_decode_step(cfg: ModelConfig, p: Params, x_tok: jax.Array,
                       cache: Dict[str, jax.Array]):
    """One token. x_tok: (B, 1, D) -> ((B, 1, D), new cache)."""
    Bb = x_tok.shape[0]
    N = cfg.ssm_state
    d_inner, nheads, conv_ch = mamba2_dims(cfg)
    hd = cfg.ssm_head_dim
    dt_ = x_tok.dtype

    proj = (x_tok[:, 0, :] @ p["in_proj"].astype(dt_))
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)   # (B, conv_ch)
    window = jnp.concatenate([cache["conv"],
                              conv_in[:, None, :].astype(cache["conv"].dtype)],
                             axis=1)                   # (B, W, conv_ch)
    w = p["conv_w"].astype(dt_)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(dt_), w)
        + p["conv_b"].astype(dt_))
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt_h = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt_h * A[None, :])                                # (B,H)
    xh = xs.reshape(Bb, nheads, hd).astype(jnp.float32)
    state = (cache["state"] * da[:, :, None, None]
             + jnp.einsum("bhp,bn,bh->bhpn", xh, Bm.astype(jnp.float32), dt_h))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(Bb, d_inner).astype(dt_) * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    new_cache = {"state": state, "conv": window[:, 1:, :]}
    return out, new_cache
