"""Beyond-paper table: ADCC vs traditional checkpointing for TRAINING.

Measures real wall-clock per-step cost of the three trainer modes on a
reduced llama3 config (same code path as production):

  none  — no fault tolerance (native)
  adcc  — synchronous few-KB ledger + async fence-free slots (paper
          technique mapped to training; recompute bounded by slot_every)
  sync  — blocking full-state checkpoint every slot_every steps (the
          traditional baseline with the same recompute bound)

This is the training-loop analogue of the paper's Fig. 4 comparison,
measured (not modeled): the ledger append is real fsync'd I/O and the
sync checkpoint writes real npy files.
"""

from __future__ import annotations

import shutil
import tempfile
from typing import List

import numpy as np

from repro.configs.base import TrainConfig
from repro.launch.train import ADCCTrainer
from repro.models.registry import get_config

from .common import Row, emit

ARTIFACT = "train_overhead.json"

STEPS = 24
SLOT_EVERY = 8


def run() -> List[Row]:
    import dataclasses
    # large enough that a blocking checkpoint visibly costs wall time
    # (~45M params -> ~540MB params+moments per snapshot)
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              d_model=512, n_layers=4, d_ff=1024,
                              vocab_size=16384, n_heads=8, n_kv_heads=4,
                              head_dim=64)
    tcfg = TrainConfig(remat="none", total_steps=STEPS, warmup_steps=2)
    rows = []
    means = {}
    for mode in ["none", "adcc", "sync"]:
        wd = tempfile.mkdtemp(prefix=f"bench_{mode}_")
        try:
            tr = ADCCTrainer(cfg, tcfg, wd, batch=8, seq=64,
                             slot_every=SLOT_EVERY, mode=mode)
            res = tr.run(STEPS, log_every=0)
            # skip warmup/compile steps
            times = np.asarray(res.step_seconds[2:])
            means[mode] = float(np.mean(times))
            rows.append(Row(f"train_overhead/{mode}/step_seconds",
                            means[mode],
                            f"p50={np.percentile(times,50)*1e3:.1f}ms "
                            f"p95={np.percentile(times,95)*1e3:.1f}ms"))
        finally:
            shutil.rmtree(wd, ignore_errors=True)
    for mode in ["adcc", "sync"]:
        rows.append(Row(f"train_overhead/{mode}/normalized_vs_native",
                        means[mode] / means["none"]))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
