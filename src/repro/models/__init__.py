"""Model zoo: unified LM (dense/moe/audio/vlm), SSM, and hybrid families.

All architectures are selected through ``registry.build_model`` /
``registry.get_config`` (the ``--arch`` flag of the launch scripts).
"""

from .registry import ModelApi, build_model, get_config, list_archs

__all__ = ["ModelApi", "build_model", "get_config", "list_archs"]
