"""Paper Fig. 7: ABFT-MM recomputation cost for crashes in loop 1
(submatrix multiplication) and loop 2 (submatrix addition), across
matrix sizes — a declarative scenario matrix (ADCC strategy ×
per-phase crash plans). Expect: large matrices lose <= 1 chunk/row-block."""

from __future__ import annotations

from typing import List

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, run_scenario

from .common import Row, emit

ARTIFACT = "fig7_mm_recompute.json"

SIZES = [256, 512, 768, 1024]
CRASH_INDEX = 2


def run() -> List[Row]:
    cfg = NVMConfig(cache_bytes=4 * 1024 * 1024)
    rows = []
    for n in SIZES:
        for loop in ("loop1", "loop2"):
            res = run_scenario(("mm", {"n": n, "k": n // 4, "seed": n}),
                               "adcc", CrashPlan.at_phase(loop, CRASH_INDEX),
                               cfg=cfg)
            assert res.correct, (n, loop, res.metrics)
            norm = ((res.detect_seconds + res.resume_seconds)
                    / max(res.avg_step_seconds, 1e-12))
            rows.append(Row(f"fig7/mm_recompute/n={n}/{loop}/chunks_lost",
                            res.info["chunks_lost"],
                            f"corrected={res.info['corrected_elements']} "
                            f"err={res.metrics['max_error']:.1e}"))
            rows.append(Row(
                f"fig7/mm_recompute/n={n}/{loop}/normalized_recompute",
                norm, f"detect={res.detect_seconds:.4f}s"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
