"""Scenario sweep: the full workloads × strategies × crash-points matrix
through ``repro.scenarios.sweep()`` in one call, on the vectorized
emulation backend. Emits one row per cell plus the machine-readable
``BENCH_scenarios.json`` artifact (the EasyCrash-style systematic
characterization of post-crash consistence).

Default matrix: 3 workloads × 6 strategies × 4 crash points = 72 cells.
``--smoke`` (or REPRO_SCENARIOS_SMOKE=1) shrinks it to the CI matrix:
3 workloads × 3 strategies × 2 crash plans.
"""

from __future__ import annotations

import os
from typing import List

from repro.core.nvm import NVMConfig
from repro.scenarios import DEFAULT_SWEEP_PLANS, CrashPlan, sweep

from .common import ART, Row, emit

ARTIFACT = "scenarios_sweep.json"
BENCH_JSON = os.path.join(ART, "BENCH_scenarios.json")

WORKLOADS = (
    ("cg", {"n": 4096, "iters": 12}),
    ("mm", {"n": 128, "k": 32}),
    ("xsbench", {"lookups": 1500, "grid_points": 2000,
                 "flush_every_frac": 0.01}),
)
STRATEGIES = ("none", "adcc", "undo_log", "checkpoint_hdd",
              "checkpoint_nvm", "checkpoint_nvm_dram")
PLANS = DEFAULT_SWEEP_PLANS

SMOKE_WORKLOADS = (
    ("cg", {"n": 1024, "iters": 8}),
    ("mm", {"n": 64, "k": 16}),
    ("xsbench", {"lookups": 400, "grid_points": 800,
                 "flush_every_frac": 0.02}),
)
SMOKE_STRATEGIES = ("none", "adcc", "checkpoint_nvm")
SMOKE_PLANS = (CrashPlan.no_crash(), CrashPlan.at_fraction(0.5))


def run(smoke: bool = None) -> List[Row]:
    if smoke is None:
        smoke = bool(int(os.environ.get("REPRO_SCENARIOS_SMOKE", "0")))
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    strategies = SMOKE_STRATEGIES if smoke else STRATEGIES
    plans = SMOKE_PLANS if smoke else PLANS
    cfg = NVMConfig(cache_bytes=1 * 1024 * 1024)
    cells = sweep(workloads=workloads, strategies=strategies, plans=plans,
                  cfg=cfg, out_json=BENCH_JSON)
    rows = []
    n_correct = 0
    for c in cells:
        cell = f"scenarios/{c.workload}/{c.strategy}/{c.plan}"
        n_correct += int(c.correct)
        rows.append(Row(f"{cell}/correct", float(c.correct),
                        f"crash_step={c.crash_step}"))
        rows.append(Row(f"{cell}/steps_lost", c.steps_lost,
                        f"restart={c.restart_point}"))
        rows.append(Row(f"{cell}/overhead_seconds", c.overhead_seconds,
                        f"modeled_total={c.modeled_total_seconds:.3e}s"))
    rows.append(Row("scenarios/summary/cells", len(cells),
                    f"matrix={len(workloads)}x{len(strategies)}x{len(plans)}"))
    rows.append(Row("scenarios/summary/correct_cells", n_correct,
                    f"artifact={BENCH_JSON}"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI matrix: 3 workloads x 3 strategies x 2 plans")
    args = ap.parse_args()
    emit(run(smoke=args.smoke or None), save_as=ARTIFACT)
