"""Paper Fig. 4: CG runtime with the seven crash-consistence mechanisms.

Cases (paper §III.A): (1) native, (2) checkpoint->HDD, (3) checkpoint->
NVM-only, (4) checkpoint->NVM/DRAM, (5) PMEM undo-log transactions,
(6) ADCC on NVM-only, (7) ADCC on NVM/DRAM. Checkpoint / transaction
frequency = every iteration (same recomputation budget as ADCC with a
large problem — the paper's fair-comparison setup).

The mechanism axis and its cost formulas come entirely from
``repro.scenarios`` (`mechanism_cases()` + the per-workload
`cg_step_profile`): this figure is just the declarative matrix
``native_iter x 7 mechanisms``. CG compute is measured wall-clock;
reported value = normalized runtime vs native.
"""

from __future__ import annotations

from typing import List

from repro.algorithms.cg import make_spd_system, plain_cg
from repro.scenarios import cg_step_profile, mechanism_cases

from .common import Row, emit, timeit

ARTIFACT = "fig4_cg_runtime.json"

N = 131072
ITERS = 12
NNZ = 8


def _native_iter_seconds(A, b) -> float:
    t = timeit(lambda: plain_cg(A, b, ITERS), repeats=2)
    return t / ITERS


def run() -> List[Row]:
    A, b = make_spd_system(N, nnz_per_row=NNZ, seed=0)
    iter_s = _native_iter_seconds(A, b)
    rows = [Row("fig4/cg_runtime/native_iter_seconds", iter_s)]
    for case in mechanism_cases():
        cfg = case.config()
        mech = case.step_seconds(cg_step_profile(N, cfg.line_bytes), cfg)
        rows.append(Row(f"fig4/cg_runtime/{case.name}/normalized",
                        (iter_s + mech) / iter_s,
                        f"mech={mech*1e3:.3f}ms"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
