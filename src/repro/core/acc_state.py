"""ADCC for training state: the checksum ledger (DESIGN.md §2-3).

The paper flushes one cache line per iteration (the loop counter) and
reasons about everything else with algorithm invariants. The training
analogue persists a few-KB *ledger record* synchronously each step —

    {step, rng seed, data cursor, per-leaf f32 checksums of
     (params, opt state, applied updates), loss}

— while the heavy state goes to slots asynchronously with no fences
(core/slots.py). Two invariant levels at recovery, both paper-style:

1. **Ledger integrity** — the linearity chain
       cks_params[t] ≈ cks_params[t-1] + cks_updates[t]
   (optimizer updates are additive, so the per-tensor sums obey the same
   recurrence; paper Eq. 1/2 analogue: an internal relation that torn
   records cannot satisfy). Torn/partial tail records are discarded.

2. **Slot consistency** — a slot written at step t is accepted iff every
   leaf's recomputed f32 sum matches the ledger's record for step t
   (ABFT checksum verification, Eq. 6 analogue, at tensor granularity).

Records are single JSON lines; a torn append produces an unparsable or
chain-breaking tail line, which recovery skips — by construction the
ledger needs no fsync ordering with the slots.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["LedgerRecord", "ChecksumLedger", "flatten_checksums",
           "verify_state_against_record"]


def flatten_checksums(tree) -> List[float]:
    """Deterministic (sorted-path) flattening of a checksum pytree."""
    import jax
    leaves = jax.tree.leaves(tree)
    return [float(x) for x in leaves]


@dataclasses.dataclass
class LedgerRecord:
    step: int
    rng_seed: int
    cursor: List[int]
    cks_params: List[float]
    cks_opt: List[float]
    cks_updates: List[float]
    loss: float

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), separators=(",", ":"))

    @classmethod
    def from_json(cls, line: str) -> "LedgerRecord":
        return cls(**json.loads(line))


class ChecksumLedger:
    """Append-only per-step ledger with linearity-chain validation."""

    # |sum(p_t) - (sum(p_{t-1}) + sum(u_t))| <= CHAIN_RTOL * scale
    CHAIN_RTOL = 1e-3
    SLOT_RTOL = 1e-4
    SLOT_ATOL = 1e-2

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = None

    # -- write side -----------------------------------------------------------
    def append(self, rec: LedgerRecord) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a", buffering=1)
        self._fh.write(rec.to_json() + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())  # the "CLFLUSH": a few KB, synchronous

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- read/verify side -----------------------------------------------------
    def read_all(self) -> List[LedgerRecord]:
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(LedgerRecord.from_json(line))
                except (json.JSONDecodeError, TypeError, KeyError):
                    break  # torn tail: discard the rest
        return out

    def validated_records(self) -> List[LedgerRecord]:
        """Drop any suffix that breaks the linearity chain (invariant 1)."""
        recs = self.read_all()
        good: List[LedgerRecord] = []
        for rec in recs:
            if good and rec.step == good[-1].step + 1 \
                    and len(rec.cks_params) == len(good[-1].cks_params):
                prev = np.asarray(good[-1].cks_params, np.float64)
                upd = np.asarray(rec.cks_updates, np.float64)
                cur = np.asarray(rec.cks_params, np.float64)
                scale = np.maximum(np.abs(cur), 1.0)
                if np.any(np.abs(cur - (prev + upd)) > self.CHAIN_RTOL * scale):
                    break  # chain broken: discard this record and the rest
            elif good and rec.step != good[-1].step + 1:
                break
            good.append(rec)
        return good

    def record_for_step(self, step: int) -> Optional[LedgerRecord]:
        for rec in reversed(self.validated_records()):
            if rec.step == step:
                return rec
        return None


def verify_state_against_record(params, opt_state, rec: LedgerRecord,
                                rtol: float = None, atol: float = None
                                ) -> Tuple[bool, int]:
    """Invariant 2: recompute per-leaf sums and compare with the ledger.
    Returns (ok, number of mismatching leaves)."""
    import jax
    import jax.numpy as jnp
    rtol = rtol if rtol is not None else ChecksumLedger.SLOT_RTOL
    atol = atol if atol is not None else ChecksumLedger.SLOT_ATOL

    def sums(tree):
        return [float(jnp.sum(jnp.asarray(x).astype(jnp.float32)))
                for x in jax.tree.leaves(tree)]

    got = np.asarray(sums(params) + sums(opt_state), np.float64)
    want = np.asarray(rec.cks_params + rec.cks_opt, np.float64)
    if got.shape != want.shape:
        return False, max(len(got), len(want))
    tol = atol + rtol * np.maximum(np.abs(want), 1.0)
    bad = int(np.sum(np.abs(got - want) > tol))
    return bad == 0, bad
