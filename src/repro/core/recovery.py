"""Backward-scan recovery engine (paper §III.B recovery procedure).

After a crash, the newest persisted iteration counter bounds the search:
starting from iteration i (or slot k), test the algorithm's invariants
against the post-crash NVM view of each candidate; accept the first
(newest) candidate where every invariant holds. The engine reports both
the chosen restart point and the *detection cost* (modeled seconds spent
reading NVM to evaluate invariants), which benchmarks/fig3 breaks out as
"detecting where to restart".
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .invariants import CheckResult, InvariantSet

__all__ = ["RecoveryOutcome", "backward_scan"]


@dataclasses.dataclass
class RecoveryOutcome:
    restart_point: int          # iteration/slot to restart from; -1 => none found
    candidates_tested: int
    detection_seconds: float    # modeled NVM-read + invariant-eval time
    reports: List[List[CheckResult]]

    @property
    def found(self) -> bool:
        return self.restart_point >= 0


def backward_scan(
    newest: int,
    oldest: int,
    load_candidate: Callable[[int], Dict[str, np.ndarray]],
    invariants_for: Callable[[int], InvariantSet],
    charge_read_seconds: Optional[Callable[[Dict[str, np.ndarray]], float]] = None,
) -> RecoveryOutcome:
    """Scan candidates newest -> oldest (inclusive); return the first
    consistent one.

    load_candidate(j)  -> post-crash NVM views of iteration/slot j's objects
    invariants_for(j)  -> the InvariantSet that must hold at j
    charge_read_seconds(data) -> modeled cost of reading `data` from NVM
    """
    reports: List[List[CheckResult]] = []
    detect_s = 0.0
    tested = 0
    for j in range(newest, oldest - 1, -1):
        data = load_candidate(j)
        tested += 1
        if charge_read_seconds is not None:
            detect_s += charge_read_seconds(data)
        results = invariants_for(j).check_all(data)
        reports.append(results)
        if all(r.ok for r in results):
            return RecoveryOutcome(j, tested, detect_s, reports)
    return RecoveryOutcome(-1, tested, detect_s, reports)
