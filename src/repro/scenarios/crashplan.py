"""Declarative crash plans — *where* a run dies, separated from *what*
runs and *how* it persists.

EasyCrash-style systematic crash-scenario sweeps need crash points to be
first-class values that can be enumerated, seeded, and serialized, not
``crash_at=...`` kwargs threaded through every driver. A
:class:`CrashPlan` names a family of crash points against an abstract
step axis; :meth:`CrashPlan.resolve` grounds it against a concrete
workload (which knows its step count and phase layout) into a list of
:class:`CrashPoint` s.

Supported kinds:

  no_crash()             run to completion
  at_step(k)             crash after step k completes (and, unless
                         torn, after the strategy's persistence hook
                         for step k ran)
  at_phase(name, i)      crash after the i-th step of a named workload
                         phase ("loop1" / "loop2" for ABFT-MM)
  at_fraction(f)         crash after step floor(f * (n_steps - 1))
  random(count, seed)    ``count`` seeded uniform crash points — the
                         batch axis sweep() expands into one cell each
  at_every_step()        one crash point per step — the exhaustive
                         recompute-vs-crash-point curve (figs 3/7);
                         dense, so pair it with the fork sweep engine

``torn`` models a crash *inside* the step boundary: the step's
computation happened but the consistency mechanism's end-of-step
persistence (undo-log commit, checkpoint, selective flush) did not —
the case that exercises rollback paths. Two spellings:

  torn=True              the all-or-nothing worst case: every dirty
                         cache line is lost (the pre-TornSpec
                         behavior, kept byte-identical);
  torn=TornSpec(...)     parameterized line survival: a seeded subset
                         of the dirty lines was already written back
                         when power failed, so the crash image is one
                         of the *torn-write* states WITCHER enumerates
                         and EasyCrash samples. ``samples`` expands
                         each crash step into that many cells, each
                         with its own derived survival seed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union, TYPE_CHECKING

import numpy as np

from ..core.backends import LineSurvival, MediaFault

if TYPE_CHECKING:  # pragma: no cover
    from .workloads import Workload

__all__ = ["CrashPlan", "CrashPoint", "TornSpec", "FaultSpec"]


@dataclasses.dataclass(frozen=True)
class TornSpec:
    """Parameterized sub-step torn-write crash: which fraction of the
    dirty cache lines already persisted, chosen how, sampled how often.

    ``survival_for(j)`` derives sample j's :class:`LineSurvival`
    (effective seed = ``seed + j``), so resolution is a pure, replayable
    function of the spec — the property tests rely on it.
    """

    fraction: float = 0.0
    seed: int = 0
    mode: str = "random"     # "random" | "eviction" (see LineSurvival)
    samples: int = 1
    granularity: str = "line"  # "line" | "word" (WITCHER sub-line states)

    def __post_init__(self):
        # LineSurvival owns fraction/mode/granularity validation
        LineSurvival(self.fraction, self.seed, self.mode, self.granularity)
        if self.samples < 1:
            raise ValueError("samples must be >= 1")

    def survival_for(self, sample: int) -> LineSurvival:
        return LineSurvival(self.fraction, self.seed + int(sample), self.mode,
                            self.granularity)

    def describe(self) -> str:
        base = f"{self.mode}:f{self.fraction:g}:s{self.seed}"
        if self.granularity == "word":
            base += ":word"
        return base + (f":x{self.samples}" if self.samples > 1 else "")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Recovery-time fault injection attached to a crash point — what
    goes wrong *after* the crash, while (or before) recovery runs.

    Two orthogonal fault families (combinable in principle, but the
    shipped campaigns keep them separate so the golden-comparison
    classes stay unambiguous):

    * **Nested crash** (``nested_after`` set): power fails again after
      the ``nested_after``-th recovery action (see
      :meth:`CrashEmulator.arm_nested_crash`), ``nested_crashes`` times
      in total, each re-crash with its own derived torn line survival
      (``nested_fraction`` / ``nested_mode``; fraction 0 = the classic
      all-or-nothing re-crash). The driver retries recovery up to
      ``max_attempts`` times; strategies whose recovery performs no
      emulator actions (a post-commit undo-log boundary, XSBench's
      read-only pointer recovery) never trip the trap and classify
      through the base path.
    * **Media fault** (``poison_words`` > 0): the post-crash image is
      silently corrupted (:class:`~repro.core.backends.MediaFault`)
      before recovery runs — ``poison_regions`` restricts targets to
      exact live-region names or ``"prefix*"`` globs (None = every
      live region); an empty match injects nothing.
    """

    nested_after: Optional[int] = None
    nested_fraction: float = 0.0
    nested_mode: str = "random"
    nested_crashes: int = 1
    max_attempts: int = 3
    seed: int = 0
    poison_words: int = 0
    poison_kind: str = "poison"
    poison_regions: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.nested_after is None and self.poison_words <= 0:
            raise ValueError(
                "FaultSpec must inject something: set nested_after "
                "and/or poison_words")
        if self.nested_after is not None:
            if self.nested_after < 1:
                raise ValueError("nested_after must be >= 1")
            if self.nested_crashes < 1:
                raise ValueError("nested_crashes must be >= 1")
            if self.max_attempts <= self.nested_crashes:
                raise ValueError(
                    "max_attempts must exceed nested_crashes (the final "
                    "attempt must be allowed to complete)")
            # LineSurvival owns fraction/mode validation
            LineSurvival(self.nested_fraction, self.seed, self.nested_mode)
        if self.poison_words > 0:
            MediaFault(self.poison_words, self.seed, self.poison_kind)
        if self.poison_regions is not None:
            object.__setattr__(self, "poison_regions",
                               tuple(self.poison_regions))

    def nested_survival(self, firing: int) -> Optional[LineSurvival]:
        """Line survival of re-crash number ``firing`` (1-based). Pure
        in (spec, firing): retried resolutions replay identically."""
        if self.nested_fraction <= 0.0:
            return None
        return LineSurvival(self.nested_fraction,
                            self.seed + 101 * int(firing),
                            self.nested_mode)

    def media_fault(self) -> Optional[MediaFault]:
        if self.poison_words <= 0:
            return None
        return MediaFault(self.poison_words, self.seed, self.poison_kind)

    def resolve_poison_regions(self, live_names) -> List[str]:
        """Ground ``poison_regions`` against a workload's live-region
        names: exact matches plus ``"prefix*"`` glob expansion, in
        sorted order (the canonical ordering corrupt_image_words
        selects over)."""
        live = sorted(live_names)
        if self.poison_regions is None:
            return live
        out = set()
        for pat in self.poison_regions:
            if pat.endswith("*"):
                out.update(n for n in live if n.startswith(pat[:-1]))
            elif pat in live:
                out.add(pat)
        return sorted(out)

    def describe(self) -> str:
        parts = []
        if self.nested_after is not None:
            p = f"nested:a{self.nested_after}:f{self.nested_fraction:g}"
            p += f":s{self.seed}"
            if self.nested_mode != "random":
                p += f":{self.nested_mode}"
            if self.nested_crashes > 1:
                p += f":x{self.nested_crashes}"
            parts.append(p)
        if self.poison_words > 0:
            p = f"{self.poison_kind}:w{self.poison_words}:s{self.seed}"
            if self.poison_regions is not None:
                p += ":" + ",".join(self.poison_regions)
            parts.append(p)
        return "+".join(parts)


@dataclasses.dataclass(frozen=True)
class CrashPoint:
    """A concrete, grounded crash point for one scenario run."""

    step: Optional[int]          # None => never crash
    torn: bool = False
    # line-survival subset for sub-step torn crashes; None = the
    # classic all-or-nothing crash (every dirty line lost)
    survival: Optional[LineSurvival] = None
    # recovery-time fault injection (nested crash / media fault); None
    # = the classic crash-once-recover-once cell
    fault: Optional[FaultSpec] = None

    def describe(self) -> str:
        if self.step is None:
            return "no_crash"
        fault = (f":fault[{self.fault.describe()}]"
                 if self.fault is not None else "")
        if self.survival is not None:
            return (f"step={self.step}:torn[{self.survival.describe()}]"
                    + fault)
        return f"step={self.step}" + (":torn" if self.torn else "") + fault


@dataclasses.dataclass(frozen=True)
class CrashPlan:
    kind: str                    # "none" | "step" | "phase" | "fraction" | "random"
    step: Optional[int] = None
    phase: Optional[str] = None
    index: Optional[int] = None
    fraction: Optional[float] = None
    count: int = 1
    seed: int = 0
    torn: Union[bool, TornSpec] = False
    # recovery-time fault injection, applied to every crash point the
    # plan resolves (no_crash plans never carry one)
    fault: Optional[FaultSpec] = None

    # -- constructors ---------------------------------------------------------
    @classmethod
    def no_crash(cls) -> "CrashPlan":
        return cls(kind="none")

    @classmethod
    def at_step(cls, step: int, torn: Union[bool, TornSpec] = False,
                fault: Optional[FaultSpec] = None) -> "CrashPlan":
        if step < 0:
            raise ValueError("crash step must be >= 0")
        return cls(kind="step", step=int(step), torn=torn, fault=fault)

    @classmethod
    def at_phase(cls, phase: str, index: int,
                 torn: Union[bool, TornSpec] = False,
                 fault: Optional[FaultSpec] = None) -> "CrashPlan":
        return cls(kind="phase", phase=phase, index=int(index), torn=torn,
                   fault=fault)

    @classmethod
    def at_fraction(cls, fraction: float,
                    torn: Union[bool, TornSpec] = False,
                    fault: Optional[FaultSpec] = None) -> "CrashPlan":
        if not 0.0 <= fraction <= 1.0:
            raise ValueError("fraction must be in [0, 1]")
        return cls(kind="fraction", fraction=float(fraction), torn=torn,
                   fault=fault)

    @classmethod
    def random(cls, count: int = 1, seed: int = 0,
               torn: Union[bool, TornSpec] = False,
               fault: Optional[FaultSpec] = None) -> "CrashPlan":
        if count < 1:
            raise ValueError("count must be >= 1")
        return cls(kind="random", count=int(count), seed=int(seed),
                   torn=torn, fault=fault)

    @classmethod
    def at_every_step(cls, torn: Union[bool, TornSpec] = False,
                      fault: Optional[FaultSpec] = None) -> "CrashPlan":
        return cls(kind="every", torn=torn, fault=fault)

    # -- grounding ------------------------------------------------------------
    def _points_at(self, step: int) -> List[CrashPoint]:
        """Expand one grounded step into its crash points: a single
        point for boolean ``torn``, one per survival sample for a
        :class:`TornSpec` (each with its own derived seed)."""
        if isinstance(self.torn, TornSpec):
            return [CrashPoint(step, True, self.torn.survival_for(j),
                               self.fault)
                    for j in range(self.torn.samples)]
        return [CrashPoint(step, bool(self.torn), None, self.fault)]

    def resolve(self, workload: "Workload") -> List[CrashPoint]:
        """Ground this plan against a set-up workload. Returns one
        :class:`CrashPoint` per scenario cell (>1 for ``random`` /
        ``every`` / multi-sample :class:`TornSpec` plans).

        Contract (property-tested in tests/test_crashplan_properties.py):
        every resolved step lies in ``[0, n_steps)``, the returned steps
        are sorted and deduplicated across *steps* (``random`` samples
        without replacement and sorts; a TornSpec with ``samples=k``
        repeats each step k times with k distinct survival seeds), and
        resolution is a pure function of (plan, workload step/phase
        layout): resolving twice, or against another workload with the
        same layout, yields the same points. Plans that cannot be
        grounded raise ``ValueError`` (``sweep()`` records these cells
        as skipped)."""
        n = workload.n_steps
        if self.kind == "none":
            return [CrashPoint(None)]
        if self.kind == "step":
            if not 0 <= self.step < n:
                raise ValueError(
                    f"crash step {self.step} outside [0, {n}) for "
                    f"workload {workload.name!r}")
            return self._points_at(self.step)
        if self.kind == "phase":
            phases = workload.phases()
            if self.phase not in phases:
                raise ValueError(
                    f"workload {workload.name!r} has no phase "
                    f"{self.phase!r} (has {sorted(phases)})")
            rng = phases[self.phase]
            if not 0 <= self.index < len(rng):
                raise ValueError(
                    f"phase {self.phase!r} has {len(rng)} steps, "
                    f"index {self.index} out of range")
            return self._points_at(rng[self.index])
        if self.kind == "fraction":
            return self._points_at(min(n - 1, int(self.fraction * (n - 1))))
        if self.kind == "random":
            if self.count > n:
                raise ValueError(
                    f"random plan requests {self.count} distinct crash "
                    f"points but workload {workload.name!r} has only "
                    f"{n} steps")
            rng = np.random.default_rng(self.seed)
            steps = sorted(int(s) for s in
                           rng.choice(n, size=self.count, replace=False))
            return [p for s in steps for p in self._points_at(s)]
        if self.kind == "every":
            return [p for s in range(n) for p in self._points_at(s)]
        raise ValueError(f"unknown crash plan kind {self.kind!r}")

    def describe(self) -> str:
        if isinstance(self.torn, TornSpec):
            torn = f":torn[{self.torn.describe()}]"
        else:
            torn = ":torn" if self.torn else ""
        if self.fault is not None:
            torn += f":fault[{self.fault.describe()}]"
        if self.kind == "none":
            return "no_crash"
        if self.kind == "step":
            return f"step:{self.step}{torn}"
        if self.kind == "phase":
            return f"phase:{self.phase}:{self.index}{torn}"
        if self.kind == "fraction":
            return f"frac:{self.fraction:g}{torn}"
        if self.kind == "random":
            return f"rand:n{self.count}:s{self.seed}{torn}"
        if self.kind == "every":
            return f"every{torn}"
        return self.kind
