"""Workload adapters: the paper's three algorithms behind one protocol.

A :class:`Workload` exposes the structure every crash-consistence
mechanism needs — a step axis, named phases, the set of critical
persistent regions, restart-from-scratch, snapshot/restore — plus the
two *algorithm-directed* hooks only ADCC uses (``adcc_*``: the selective
flushes and the invariant-scan recovery).

Workloads run in one of two modes, chosen by the strategy:

  "adcc"   the paper's extended algorithm (versioned CG iterates,
           checksummed two-loop MM, selective-flush XSBench) — the data
           layout ADCC's recovery reasons about;
  "plain"  the unmodified algorithm over persistent regions — what the
           checkpoint / undo-log / native baselines actually protect.

Adapters are extracted from (and delegate to) ``repro.algorithms``:
``CGWorkload`` wraps :class:`~repro.algorithms.cg.ADCC_CG` primitives,
``MMWorkload`` wraps :class:`~repro.algorithms.mm_abft.ABFTMatmul`, and
``XSBenchWorkload`` wraps
:class:`~repro.algorithms.xsbench.ADCC_XSBench`.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..algorithms.cg import ADCC_CG, _sym_matvec, make_spd_system, plain_cg
from ..algorithms.mm_abft import ABFTMatmul
from ..algorithms.xsbench import ADCC_XSBench, XSBenchConfig
from ..core.nvm import CrashEmulator, NVMConfig
from ..core.regions import PersistentRegion
from . import costmodel

__all__ = [
    "RecoveryResult",
    "FinalReport",
    "Workload",
    "CGWorkload",
    "MMWorkload",
    "XSBenchWorkload",
    "WORKLOADS",
    "register_workload",
    "make_workload",
    "unknown_name_error",
]


def unknown_name_error(kind: str, name: str,
                       registered) -> ValueError:
    """Uniform unknown-registry-name error: lists every registered name
    (sorted) and suggests the closest match. Shared by the workload,
    strategy, and sweep-argument validators so a typo'd spec fails the
    same way everywhere — with enough context to fix it — instead of a
    bare KeyError deep inside a worker process."""
    import difflib

    names = sorted(registered)
    msg = f"unknown {kind} {name!r} (registered: {names})"
    close = difflib.get_close_matches(str(name), names, n=1)
    if close:
        msg += f"; did you mean {close[0]!r}?"
    return ValueError(msg)


@dataclasses.dataclass
class RecoveryResult:
    """What a strategy's (or ADCC's) recovery did after a crash."""

    resume_step: int                 # first step index to (re-)execute
    restart_point: int = -1          # newest surviving step; -1 => scratch
    detect_seconds: float = 0.0      # modeled cost of finding the restart
    redo_steps: int = 0              # work re-executed because of the crash
    steps_lost: Optional[int] = None  # completed-work lost; default derived
    from_scratch: bool = False
    info: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FinalReport:
    """End-of-run correctness report (uniform across workloads)."""

    metrics: Dict[str, float]
    correct: bool
    info: Dict[str, object] = dataclasses.field(default_factory=dict)


class Workload(abc.ABC):
    """One crash-consistence experiment subject (setup/step/recover)."""

    name: str = "workload"

    def __init__(self) -> None:
        self.mode: Optional[str] = None

    # -- lifecycle -------------------------------------------------------------
    @abc.abstractmethod
    def setup(self, cfg: Optional[NVMConfig], mode: str) -> None:
        """Allocate emulator state for ``mode`` ("adcc" | "plain")."""

    @property
    @abc.abstractmethod
    def emu(self) -> CrashEmulator: ...

    @property
    @abc.abstractmethod
    def n_steps(self) -> int: ...

    def phases(self) -> Dict[str, range]:
        return {"main": range(self.n_steps)}

    @abc.abstractmethod
    def step(self, i: int) -> None:
        """Execute step i (computation + region writes)."""

    @abc.abstractmethod
    def reset(self) -> None:
        """Reinitialize program state for a restart from scratch."""

    @abc.abstractmethod
    def finalize(self) -> FinalReport: ...

    def params(self) -> Dict[str, object]:
        return {}

    # -- generic strategy support (checkpoint / undo-log / none) ---------------
    def live_regions(self) -> List[PersistentRegion]:
        """Critical data objects a traditional mechanism protects."""
        return []

    def scalar_state(self) -> Dict[str, float]:
        """Small host-side state a snapshot must carry (e.g. CG's rho)."""
        return {}

    def restore(self, arrays: Optional[Dict[str, np.ndarray]],
                scalars: Optional[Dict[str, float]], last_step: int) -> None:
        """Load a consistent snapshot taken at the end of ``last_step``."""
        if arrays:
            by_name = {r.name: r for r in self.live_regions()}
            for name, data in arrays.items():
                if name in by_name:
                    by_name[name][...] = np.asarray(data).reshape(
                        by_name[name].shape)
        if scalars:
            self.restore_scalars(scalars)

    def restore_scalars(self, scalars: Dict[str, float]) -> None:
        pass

    def resync_from_nvm(self) -> None:
        """Reload truth from the (possibly rolled-back) NVM image —
        used after an undo-log rollback mutates the image post-crash."""
        emu = self.emu
        for r in self.live_regions():
            emu.resync_truth(r.name)

    def restart_digest(self, restart_point: int):
        """The semantically-live state at a restart point, as a dict of
        plain arrays/scalars — what a resumed deterministic replay
        actually reads. The fork engine's measure-mode certification
        diffs a recovered digest against the golden-prefix digest at
        the same step (``state_certified``): byte equality means the
        recovery provably landed on consistent state without running
        the tail.

        The default — live-region truth views (uncharged) plus scalar
        state — fits the plain-mode adapters and XSBench (whose loop
        index is a resume *pointer*, already certified via
        ``restart_point``, not replay input). Workloads whose live
        state is a sub-view of their regions (CG's versioned iterates)
        override. Return None to opt out of certification."""
        d = {r.name: r.view.copy() for r in self.live_regions()}
        for k, v in self.scalar_state().items():
            d[f"scalar:{k}"] = v
        return d

    # -- snapshot / fork ---------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Capture the complete mid-run state for the fork sweep engine:
        the emulator snapshot (every region's truth + NVM image + cache
        state + traffic stats) plus host-side scalars (``scalar_state``,
        e.g. CG's rho). Restorable any number of times.

        This is sufficient for all three adapters because every state
        array (CG's versioned p/q/r/z, MM's C_s/C_temp and counters,
        XSBench's macro vector / counters / loop index) lives in
        emulator regions, and sampling is counter-based (SplitMix64 of
        the step index), so there is no live RNG state to carry.
        Workload.step must stay deterministic in (state, i) for forked
        tails to replay exactly — see README "Sweep engine"."""
        return {"emu": self.emu.snapshot(),
                "scalars": dict(self.scalar_state())}

    def restore_snapshot(self, snap: Dict[str, object]) -> None:
        """Reset to a :meth:`snapshot` taken on this instance (in-place:
        regions and the emulator keep their identity)."""
        self.emu.restore(snap["emu"])
        scalars = snap["scalars"]
        if scalars:
            self.restore_scalars(dict(scalars))

    # -- recovery audit ----------------------------------------------------------
    def audit_recovery(self, rec: "RecoveryResult", crash_step: int,
                       torn: bool) -> None:
        """Oracle-side audit of the just-recovered state, called by the
        driver immediately after ``strategy.recover(...)`` (before any
        tail replay or certification). Serving-style workloads override
        this to check the recovered store against the acknowledged
        request prefix and record violation counts in ``rec.info``
        (``durability_violations`` — an acknowledged update is missing
        or stale; ``atomicity_violations`` — partially-applied state is
        reader-visible), which ``classify_recovery`` maps to the
        ``durability_violation`` / ``atomicity_violation`` classes.

        Must be deterministic in the recovered state and side-effect
        free on regions/traffic (read via uncharged ``.view``s): its
        ``rec.info`` entries are part of the engine-invariance contract.
        The default is a no-op. The batched engine's analytic
        evaluators synthesize RecoveryResults without running live
        recovery, so a workload that overrides this needs a matching
        evaluator that reproduces the audit from the request oracle or
        the crash image (the KV family has one —
        ``batched_engine._KVStateEvaluator`` / ``_KVAdccEvaluator``);
        unknown auditing workloads take the per-cell measure fallback
        instead (``info["batched_fallback"] = "audit-override:..."``)."""

    # -- ADCC hooks -------------------------------------------------------------
    def adcc_before_step(self, i: int) -> None:
        pass

    def adcc_after_step(self, i: int) -> None:
        pass

    def adcc_recover(self, crash_step: int) -> RecoveryResult:
        raise NotImplementedError(
            f"workload {self.name!r} has no ADCC recovery")

    # -- cost model --------------------------------------------------------------
    def step_cost_profile(self) -> costmodel.StepCostProfile:
        raise NotImplementedError

    def _check_mode(self, mode: str) -> None:
        if mode not in ("adcc", "plain"):
            raise ValueError(f"unknown workload mode {mode!r}")


# ---------------------------------------------------------------------------
# input caches (sweep() runs many cells over identical problem inputs)
# ---------------------------------------------------------------------------

_SPD_CACHE: Dict[Tuple[int, int, int], Tuple[object, np.ndarray]] = {}
_CG_ORACLE_CACHE: Dict[Tuple[int, int, int, int], np.ndarray] = {}
_MM_CACHE: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}


def _spd_system(n: int, nnz: int, seed: int):
    key = (n, nnz, seed)
    if key not in _SPD_CACHE:
        _SPD_CACHE[key] = make_spd_system(n, nnz_per_row=nnz, seed=seed)
    return _SPD_CACHE[key]


def _cg_oracle(n: int, nnz: int, seed: int, iters: int) -> np.ndarray:
    key = (n, nnz, seed, iters)
    if key not in _CG_ORACLE_CACHE:
        A, b = _spd_system(n, nnz, seed)
        _CG_ORACLE_CACHE[key] = plain_cg(A, b, iters)
    return _CG_ORACLE_CACHE[key]


def _mm_inputs(n: int, seed: int):
    key = (n, seed)
    if key not in _MM_CACHE:
        rng = np.random.default_rng(seed)
        A = rng.uniform(-1, 1, (n, n))
        B = rng.uniform(-1, 1, (n, n))
        _MM_CACHE[key] = (A, B, A @ B)
    return _MM_CACHE[key]


# ---------------------------------------------------------------------------
# CG
# ---------------------------------------------------------------------------

class CGWorkload(Workload):
    """Conjugate gradient (paper §III.B)."""

    name = "cg"

    def __init__(self, n: int = 2048, iters: int = 12, nnz_per_row: int = 8,
                 seed: int = 0, emulate_reads: bool = True,
                 impl: Optional[ADCC_CG] = None):
        super().__init__()
        if impl is not None:
            n, iters = impl.A.n, impl.iters
        self.n, self.iters = n, iters
        self.nnz_per_row, self.seed = nnz_per_row, seed
        self.emulate_reads = emulate_reads
        self._impl = impl
        # a pre-built impl carries its own A/b; the (n, nnz, seed) cache
        # would regenerate a *different* system for the oracle
        self._ext_inputs = impl is not None
        self._oracle: Optional[np.ndarray] = None
        self._rho = 0.0

    def params(self):
        return {"n": self.n, "iters": self.iters, "seed": self.seed}

    def setup(self, cfg, mode):
        self._check_mode(mode)
        self.mode = mode
        if self._impl is not None:   # legacy bridge: pre-built ADCC_CG
            if mode != "adcc":
                raise ValueError("pre-built ADCC_CG implies adcc mode")
            self.A, self.b = self._impl.A, self._impl.b
            self._rho = self._impl._init_iterates()
            return
        self.A, self.b = _spd_system(self.n, self.nnz_per_row, self.seed)
        if mode == "adcc":
            self._impl = ADCC_CG(self.A, self.b, self.iters, cfg,
                                 emulate_reads=self.emulate_reads)
            self._rho = self._impl._init_iterates()
            return
        # plain mode: the unmodified algorithm over single-copy regions
        self._emu = CrashEmulator(cfg or NVMConfig())
        emu = self._emu
        self._rA = emu.alloc("A.data", self.A.data.shape, np.float64,
                             init=self.A.data, sector_lines=16)
        self._rAi = emu.alloc("A.indices", self.A.indices.shape, np.int32,
                              init=self.A.indices, sector_lines=16)
        self._rb = emu.alloc("b", self.b.shape, np.float64, init=self.b,
                             sector_lines=16)
        for reg in (self._rA, self._rAi, self._rb):
            reg.flush()
        self._rp = emu.alloc("p", (self.n,), np.float64, sector_lines=4)
        self._rq = emu.alloc("q", (self.n,), np.float64, sector_lines=4)
        self._rr = emu.alloc("r", (self.n,), np.float64, sector_lines=4)
        self._rz = emu.alloc("z", (self.n,), np.float64, sector_lines=4)
        self.reset()

    @property
    def emu(self):
        return self._impl.emu if self.mode == "adcc" else self._emu

    @property
    def n_steps(self):
        return self.iters

    def _touch_matvec_reads(self):
        if self.emulate_reads:
            self.emu.read("A.data", 0, self.A.data.shape[0])
            self.emu.read("A.indices", 0, self.A.indices.shape[0])

    def step(self, i):
        if self.mode == "adcc":
            self._rho = self._impl._iterate(i, self._rho)
            return
        self._touch_matvec_reads()
        p = self._rp[...]
        q = _sym_matvec(self.A, p)
        self._rq[...] = q
        pq = float(p @ q)
        if pq <= 0.0 or self._rho == 0.0:
            return  # converged: iterates carry forward unchanged
        alpha = self._rho / pq
        self._rz[...] = self._rz[...] + alpha * p
        r_new = self._rr[...] - alpha * q
        self._rr[...] = r_new
        rho_new = float(r_new @ r_new)
        beta = rho_new / self._rho
        self._rho = rho_new
        self._rp[...] = r_new + beta * p

    def reset(self):
        if self.mode == "adcc":
            self._rho = self._impl._init_iterates()
            return
        self._rz[...] = np.zeros(self.n)
        self._rq[...] = np.zeros(self.n)
        self._rr[...] = self.b
        self._rp[...] = self.b
        self._rho = float(self.b @ self.b)

    def live_regions(self):
        if self.mode == "adcc":
            return [self._impl.p.region, self._impl.q.region,
                    self._impl.r.region, self._impl.z.region]
        return [self._rp, self._rq, self._rr, self._rz]

    def scalar_state(self):
        return {"rho": self._rho}

    def restore_scalars(self, scalars):
        self._rho = float(scalars["rho"])

    # -- ADCC --------------------------------------------------------------------
    def adcc_recover(self, crash_step):
        impl = self._impl
        outcome = impl.recover(upper_iter=impl.counter.nvm_value())
        restart = outcome.restart_point
        if restart >= 0:
            impl.p.set(restart + 1, impl.p.nvm_version(restart + 1))
            impl.q.set(restart, impl.q.nvm_version(restart))
            impl.r.set(restart + 1, impl.r.nvm_version(restart + 1))
            impl.z.set(restart + 1, impl.z.nvm_version(restart + 1))
            r_cur = impl.r.get(restart + 1)
            self._rho = float(r_cur @ r_cur)
            resume = restart + 1
            lost = crash_step - restart
        else:
            self._rho = impl._init_iterates()
            resume = 0
            lost = crash_step + 1
        return RecoveryResult(
            resume_step=resume, restart_point=restart,
            detect_seconds=outcome.detection_seconds,
            redo_steps=crash_step + 1 - resume, steps_lost=lost,
            from_scratch=restart < 0,
            info={"recovery": outcome, "iterations_lost": lost,
                  # the invariant scan rejected >= 1 candidate version:
                  # it positively identified inconsistent (torn) state
                  "torn_flagged": outcome.candidates_tested > 1})

    def restart_digest(self, restart_point):
        if self.mode != "adcc":
            return super().restart_digest(restart_point)
        # live state is the version-indexed iterate views, not the whole
        # versioned regions (older/newer slots legitimately differ from
        # the golden prefix after a torn crash); uncharged truth reads
        impl, j = self._impl, restart_point
        return {"p": impl.p.region.view[j + 1].copy(),
                "q": impl.q.region.view[j].copy(),
                "r": impl.r.region.view[j + 1].copy(),
                "z": impl.z.region.view[j + 1].copy(),
                "scalar:rho": self._rho}

    def step_cost_profile(self):
        return costmodel.cg_step_profile(self.n, self.emu.cfg.line_bytes)

    def finalize(self):
        if self.mode == "adcc":
            z = self._impl.z.get(self.iters)
        else:
            z = self._rz[...]
        if self._oracle is None:
            self._oracle = (plain_cg(self.A, self.b, self.iters)
                            if self._ext_inputs else
                            _cg_oracle(self.n, self.nnz_per_row, self.seed,
                                       self.iters))
        oracle = self._oracle
        max_err = float(np.max(np.abs(z - oracle)))
        bnorm = float(np.linalg.norm(self.b)) + 1e-300
        resid = float(np.linalg.norm(self.b - _sym_matvec(self.A, z))) / bnorm
        scale = max(1.0, float(np.max(np.abs(oracle))))
        return FinalReport(
            metrics={"max_abs_err": max_err, "rel_residual": resid},
            correct=max_err <= 1e-7 * scale,
            info={"z": z})


# ---------------------------------------------------------------------------
# ABFT matrix multiplication
# ---------------------------------------------------------------------------

class MMWorkload(Workload):
    """Two-loop ABFT matmul (paper §III.C) / plain rank-k-update matmul."""

    name = "mm"

    def __init__(self, n: int = 128, k: int = 32, seed: int = 0,
                 impl: Optional[ABFTMatmul] = None):
        super().__init__()
        if impl is not None:
            n, k = impl.n, impl.k
        self.n, self.k, self.seed = n, k, seed
        self._impl = impl

    def params(self):
        return {"n": self.n, "k": self.k, "seed": self.seed}

    def setup(self, cfg, mode):
        self._check_mode(mode)
        self.mode = mode
        if self._impl is not None:
            if mode != "adcc":
                raise ValueError("pre-built ABFTMatmul implies adcc mode")
            self.A_np, self.B_np = self._impl.A, self._impl.B
            self._oracle = self.A_np @ self.B_np
            return
        self.A_np, self.B_np, self._oracle = _mm_inputs(self.n, self.seed)
        if mode == "adcc":
            self._impl = ABFTMatmul(self.A_np, self.B_np, self.k, cfg)
            return
        self._emu = CrashEmulator(cfg or NVMConfig())
        emu = self._emu
        n = self.n
        self._rA = emu.alloc("A", (n, n), np.float64, init=self.A_np,
                             sector_lines=16)
        self._rB = emu.alloc("B", (n, n), np.float64, init=self.B_np,
                             sector_lines=16)
        self._rA.flush(); self._rB.flush()
        self._rC = emu.alloc("C", (n, n), np.float64, sector_lines=8)

    @property
    def emu(self):
        return self._impl.emu if self.mode == "adcc" else self._emu

    @property
    def nchunks(self):
        return self.n // self.k

    @property
    def n_steps(self):
        if self.mode == "adcc":
            return self._impl.nchunks + len(self._impl.row_blocks)
        return self.nchunks

    def phases(self):
        if self.mode == "adcc":
            nc = self._impl.nchunks
            return {"loop1": range(nc), "loop2": range(nc, self.n_steps)}
        return {"loop1": range(self.nchunks)}

    def step(self, i):
        if self.mode == "adcc":
            nc = self._impl.nchunks
            if i < nc:
                self._impl._loop1_chunk(i)
            else:
                self._impl._loop2_block(i - nc)
            return
        n, k = self.n, self.k
        self.emu.read("A", 0, n * n)
        self.emu.read("B", i * k * n, (i + 1) * k * n)
        acc = self._rC[...]
        block = self.A_np[:, i * k:(i + 1) * k] @ self.B_np[i * k:(i + 1) * k, :]
        self._rC[...] = acc + block

    def reset(self):
        if self.mode == "adcc":
            # versioned-by-construction layout: recomputing chunk s simply
            # overwrites C_s, so scratch restart = run every step again
            return
        self._rC[...] = np.zeros((self.n, self.n))

    def live_regions(self):
        if self.mode == "adcc":
            return list(self._impl.C_s) + [self._impl.C_temp]
        return [self._rC]

    # -- ADCC --------------------------------------------------------------------
    def adcc_recover(self, crash_step):
        impl = self._impl
        nc = impl.nchunks
        # re-executions run with replay=True: the persisted progress
        # counter stays pinned at its crash-time value, so a nested crash
        # anywhere inside recovery re-enters with the same scan range and
        # the retry provably lands on the same state (idempotence).
        if crash_step < nc:
            bad, corrected, detect = impl._recover_loop1()
            for sb in bad:
                impl._loop1_chunk(sb, replay=True)
            lost, crashed_in = len(bad), "loop1"
        else:
            blocks_done = crash_step - nc + 1
            bad_chunks, corrected, d1 = impl._recover_loop1()
            for sb in bad_chunks:
                impl._loop1_chunk(sb, replay=True)
            bad_blocks, d2 = impl._recover_loop2(blocks_done)
            detect = d1 + d2
            for bb in bad_blocks:
                impl._loop2_block(bb, replay=True)
            lost, crashed_in = len(bad_blocks), "loop2"
        return RecoveryResult(
            resume_step=crash_step + 1, restart_point=crash_step,
            detect_seconds=detect, redo_steps=lost, steps_lost=lost,
            info={"crashed_in": crashed_in, "chunks_lost": lost,
                  "corrected_elements": corrected,
                  # checksums flagged bad chunks/blocks or corrected
                  # elements: the ABFT machinery caught torn state
                  "torn_flagged": lost > 0 or corrected > 0})

    def step_cost_profile(self):
        return costmodel.mm_step_profile(self.n, self.emu.cfg.line_bytes)

    def finalize(self):
        if self.mode == "adcc":
            from ..core import abft
            C = abft.strip(self._impl.C_temp.view.copy())
        else:
            C = self._rC[...]
        max_err = float(np.max(np.abs(C - self._oracle)))
        scale = max(1.0, float(np.max(np.abs(self._oracle))))
        return FinalReport(
            metrics={"max_error": max_err},
            correct=max_err <= 1e-8 * scale,
            info={"C": C})


# ---------------------------------------------------------------------------
# XSBench Monte-Carlo lookups
# ---------------------------------------------------------------------------

class XSBenchWorkload(Workload):
    """Monte-Carlo cross-section lookups (paper §III.D).

    ``policy`` selects the *ADCC design* ("selective" is the paper's fix,
    "basic" its Fig.-10 failing scheme, "every" the 16%-overhead
    strawman); it only matters under the ``adcc`` strategy.
    """

    name = "xsbench"

    def __init__(self, lookups: int = 1500, grid_points: int = 2000,
                 n_nuclides: int = 8, n_materials: int = 6,
                 max_nuclides_per_material: int = 4,
                 flush_every_frac: float = 0.01, seed: int = 7,
                 policy: str = "selective",
                 impl: Optional[ADCC_XSBench] = None):
        super().__init__()
        self.policy = policy if impl is None else impl.policy
        if impl is not None:
            self._cfg = impl.cfg
        else:
            self._cfg = XSBenchConfig(
                n_nuclides=n_nuclides, grid_points=grid_points,
                n_materials=n_materials,
                max_nuclides_per_material=max_nuclides_per_material,
                lookups=lookups, flush_every_frac=flush_every_frac,
                seed=seed)
        self._impl = impl

    def params(self):
        c = self._cfg
        return {"lookups": c.lookups, "grid_points": c.grid_points,
                "policy": self.policy, "seed": c.seed}

    def setup(self, cfg, mode):
        self._check_mode(mode)
        self.mode = mode
        if self._impl is None:
            # plain mode never flushes, so the impl policy is irrelevant;
            # reuse the same lookup kernel either way
            self._impl = ADCC_XSBench(
                self._cfg, cfg,
                policy=self.policy if self.policy != "none" else "selective")

    @property
    def emu(self):
        return self._impl.emu

    @property
    def n_steps(self):
        return self._cfg.lookups

    def step(self, i):
        self._impl._lookup(i)

    def reset(self):
        impl = self._impl
        impl._macro[...] = np.zeros(impl._macro.shape)
        for c in impl._counters:
            c[0] = 0
        impl._index[0] = 0

    def live_regions(self):
        impl = self._impl
        return [impl._macro] + list(impl._counters)

    # -- ADCC --------------------------------------------------------------------
    def adcc_before_step(self, i):
        if self.policy == "basic":
            impl = self._impl
            impl._index[0] = i
            impl._index.flush()

    def adcc_after_step(self, i):
        impl = self._impl
        if self.policy == "every":
            impl._flush_critical(i + 1)
        elif self.policy == "selective" and (i + 1) % impl.flush_every == 0:
            impl._flush_critical(i + 1)

    def adcc_recover(self, crash_step):
        impl = self._impl
        crashed_lookups = crash_step + 1
        resume_i = int(impl._index.nvm[0])
        counted = int(sum(int(c.view[0]) for c in impl._counters))
        lost = max(0, resume_i - counted) + (crashed_lookups - resume_i)
        # counter/index mismatch is the counters' torn-state signal.
        # counted < resume_i: updates lost (Fig. 10). counted > resume_i:
        # increments beyond the persisted index survived a torn crash —
        # replay from resume_i will RE-count them, so the recovered
        # state is positively corrupt (no repair exists: the extra
        # counts cannot be attributed and un-counted)
        return RecoveryResult(
            resume_step=resume_i, restart_point=resume_i - 1,
            redo_steps=crashed_lookups - resume_i, steps_lost=lost,
            from_scratch=resume_i == 0,
            info={"iterations_lost": lost,
                  "torn_flagged": counted != resume_i,
                  "state_corrupt": counted > resume_i})

    def step_cost_profile(self):
        line = self.emu.cfg.line_bytes
        if self.policy == "basic":
            # index-only flush, every lookup (Fig. 10's failing scheme)
            return costmodel.StepCostProfile(
                ckpt_bytes=8, log_bytes=line, adcc_bytes=line,
                adcc_lines=1, interval_steps=1, hdd_latency_s=5e-3)
        interval = 1 if self.policy == "every" else self._impl.flush_every
        return costmodel.xsbench_step_profile(line, interval_steps=interval)

    def finalize(self):
        impl = self._impl
        counts = np.array([int(c.view[0]) for c in impl._counters])
        total = max(1, int(counts.sum()))
        fractions = counts / total
        spread = float(np.max(fractions) - np.min(fractions))
        return FinalReport(
            metrics={"counts_total": float(counts.sum()),
                     "fraction_spread": spread},
            correct=int(counts.sum()) == self._cfg.lookups,
            info={"counts": counts, "fractions": fractions,
                  "macro_xs": impl._macro.view.copy()})


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

WORKLOADS: Dict[str, Callable[..., Workload]] = {
    "cg": CGWorkload,
    "mm": MMWorkload,
    "xsbench": XSBenchWorkload,
}


def register_workload(name: str, factory: Callable[..., Workload], *,
                      override: bool = False) -> None:
    """Register a workload factory under ``name``.

    Re-registering an existing name raises (a silent overwrite would
    make every subsequent sweep spec mean something else) unless the
    factory is identical (idempotent re-import) or ``override=True``.
    """
    if not override and name in WORKLOADS and WORKLOADS[name] is not factory:
        raise ValueError(
            f"workload {name!r} already registered "
            f"(registered: {sorted(WORKLOADS)}); pass override=True "
            f"to replace it")
    WORKLOADS[name] = factory


def make_workload(spec) -> Workload:
    """spec: Workload instance | "name" | ("name", {params})."""
    if isinstance(spec, Workload):
        return spec
    if isinstance(spec, str):
        name, kwargs = spec, {}
    else:
        name, kwargs = spec
    if name not in WORKLOADS:
        raise unknown_name_error("workload", name, WORKLOADS)
    return WORKLOADS[name](**dict(kwargs))
