"""ndarray facade over the crash emulator.

A :class:`PersistentRegion` behaves like a numpy array whose loads and
stores are routed through the emulated volatile cache, so that after
``CrashEmulator.crash()`` the region's contents silently revert to
whatever had reached NVM. Slicing covers the common access shapes used
by the paper's three algorithms (whole-array, 1-D ranges, row blocks of
2-D arrays, scalar elements).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["PersistentRegion"]


def _flat_span(shape: Tuple[int, ...], index) -> Tuple[int, int]:
    """Map a (supported) index into a [lo, hi) span over the flattened
    buffer. Supported: Ellipsis/':', int, slice, and tuples thereof where
    only the *leading* axes are restricted (row-major contiguity)."""
    if index is Ellipsis:
        return 0, int(np.prod(shape))
    if not isinstance(index, tuple):
        index = (index,)
    lo = 0
    span = int(np.prod(shape))
    for ax, idx in enumerate(index):
        extent = shape[ax]
        span //= extent
        if isinstance(idx, (int, np.integer)):
            lo += (int(idx) % extent) * span
        elif isinstance(idx, slice):
            start, stop, step = idx.indices(extent)
            if step != 1:
                raise IndexError("strided slices unsupported on PersistentRegion")
            lo += start * span
            # a slice freezes the span to (stop-start) * inner; further
            # restriction only allowed if this slice is the last axis given
            if ax != len(index) - 1 and (stop - start) != extent and any(
                not (isinstance(j, slice) and j == slice(None)) for j in index[ax + 1:]
            ):
                raise IndexError("non-contiguous multi-axis slicing unsupported")
            return lo, lo + (stop - start) * span
        elif idx is Ellipsis:
            return lo, lo + span * extent
        else:
            raise IndexError(f"unsupported index component {idx!r}")
    # all given axes were ints
    return lo, lo + span


class PersistentRegion:
    """An array living in emulated NVM behind an emulated volatile cache."""

    def __init__(self, emu, name: str, shape: Tuple[int, ...], dtype: np.dtype):
        self._emu = emu
        self.name = name
        self.shape = shape
        self.dtype = dtype

    # -- views -----------------------------------------------------------------
    @property
    def view(self) -> np.ndarray:
        """Latest program-visible values (truth). Mutating this directly
        bypasses cache accounting — use __setitem__ instead."""
        return self._emu.truth_flat(self.name).reshape(self.shape)

    @property
    def nvm(self) -> np.ndarray:
        """What would survive a crash right now."""
        return self._emu.post_crash_view(self.name)

    # -- array protocol ----------------------------------------------------------
    def __getitem__(self, index) -> np.ndarray:
        lo, hi = _flat_span(self.shape, index)
        self._emu.read(self.name, lo, hi)
        return self.view[index]

    def __setitem__(self, index, value) -> None:
        lo, hi = _flat_span(self.shape, index)
        self.view[index] = value
        self._emu.write(self.name, lo, hi)

    def __array__(self, dtype=None):
        out = self.__getitem__(Ellipsis)
        return out.astype(dtype) if dtype is not None else out

    # -- persistence ops --------------------------------------------------------
    def flush(self, index=Ellipsis) -> None:
        """CLFLUSH the lines covering ``index``."""
        lo, hi = _flat_span(self.shape, index)
        self._emu.flush(self.name, lo, hi)

    def nbytes_span(self, index=Ellipsis) -> int:
        lo, hi = _flat_span(self.shape, index)
        return (hi - lo) * self.dtype.itemsize
