"""Beyond-paper figure: recovery under fire — nested-crash and
media-fault campaigns over every (workload, strategy) pair.

The torn-write figure (fig_torn) asks what a mechanism does with an
inconsistent *crash image*. This figure asks the two harder questions a
real NVM deployment adds on top:

* **nested crashes** — the machine crashes again *while recovery is
  running* (``FaultSpec(nested_after=k)`` re-crashes after k recovery
  actions, optionally with its own torn line survival). Each cell is
  certified against the *golden* single-crash cell — same crash, no
  fault — by restart point and state digest: ``recovery_idempotent``
  means the retried recovery provably landed on the same state
  (re-entrancy, proven not assumed); ``recovery_diverged`` means the
  mid-recovery crash changed the outcome — the crash-unsafe-recovery
  class WITCHER hunts. ABFT-MM's ADCC recovery used to *diverge* here
  (it re-executed compute chunks while advancing its progress counter
  mid-recovery, so a second crash stranded progress the data didn't
  back); recovery now replays chunks with the counter pinned at its
  crash-time value, so MM-adcc joins the wholesale mechanisms'
  rollback / restore paths under the zero-``recovery_diverged``
  coverage-floor gate (the old pinned-diverged finding, flipped —
  not deleted).

* **silent media faults** — a seeded poisoned-line/bit-flip injector
  (``FaultSpec(poison_words=w)``) corrupts the post-crash image with
  no torn-ness to flag it. Recovery must *detect* this through the
  integrity machinery it already has (CG's invariant scan, ABFT's
  checksums, the undo log's entry CRCs, KV's row checksums):
  ``fault_detected`` vs ``fault_silent`` (corruption reached the
  resumed run with no signal). Gate: the ADCC strategies produce zero
  ``fault_silent`` cells on the covered regions — the paper's claim
  that algorithm knowledge doubles as an integrity check, made
  falsifiable. The wholesale mechanisms split as the taxonomy
  predicts: checkpoint/shadow restore *heals* poison wholesale
  (harmless classes), and the undo log — whose commit-boundary cells
  used to let poison on committed spans through silently (the old
  pinned coverage hole) — now stamps a crc32 per committed span and
  validates the post-crash image against them, so it rides the same
  zero-``fault_silent`` floor on its covered spans.

Campaign sweeps run ``mode="measure"`` under the full dense-gate stack
(``run_dense_cross_checks``: sharded == serial cell-for-cell, every
measure field == full execution) at every size, plus the
campaign-specific gates above. ``--chaos`` additionally runs the
self-healing harness gate: a sharded sweep with one injected worker
kill and one injected hang must complete — via retry and re-dispatch —
cell-for-cell identical to the serial sweep. Sharded campaign sweeps
journal completed shards to ``BENCH_faults.partial.jsonl`` so an
interrupted run resumes instead of restarting.
"""

from __future__ import annotations

import os
from collections import Counter
from typing import Dict, Iterator, List, Tuple

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, FaultSpec, sweep

from .common import ART, Row, write_json

ARTIFACT = "fig_faults.json"
BENCH_JSON = os.path.join(ART, "BENCH_faults.json")
JOURNAL = os.path.join(ART, "BENCH_faults.partial.jsonl")

SEED = 47

HPC_WORKLOADS = (
    ("cg", {"n": 2048, "iters": 12, "seed": 5}),
    ("mm", {"n": 64, "k": 16, "seed": 2}),
    ("xsbench", {"lookups": 160, "grid_points": 1200, "n_nuclides": 8,
                 "n_materials": 6, "max_nuclides_per_material": 4,
                 "flush_every_frac": 0.05, "seed": 7}),
)
SMOKE_HPC_WORKLOADS = (
    ("cg", {"n": 512, "iters": 8, "seed": 5}),
    ("mm", {"n": 32, "k": 8, "seed": 2}),
    ("xsbench", {"lookups": 80, "grid_points": 600, "n_nuclides": 8,
                 "n_materials": 6, "max_nuclides_per_material": 4,
                 "flush_every_frac": 0.1, "seed": 7}),
)
KV_WORKLOAD = ("kv", {"profile": "etc", "n_steps": 36, "seed": 11})
SMOKE_KV_WORKLOAD = ("kv", {"profile": "etc", "n_steps": 16, "seed": 11})

HPC_STRATEGIES = ("adcc", "undo_log", "checkpoint_nvm@2",
                  "shadow_snapshot@2")
KV_STRATEGIES = ("adcc", "shadow_snapshot@2")

# mechanisms whose recovery is rollback/restore over state they own —
# re-running it after a mid-recovery crash must land on the identical
# outcome, at every crash point (the nested-campaign coverage floor)
WHOLESALE_BASES = ("undo_log", "checkpoint_hdd", "checkpoint_nvm",
                   "checkpoint_nvm_dram", "shadow_snapshot")

# poison scope per workload: the regions the pair's integrity machinery
# actually covers, valid in BOTH plain and adcc modes (FaultSpec globs
# resolve against live-region names at recovery time). cg: all live
# iterate vectors (the invariant scan's domain; the "iter" counter is
# not live and stays clean — a garbage counter would send the backward
# scan out of bounds, a different failure than silent data corruption).
# mm: "C" in plain mode, the checksummed C_s chunks in adcc mode
# (C_temp's loop-1 rows carry no checksum yet — poison there is
# genuinely undetectable and would gate-fail by design, see the
# uncovered-region test). xsbench: the typed tally counters the
# counter/index cross-check covers. kv: the A/B-versioned hash index
# (row checksums); 8 words so the seeded sampler reliably hits
# committed-live slots, not just the inactive A/B halves.
POISON_REGIONS = {"cg": None, "mm": ("C", "C_s*"),
                  "xsbench": ("type_counter_*",), "kv": ("kv.index",)}
POISON_WORDS = {"cg": 2, "mm": 2, "xsbench": 2, "kv": 8}

NESTED_FAULTS = (
    # re-crash after the FIRST recovery action: the hardest re-entrancy
    # point (nothing of attempt 1 is guaranteed complete)
    FaultSpec(nested_after=1, seed=SEED),
    # deeper re-crash, and the second crash is itself torn: half the
    # dirty lines of the interrupted recovery survive
    FaultSpec(nested_after=3, nested_fraction=0.5, seed=SEED + 1),
)


def _fractions(smoke: bool) -> Tuple[float, ...]:
    return (0.35, 0.7) if smoke else (0.2, 0.5, 0.8)


def _nested_plans(smoke: bool) -> Tuple[CrashPlan, ...]:
    plans = [CrashPlan.at_fraction(f, fault=fs)
             for fs in NESTED_FAULTS for f in _fractions(smoke)]
    # a torn first crash + a nested re-crash during its recovery: the
    # compounded case (rollback of a torn image, interrupted)
    plans.append(CrashPlan.at_fraction(0.6, torn=True,
                                       fault=NESTED_FAULTS[0]))
    return tuple(plans)


def _poison_plans(wl_name: str, smoke: bool) -> Tuple[CrashPlan, ...]:
    words = POISON_WORDS[wl_name]
    regions = POISON_REGIONS[wl_name]
    plans = [CrashPlan.at_fraction(f, fault=FaultSpec(
        poison_words=words, seed=SEED + 10 + i, poison_regions=regions))
        for i, f in enumerate(_fractions(smoke))]
    # poison layered on a torn crash image: the detector must separate
    # the two corruption sources (never nested+poison combined — each
    # campaign isolates one fault axis)
    plans.append(CrashPlan.at_fraction(0.6, torn=True, fault=FaultSpec(
        poison_words=words, seed=SEED + 20, poison_regions=regions)))
    return tuple(plans)


def _campaign_sweeps(smoke: bool) -> Iterator[Tuple[str, Dict]]:
    """Every (campaign, sweep-kwargs) this figure runs: both campaigns
    over the HPC matrix and over the KV serving pair. Poison scopes are
    per-workload, so the poison campaign is one sweep per workload."""
    cfg = NVMConfig(cache_bytes=1024 * 1024)
    hpc = SMOKE_HPC_WORKLOADS if smoke else HPC_WORKLOADS
    kv = SMOKE_KV_WORKLOAD if smoke else KV_WORKLOAD
    for wls, strats in ((hpc, HPC_STRATEGIES), ((kv,), KV_STRATEGIES)):
        yield "nested", dict(workloads=wls, strategies=strats,
                             plans=_nested_plans(smoke), cfg=cfg)
        for wl in wls:
            yield "poison", dict(workloads=(wl,), strategies=strats,
                                 plans=_poison_plans(wl[0], smoke), cfg=cfg)


def _base(strategy: str) -> str:
    return strategy.partition("@")[0]


def check_fault_gates(campaign: str, kw: Dict, cells, workers: int) -> None:
    """Campaign gates on top of the shared dense-gate core. Explicit
    raises (not asserts): these are CI gates and must survive
    ``python -O``."""
    from .scenarios_sweep import run_dense_cross_checks

    run_dense_cross_checks(kw, cells, workers)

    crashed = [c for c in cells if c.crash_step is not None]
    for c in crashed:
        key = (c.workload, c.strategy, c.plan, c.crash_step)
        if int(c.info.get("fault_words_injected") or 0) == 0 \
                and int(c.info.get("nested_crashes") or 0) == 0 \
                and "recovery_attempts" not in c.info:
            raise AssertionError(
                f"fault-campaign cell ran without the fault harness: {key}")
        if campaign == "nested":
            # MM-adcc rides the same floor since its replay-pinned
            # counter fix: the old pinned-diverged finding, flipped
            if ((_base(c.strategy) in WHOLESALE_BASES
                    or (_base(c.strategy) == "adcc" and c.workload == "mm"))
                    and c.correctness_class == "recovery_diverged"):
                raise AssertionError(
                    f"recovery diverged under a nested crash (idempotence "
                    f"floor): {key}")
        else:
            if int(c.info.get("fault_words_injected") or 0) == 0:
                raise AssertionError(
                    f"poison cell injected zero words (mis-scoped "
                    f"poison_regions?): {key}")
            # undo_log joined the zero-silent floor when commits began
            # stamping per-span payload crc32s (the old coverage-hole
            # pin, flipped): every campaign poison scope is tx-covered
            if (_base(c.strategy) in ("adcc", "undo_log")
                    and c.correctness_class == "fault_silent"):
                raise AssertionError(
                    f"integrity machinery missed a poisoned-line "
                    f"fault on a covered region: {key}")
    if campaign == "nested":
        # the trap must actually fire somewhere for every strategy whose
        # recovery performs persistent actions — a campaign whose nested
        # crashes never trigger certifies nothing. (KV ADCC recovery is
        # read-mostly: its blind/validate scan only writes when torn
        # rows must be dropped, so it is exempt from the floor.)
        fired = Counter()
        for c in crashed:
            fired[c.strategy] += int(c.info.get("nested_crashes") or 0)
        exempt = {"adcc"} if kw["workloads"][0][0] == "kv" else set()
        for strategy in kw["strategies"]:
            if strategy in exempt:
                continue
            if fired[strategy] == 0:
                raise AssertionError(
                    f"nested campaign never interrupted {strategy!r} "
                    f"recovery (trap count 0 across all cells)")


def check_chaos_gate(smoke: bool) -> int:
    """The self-healing harness gate: shard the nested HPC campaign
    with one injected worker kill and one injected hang; the healed
    sweep must merge cell-for-cell identical to the serial one. Returns
    the cell count (the gate raises on any divergence)."""
    from .scenarios_sweep import full_divergences

    cfg = NVMConfig(cache_bytes=1024 * 1024)
    kw = dict(workloads=SMOKE_HPC_WORKLOADS if smoke else HPC_WORKLOADS,
              strategies=HPC_STRATEGIES, plans=_nested_plans(smoke),
              cfg=cfg)
    serial = sweep(mode="measure", workers=1, **kw)
    chaotic = sweep(mode="measure", workers=2,
                    chaos={0: "kill", 1: "hang"},
                    shard_timeout=30 if smoke else 120,
                    journal=JOURNAL + ".chaos", **kw)
    div = full_divergences(chaotic, serial)
    if div:
        raise AssertionError(
            f"chaos-injected sharded sweep diverged from serial after "
            f"healing: {div[:3]}")
    return len(chaotic)


def run(smoke: bool = None, workers: int = None, mode: str = "measure",
        chaos: bool = False) -> List[Row]:
    from .scenarios_sweep import resolve_sweep_env

    smoke, workers = resolve_sweep_env(smoke, workers)
    all_cells = []
    census: Dict[Tuple, Counter] = {}
    resilience: Dict[Tuple, Counter] = {}
    matrices = []
    for campaign, kw in _campaign_sweeps(smoke):
        cells = sweep(mode=mode, workers=workers, journal=JOURNAL, **kw)
        check_fault_gates(campaign, kw, cells, workers)
        matrices.append({
            "campaign": campaign,
            "workloads": [[w, p] for w, p in kw["workloads"]],
            "strategies": list(kw["strategies"]),
            "plans": [p.describe() for p in kw["plans"]],
        })
        for c in cells:
            all_cells.append((campaign, c))
            if c.crash_step is None:
                continue
            key = (campaign, c.workload, c.strategy)
            census.setdefault(key, Counter())[c.correctness_class] += 1
            r = resilience.setdefault(key, Counter())
            r["attempts"] += int(c.info.get("recovery_attempts") or 0)
            r["nested_crashes"] += int(c.info.get("nested_crashes") or 0)
            r["fault_words"] += int(c.info.get("fault_words_injected") or 0)

    rows = []
    for key in sorted(census):
        campaign, wl, strat = key
        cls = census[key]
        res = resilience[key]
        total = sum(cls.values())
        prefix = f"fig_faults/{campaign}/{wl}/{strat}"
        rows.append(Row(f"{prefix}/cells", total,
                        " ".join(f"{k}={v}" for k, v in sorted(cls.items()))))
        if campaign == "nested":
            rows.append(Row(
                f"{prefix}/idempotent_fraction",
                cls.get("recovery_idempotent", 0)
                / max(1, sum(v for k, v in cls.items()
                             if k.startswith("recovery_"))),
                f"diverged={cls.get('recovery_diverged', 0)} "
                f"re-crashes={res['nested_crashes']} "
                f"attempts={res['attempts']}"))
        else:
            rows.append(Row(
                f"{prefix}/silent_cells", cls.get("fault_silent", 0),
                f"detected={cls.get('fault_detected', 0)} "
                f"words_injected={res['fault_words']}"))

    chaos_cells = None
    if chaos:
        chaos_cells = check_chaos_gate(smoke)
        rows.append(Row("fig_faults/chaos/cells", chaos_cells,
                        "kill+hang injected; healed sweep == serial"))

    write_json(BENCH_JSON, {
        "schema": "repro.scenarios.faults/v1",
        "smoke": bool(smoke),
        "matrices": matrices,
        "cells": [dict(campaign=camp, **c.to_json_dict())
                  for camp, c in all_cells],
        "census": [
            {"campaign": k[0], "workload": k[1], "strategy": k[2],
             "classes": dict(census[k]), **dict(resilience[k])}
            for k in sorted(census)],
        "chaos_gate_cells": chaos_cells,
    })
    rows.append(Row("fig_faults/summary/cells", len(all_cells),
                    f"artifact={BENCH_JSON}"))
    return rows


def main(argv=None) -> None:
    """``dense_figure_cli`` plus the ``--chaos`` leg (the self-healing
    harness gate is opt-in: it re-runs the nested campaign twice)."""
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI size axis (gates run at every size)")
    ap.add_argument("--workers", type=int, default=None,
                    help="processes for the sweep "
                         "(default: REPRO_SWEEP_WORKERS or 2)")
    ap.add_argument("--mode", default="measure",
                    choices=["measure", "batched"],
                    help="cell evaluation mode (default: measure; fault "
                         "cells always evaluate per-cell)")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the chaos gate: sharded sweep with an "
                         "injected worker kill + hang must equal serial")
    args = ap.parse_args(argv)
    emit(run(smoke=args.smoke or None, workers=args.workers,
             mode=args.mode, chaos=args.chaos),
         save_as=ARTIFACT)


if __name__ == "__main__":
    main()
