"""Config system: model / mesh / train / shape configs.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published configuration) built on these dataclasses.
``ModelConfig.reduced()`` derives the CPU smoke-test variant (same family
switches, tiny dims). Input-shape cells (train_4k / prefill_32k /
decode_32k / long_500k) are defined here once and reused by the dry-run,
roofline, and launcher.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "MeshConfig", "TrainConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # -- MoE ------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert FFN width
    capacity_factor: float = 1.25

    # -- MLA (DeepSeek-style latent attention) ----------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0          # hybrid: shared attn block every k ssm layers

    # -- positional / misc ---------------------------------------------------
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = ()   # qwen2-vl M-RoPE
    causal: bool = True          # False => encoder-only (no decode shapes)
    embed_inputs: bool = True    # False => frontend stub supplies embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    n_patches: int = 1024        # vlm: image patch count inside the sequence

    # -- dtypes ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables padded to a multiple of 256 so the vocab
        dim shards evenly under any plausible TP degree (standard
        framework practice); logits are sliced back to ``vocab_size``."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def is_ssm_family(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: preserves every family switch, shrinks dims."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(3, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.n_experts else 0,
            kv_lora_rank=32 if self.use_mla else 0,
            qk_nope_dim=32 if self.use_mla else self.qk_nope_dim,
            qk_rope_dim=16 if self.use_mla else self.qk_rope_dim,
            v_head_dim=32 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=16 if self.ssm_state else self.ssm_chunk,
            attn_every=2 if self.attn_every else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            n_patches=16 if self.family == "vlm" else self.n_patches,
        )

    # -- parameter counting (for MODEL_FLOPS = 6 N D) ---------------------------
    def param_count(self, active_only: bool = False) -> int:
        D, hd = self.d_model, self.resolved_head_dim
        H, KV, L = self.n_heads, self.n_kv_heads, self.n_layers
        embed = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        if not self.embed_inputs:
            embed = self.vocab_size * D  # output head only
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            if self.use_mla:
                r = self.kv_lora_rank
                qk = self.qk_nope_dim + self.qk_rope_dim
                attn = (D * H * qk                       # q proj
                        + D * (r + self.qk_rope_dim)     # kv compress + k_rope
                        + r * H * (self.qk_nope_dim + self.v_head_dim)
                        + H * self.v_head_dim * D)       # o proj
            else:
                attn = D * H * hd + 2 * D * KV * hd + H * hd * D
            if self.n_experts:
                experts = self.experts_per_token if active_only else self.n_experts
                ff = 3 * D * self.moe_d_ff * (experts + self.n_shared_experts)
                ff += D * self.n_experts  # router
            else:
                ff = 3 * D * self.d_ff
            per_layer = attn + ff
        elif self.family == "ssm":
            d_in = self.ssm_expand * D
            nh = d_in // self.ssm_head_dim
            per_layer = (D * (2 * d_in + 2 * self.ssm_state + nh)
                         + d_in * D + self.ssm_conv_width * (d_in + 2 * self.ssm_state))
        elif self.family == "hybrid":
            d_in = self.ssm_expand * D
            nh = d_in // self.ssm_head_dim
            mamba = (D * (2 * d_in + 2 * self.ssm_state + nh)
                     + d_in * D + self.ssm_conv_width * (d_in + 2 * self.ssm_state))
            shared_attn = (D * H * hd + 2 * D * KV * hd + H * hd * D
                           + 3 * D * self.d_ff)  # one shared block
            return embed + L * mamba + shared_attn
        return embed + L * per_layer


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 10_000
    optimizer: str = "adamw"     # adamw | adafactor
    remat: str = "dots"          # none | dots | full
    fsdp: bool = True            # ZeRO-shard params/opt over the data axis
    grad_compression: str = "none"  # none | int8
    seed: int = 0
