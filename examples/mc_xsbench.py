"""Paper §III.D end to end: Monte-Carlo XSBench with selective flushing.

Runs the cross-section lookup benchmark three ways on identical random
streams: no crash, crash+basic restart (loses counts — the paper's
Fig. 10 surprise), crash+selective flush (bitwise-correct, Fig. 12).

    PYTHONPATH=src python examples/mc_xsbench.py
"""

import numpy as np

from repro.algorithms.xsbench import ADCC_XSBench, XSBenchConfig
from repro.core.nvm import NVMConfig


def main() -> None:
    cfg = XSBenchConfig(lookups=60_000, grid_points=20_000)
    nvm = NVMConfig(cache_bytes=2 * 1024 * 1024, replacement="fifo")
    crash_at = cfg.lookups // 10   # 10% in, as in the paper

    ok = ADCC_XSBench(cfg, nvm, policy="selective").run()
    basic = ADCC_XSBench(cfg, nvm, policy="basic").run(crash_at=crash_at)
    sel = ADCC_XSBench(cfg, nvm, policy="selective").run(crash_at=crash_at)

    print("interaction-type fractions (%):")
    print(f"  {'type':>6s} {'no crash':>9s} {'basic':>9s} {'selective':>10s}")
    for t in range(5):
        print(f"  {t+1:>6d} {100*ok.fractions[t]:>9.3f} "
              f"{100*basic.fractions[t]:>9.3f} {100*sel.fractions[t]:>10.3f}")
    print(f"\nbasic restart: lost {cfg.lookups - int(basic.counts.sum())} "
          f"counts ({basic.iterations_lost} iterations of stale counters)")
    print(f"selective flush: counts bitwise-identical to no-crash run: "
          f"{np.array_equal(sel.counts, ok.counts)} "
          f"(loss bound = {int(cfg.lookups * cfg.flush_every_frac)} lookups)")


if __name__ == "__main__":
    main()
