"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps with the full production stack — sharded train step, ADCC
ledger + async slots, straggler monitor, synthetic pipeline — and report
the loss curve + fault-tolerance overhead.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

The ~100M config is mamba2-130m at full width but trimmed depth for CPU
wall-time; pass --full for the real 24-layer config.
"""

import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs.base import TrainConfig
from repro.launch.train import ADCCTrainer
from repro.models.registry import get_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")
    if not args.full:
        cfg = dataclasses.replace(cfg, n_layers=6)   # ~90M params, CPU-sized
    print(f"== {cfg.name}: {cfg.param_count()/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    tcfg = TrainConfig(remat="none", total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20),
                       learning_rate=1e-3)
    wd = tempfile.mkdtemp(prefix="train_e2e_")
    tr = ADCCTrainer(cfg, tcfg, wd, batch=args.batch, seq=args.seq,
                     slot_every=25)
    res = tr.run(args.steps, log_every=20)

    first = float(np.mean(res.losses[:10]))
    last = float(np.mean(res.losses[-10:]))
    med = float(np.median(res.step_seconds[2:]))
    print(f"\n== loss {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first - 0.05 else 'check config'})")
    print(f"== median step {med*1e3:.0f} ms; straggler flags: "
          f"{tr.monitor.flagged_steps}")
    print(f"== ledger + slots in {wd} (delete when done)")


if __name__ == "__main__":
    main()
