"""repro.core — the paper's primary contribution: algorithm-directed
crash consistence (ADCC) for NVM, adapted to a JAX/TPU training stack.

Substrate (paper SIII.A):
  nvm, regions            emulated NVM + volatile cache + crash semantics
  backends                pluggable cache emulation: exact per-entry
                          "reference" oracle / batched "vectorized" default
Baselines (paper test cases 2-5):
  checkpoint_baseline     synchronous full-copy checkpoint (hdd/nvm/nvm+dram)
  transactions            PMEM-style undo-log transactions
Algorithm knowledge (paper SIII.B-D):
  abft                    checksum algebra (Eqs. 3-6)
  invariants              invariant registry (orthogonality/residual/checksum)
  recovery                backward-scan restart-point search
  versioned               iteration-versioned persistent arrays
ADCC-for-training (TPU adaptation, DESIGN.md S2-3):
  acc_state, slots        incremental checksums + multi-slot verified recovery

The scenario layer above this package (``repro.scenarios``) composes
these pieces into the unified Workload x ConsistencyStrategy x CrashPlan
experiment matrix: strategies there wrap CheckpointBaseline / TxManager /
the ADCC paths, and run_scenario()/sweep() drive them over the emulator.
"""

from .backends import (
    BACKENDS,
    MemoryBackend,
    ReferenceLRUBackend,
    VectorizedBackend,
    make_backend,
)
from .nvm import CrashEmulator, NVMConfig, NVMStore, TrafficStats, VolatileCache
from .regions import PersistentRegion
from .invariants import (
    ChecksumInvariant,
    InvariantSet,
    OrthogonalityInvariant,
    ResidualInvariant,
    ScalarChecksumInvariant,
)
from .recovery import RecoveryOutcome, backward_scan
from .transactions import TxManager, UndoLogTx
from .checkpoint_baseline import CheckpointBaseline

__all__ = [
    "CrashEmulator", "NVMConfig", "NVMStore", "TrafficStats", "VolatileCache",
    "MemoryBackend", "ReferenceLRUBackend", "VectorizedBackend",
    "BACKENDS", "make_backend",
    "PersistentRegion",
    "ChecksumInvariant", "InvariantSet", "OrthogonalityInvariant",
    "ResidualInvariant", "ScalarChecksumInvariant",
    "RecoveryOutcome", "backward_scan",
    "TxManager", "UndoLogTx", "CheckpointBaseline",
]
