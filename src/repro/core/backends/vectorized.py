"""Batched numpy backend — semantics identical to the reference oracle.

State per region is three flat numpy arrays over *entries* (an entry =
``sector_lines`` cache lines): a presence bitmap, a dirty bitmap, and an
int64 last-touch stamp (insertion stamp under FIFO). Replacement order
lives in a global append-only queue of ``(region, entry, stamp)`` slots
with lazy staleness: a slot is live iff the entry is present and its
stamp still matches (LRU re-touches append a fresh slot, invalidating
the old one). This is exactly an ``OrderedDict`` — but poppable and
appendable in vectorized batches.

An operation over ``[lo, hi)`` decomposes its entry range into
alternating hit/miss *runs* (misses can appear mid-op when eviction
pressure throws out a not-yet-touched entry of the same range — the
queue pop detects those and extends the miss mask, reproducing the
reference's per-entry interleaving). Each run is handled with O(1)
numpy ops: bulk bitmap/stamp updates, bulk queue append, and chunked
queue pops that free exactly the line weight the reference would. Cost
charging follows the invariants in backends/base.py: integer aggregates
per operation, applied once through ``TrafficStats.charge_batch`` — so
traffic stats match the reference bit-for-bit, and the post-crash NVM
image is byte-identical (verified by tests/test_backend_equivalence.py
on randomized traces).

Per-op Python cost is O(#runs + #eviction-chunks) instead of the
reference's O(#entries); contiguous streaming access — the shape of the
paper's CSR matvecs and MC grid lookups — is a single run.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from .base import (LineSurvival, OpAccumulator as _OpAcc, select_survivors,
                   select_survivor_words)

__all__ = ["VectorizedBackend"]

_EVICT_CHUNK = 1024


class _Region:
    __slots__ = ("name", "rid", "truth", "image", "w", "epe", "itemsize",
                 "n_entries", "present", "dirty", "stamp")

    def __init__(self, name: str, rid: int, truth: np.ndarray,
                 image: np.ndarray, sector_lines: int, line_bytes: int):
        self.name = name
        self.rid = rid
        self.truth = truth
        self.image = image
        self.w = sector_lines
        self.itemsize = truth.itemsize
        epl = max(1, line_bytes // truth.itemsize)
        self.epe = epl * sector_lines
        n = truth.shape[0]
        self.n_entries = (n + self.epe - 1) // self.epe
        self.present = np.zeros(self.n_entries, dtype=bool)
        self.dirty = np.zeros(self.n_entries, dtype=bool)
        self.stamp = np.zeros(self.n_entries, dtype=np.int64)

    def entry_nbytes(self, entries: np.ndarray) -> np.ndarray:
        nb = np.full(entries.shape, self.epe * self.itemsize, dtype=np.int64)
        last = self.n_entries - 1
        tail = self.truth.shape[0] - last * self.epe
        nb[entries == last] = tail * self.itemsize
        return nb


class VectorizedBackend:
    """Bitmap/stamp-array cache emulation with batched queue eviction."""

    kind = "vectorized"

    def __init__(self, store, cfg):
        self.store = store
        self.cfg = cfg
        self.capacity_lines = max(1, cfg.cache_bytes // cfg.line_bytes)
        self._regions: Dict[str, _Region] = {}
        self._by_rid: Dict[int, _Region] = {}
        self._next_rid = 0
        self._clock = 1  # stamp 0 = "never touched"
        self._weight_used = 0
        cap = 1024
        self._q_rid = np.zeros(cap, dtype=np.int64)
        self._q_entry = np.zeros(cap, dtype=np.int64)
        self._q_stamp = np.zeros(cap, dtype=np.int64)
        self._q_head = 0
        self._q_len = 0
        # compaction scratch (lazily grown): _q_compact copies the live
        # window here instead of allocating three fresh arrays per
        # compaction — dense sweeps compact thousands of times
        self._qc_rid = np.zeros(0, dtype=np.int64)
        self._qc_entry = np.zeros(0, dtype=np.int64)
        self._qc_stamp = np.zeros(0, dtype=np.int64)

    # -- registration ------------------------------------------------------
    def register(self, name: str, truth_flat: np.ndarray,
                 sector_lines: int = 1) -> None:
        r = _Region(name, self._next_rid, truth_flat, self.store.image[name],
                    max(1, int(sector_lines)), self.cfg.line_bytes)
        self._next_rid += 1
        self._regions[name] = r
        self._by_rid[r.rid] = r

    def unregister(self, name: str) -> None:
        r = self._regions.pop(name, None)
        if r is None:
            return
        self._weight_used -= int(r.present.sum()) * r.w
        # queue slots of a dropped rid fail validity lookups and get
        # skipped/compacted away lazily
        self._by_rid.pop(r.rid, None)

    # -- queue ---------------------------------------------------------------
    def _validity(self, rids: np.ndarray, ents: np.ndarray,
                  stamps: np.ndarray):
        """(live mask, per-slot line weights) for a block of queue slots."""
        valid = np.zeros(rids.shape[0], dtype=bool)
        wts = np.zeros(rids.shape[0], dtype=np.int64)
        for rid in np.unique(rids):
            r = self._by_rid.get(int(rid))
            if r is None:
                continue
            m = rids == rid
            e = ents[m]
            v = r.present[e] & (r.stamp[e] == stamps[m])
            valid[m] = v
            wts[m] = np.where(v, r.w, 0)
        return valid, wts

    def _q_compact(self) -> None:
        n = self._q_len - self._q_head
        if self._qc_rid.shape[0] < n:
            cap = max(n, 2 * self._qc_rid.shape[0])
            self._qc_rid = np.zeros(cap, dtype=np.int64)
            self._qc_entry = np.zeros(cap, dtype=np.int64)
            self._qc_stamp = np.zeros(cap, dtype=np.int64)
        sl = slice(self._q_head, self._q_len)
        rids, ents, stamps = (self._qc_rid[:n], self._qc_entry[:n],
                              self._qc_stamp[:n])
        np.copyto(rids, self._q_rid[sl])
        np.copyto(ents, self._q_entry[sl])
        np.copyto(stamps, self._q_stamp[sl])
        keep, _ = self._validity(rids, ents, stamps)
        k = int(keep.sum())
        self._q_rid[:k] = rids[keep]
        self._q_entry[:k] = ents[keep]
        self._q_stamp[:k] = stamps[keep]
        self._q_head = 0
        self._q_len = k

    def _q_append_one(self, rid: int, entry: int, stamp: int) -> None:
        if self._q_len + 1 > self._q_rid.shape[0]:
            self._q_reserve(1)
        i = self._q_len
        self._q_rid[i] = rid
        self._q_entry[i] = entry
        self._q_stamp[i] = stamp
        self._q_len = i + 1

    def _q_reserve(self, k: int) -> None:
        cap = self._q_rid.shape[0]
        if self._q_len + k > cap:
            self._q_compact()
            if self._q_len + k > cap:
                new_cap = max(self._q_len + k, cap * 2)
                for attr in ("_q_rid", "_q_entry", "_q_stamp"):
                    old = getattr(self, attr)
                    grown = np.zeros(new_cap, dtype=np.int64)
                    grown[:self._q_len] = old[:self._q_len]
                    setattr(self, attr, grown)

    def _q_append(self, rid: int, entries: np.ndarray,
                  stamps: np.ndarray) -> None:
        k = entries.shape[0]
        if k == 0:
            return
        self._q_reserve(k)
        s = slice(self._q_len, self._q_len + k)
        self._q_rid[s] = rid
        self._q_entry[s] = entries
        self._q_stamp[s] = stamps
        self._q_len += k

    # -- writeback -----------------------------------------------------------
    def _persist_entries(self, r: _Region, entries: np.ndarray) -> int:
        """Copy the given entries' truth spans into the image; returns the
        (clipped) byte count, matching the reference's per-entry charges."""
        self.store.mark_image_dirty(r.name)
        ents = np.sort(entries)
        nbytes = int(r.entry_nbytes(ents).sum())
        n = r.truth.shape[0]
        if int(ents[-1]) - int(ents[0]) + 1 == ents.size:  # contiguous
            lo = int(ents[0]) * r.epe
            hi = min((int(ents[-1]) + 1) * r.epe, n)
            r.image[lo:hi] = r.truth[lo:hi]
        else:
            idx = (ents[:, None] * r.epe +
                   np.arange(r.epe, dtype=np.int64)).ravel()
            idx = idx[idx < n]
            r.image[idx] = r.truth[idx]
        return nbytes

    # -- eviction ------------------------------------------------------------
    def _evict_until(self, target: int, acc: _OpAcc,
                     cur: Optional[_Region] = None, e_lo: int = 0,
                     e_hi: int = 0, dyn_pos: int = 0,
                     miss: Optional[np.ndarray] = None) -> None:
        """Pop oldest live slots until occupancy <= target (or the queue
        empties). When popping evicts a not-yet-touched entry of the
        in-flight range (``cur`` region, entries >= e_lo+dyn_pos), the
        entry is flagged in ``miss`` so the caller re-touches it as a
        miss — the reference's intra-op eviction interleaving."""
        while self._weight_used > target and self._q_head < self._q_len:
            hi = min(self._q_head + _EVICT_CHUNK, self._q_len)
            sl = slice(self._q_head, hi)
            rids = self._q_rid[sl]
            ents = self._q_entry[sl]
            stamps = self._q_stamp[sl]
            valid, wts = self._validity(rids, ents, stamps)
            cum = np.cumsum(wts)
            need = self._weight_used - target
            cut = int(np.searchsorted(cum, need, side="left"))
            consume = (hi - self._q_head) if cut >= cum.size else cut + 1
            crids = rids[:consume]
            cents = ents[:consume]
            cvalid = valid[:consume]
            for rid in np.unique(crids[cvalid]):
                r = self._by_rid[int(rid)]
                es = cents[(crids == rid) & cvalid]
                if cur is not None and r is cur and miss is not None:
                    dyn = es[(es >= e_lo + dyn_pos) & (es < e_hi)]
                    if dyn.size:
                        miss[dyn - e_lo] = True
                d = es[r.dirty[es]]
                if d.size:
                    acc.wb_bytes += self._persist_entries(r, d)
                r.present[es] = False
                r.dirty[es] = False
                freed = es.size * r.w
                acc.evict_lines += freed
                self._weight_used -= freed
            self._q_head += consume

    def _persist_one(self, r: _Region, entry: int) -> int:
        lo = entry * r.epe
        hi = min(lo + r.epe, r.truth.shape[0])
        r.image[lo:hi] = r.truth[lo:hi]
        self.store.mark_image_dirty(r.name)
        return (hi - lo) * r.itemsize

    # -- program-visible operations ------------------------------------------
    def _op_one(self, r: _Region, entry: int, is_write: bool) -> None:
        """Single-entry fast path: plain-int state updates, no array
        temporaries — dominant in pointer-chasing traffic (XSBench's
        binary-search probes, per-lookup counters)."""
        stamp = self._clock
        self._clock = stamp + 1
        if r.present[entry]:
            if self.cfg.replacement != "fifo":
                r.stamp[entry] = stamp
                self._q_append_one(r.rid, entry, stamp)
            if is_write:
                r.dirty[entry] = True
            return
        r.present[entry] = True
        r.dirty[entry] = is_write
        r.stamp[entry] = stamp
        self._q_append_one(r.rid, entry, stamp)
        self._weight_used += r.w
        acc = _OpAcc()
        if self._weight_used > self.capacity_lines:
            self._evict_until(max(self.capacity_lines, r.w), acc)
        self.store.stats.charge_batch(
            self.cfg, write_bytes=acc.wb_bytes,
            read_bytes=0 if is_write else r.epe * r.itemsize,
            evict_lines=acc.evict_lines)

    def _op(self, name: str, lo: int, hi: int, is_write: bool) -> None:
        r = self._regions[name]
        if hi <= lo:
            return
        e_lo = lo // r.epe
        e_hi = (hi - 1) // r.epe + 1
        if e_hi - e_lo == 1:
            self._op_one(r, e_lo, is_write)
            return
        m = e_hi - e_lo
        t0 = self._clock
        self._clock += m
        ents = np.arange(e_lo, e_hi, dtype=np.int64)
        miss = ~r.present[ents]
        acc = _OpAcc()
        fifo = self.cfg.replacement == "fifo"
        p = 0
        while p < m:
            if miss[p]:
                nxt = np.flatnonzero(~miss[p:])
                t = m if nxt.size == 0 else p + int(nxt[0])
                run = ents[p:t]
                stamps = t0 + np.arange(p, t, dtype=np.int64)
                r.present[run] = True
                r.dirty[run] = is_write
                r.stamp[run] = stamps
                self._q_append(r.rid, run, stamps)
                self._weight_used += (t - p) * r.w
                if not is_write:
                    acc.read_entries += t - p
                if self._weight_used > self.capacity_lines:
                    # target C normally; a single entry heavier than the
                    # whole cache leaves exactly the newest entry resident
                    self._evict_until(max(self.capacity_lines, r.w), acc,
                                      cur=r, e_lo=e_lo, e_hi=e_hi,
                                      dyn_pos=t, miss=miss)
                p = t
            else:
                nxt = np.flatnonzero(miss[p:])
                t = m if nxt.size == 0 else p + int(nxt[0])
                run = ents[p:t]
                if not fifo:  # LRU re-touch; FIFO hits keep their slot
                    stamps = t0 + np.arange(p, t, dtype=np.int64)
                    r.stamp[run] = stamps
                    self._q_append(r.rid, run, stamps)
                if is_write:
                    r.dirty[run] = True
                p = t
        self.store.stats.charge_batch(
            self.cfg, write_bytes=acc.wb_bytes,
            read_bytes=acc.read_entries * r.epe * r.itemsize,
            evict_lines=acc.evict_lines)

    def write(self, name: str, lo: int, hi: int) -> None:
        self._op(name, lo, hi, is_write=True)

    def read(self, name: str, lo: int, hi: int) -> None:
        self._op(name, lo, hi, is_write=False)

    def flush(self, name: str, lo: int = 0, hi: Optional[int] = None) -> None:
        r = self._regions[name]
        if hi is None:
            hi = r.truth.shape[0]
        if hi <= lo:
            return
        e_lo = lo // r.epe
        e_hi = (hi - 1) // r.epe + 1
        if e_hi - e_lo == 1:  # scalar fast path (counter/line flushes)
            entry = e_lo
            wb_bytes = 0
            clean = 0
            if r.present[entry]:
                self._weight_used -= r.w
                r.present[entry] = False
                if r.dirty[entry]:
                    r.dirty[entry] = False
                    wb_bytes = self._persist_one(r, entry)
                else:
                    clean = 1
            else:
                clean = 1
            self.store.stats.charge_batch(
                self.cfg, write_bytes=wb_bytes, flush_lines=r.w,
                clean_flush_bytes=clean * r.epe * r.itemsize)
            return
        ents = np.arange(e_lo, e_hi, dtype=np.int64)
        pres = r.present[ents]
        d = ents[pres & r.dirty[ents]]
        wb_bytes = self._persist_entries(r, d) if d.size else 0
        clean = ents.size - int(d.size)
        self._weight_used -= int(pres.sum()) * r.w
        r.present[ents] = False
        r.dirty[ents] = False
        self.store.stats.charge_batch(
            self.cfg, write_bytes=wb_bytes, flush_lines=ents.size * r.w,
            clean_flush_bytes=clean * r.epe * r.itemsize)

    def drain(self) -> None:
        acc = _OpAcc()
        self._evict_until(0, acc)
        self._q_head = 0
        self._q_len = 0
        self.store.stats.charge_batch(
            self.cfg, write_bytes=acc.wb_bytes, evict_lines=acc.evict_lines)

    def _dirty_eviction_order(self):
        """Dirty entries as (name, entry) in replacement order: live
        queue slots front-to-back — exactly the reference OrderedDict's
        iteration order (stale slots are skipped by validity)."""
        sl = slice(self._q_head, self._q_len)
        rids = self._q_rid[sl]
        ents = self._q_entry[sl]
        valid, _ = self._validity(rids, ents, self._q_stamp[sl])
        out = []
        for i in np.flatnonzero(valid):
            r = self._by_rid[int(rids[i])]
            e = int(ents[i])
            if r.dirty[e]:
                out.append((r.name, e))
        return out

    def crash(self, survival: Optional[LineSurvival] = None) -> int:
        # fraction 0.0 selects nothing: skip the per-slot queue walk on
        # the dense-sweep hot path (crash is once per measure cell)
        torn = survival is not None and survival.fraction > 0.0
        if torn and survival.granularity == "word":
            return self._crash_words(survival)
        survivors = select_survivors(
            self._dirty_eviction_order() if torn else (), survival)
        if survivors:
            nbytes = 0
            by_region: Dict[str, list] = {}
            for name, entry in survivors:
                by_region.setdefault(name, []).append(entry)
            for name, entries in by_region.items():
                nbytes += self._persist_entries(
                    self._regions[name], np.asarray(entries, dtype=np.int64))
            self.store.stats.note_torn_persist(nbytes, len(survivors))
        lost = -len(survivors)
        for r in self._regions.values():
            lost += int((r.present & r.dirty).sum())
            r.present[:] = False
            r.dirty[:] = False
        self._weight_used = 0
        self._q_head = 0
        self._q_len = 0
        return lost

    def _crash_words(self, survival: LineSurvival) -> int:
        """Word-granularity torn crash — mirrors the reference path:
        surviving word spans persist through ``store.persist`` (which
        handles image epochs), an entry counts as lost only if none of
        its words made it."""
        dirty = self._dirty_eviction_order()
        words = select_survivor_words(dirty, survival, self.entry_geometry)
        if words:
            nbytes = 0
            for name, _entry, lo, hi in words:
                r = self._regions[name]
                self.store.persist(name, lo, hi, r.truth)
                nbytes += (hi - lo) * r.itemsize
            self.store.stats.note_torn_persist(nbytes, len(words))
        touched = {(name, entry) for name, entry, _lo, _hi in words}
        lost = len(dirty) - len(touched)
        for r in self._regions.values():
            r.present[:] = False
            r.dirty[:] = False
        self._weight_used = 0
        self._q_head = 0
        self._q_len = 0
        return lost

    # -- snapshot / fork ----------------------------------------------------
    def snapshot(self) -> object:
        """Capture bitmaps/stamps per region plus the live queue slice.
        Only the [head, len) window is copied — dead slots ahead of the
        head are irrelevant to replay, so snapshots stay proportional to
        live state, not queue history."""
        sl = slice(self._q_head, self._q_len)
        snap = {
            "regions": {name: (r.present.copy(), r.dirty.copy(),
                               r.stamp.copy())
                        for name, r in self._regions.items()},
            "clock": self._clock,
            "weight_used": self._weight_used,
            "queue": (self._q_rid[sl].copy(), self._q_entry[sl].copy(),
                      self._q_stamp[sl].copy()),
        }
        for present, dirty, stamp in snap["regions"].values():
            present.flags.writeable = False
            dirty.flags.writeable = False
            stamp.flags.writeable = False
        for arr in snap["queue"]:
            arr.flags.writeable = False
        return snap

    def restore(self, snap: object) -> None:
        if set(snap["regions"]) != set(self._regions):
            raise ValueError(
                "snapshot regions do not match this backend's regions "
                "(snapshots only restore into the instance that took them)")
        for name, (present, dirty, stamp) in snap["regions"].items():
            r = self._regions[name]
            r.present[:] = present
            r.dirty[:] = dirty
            r.stamp[:] = stamp
        self._clock = snap["clock"]
        self._weight_used = snap["weight_used"]
        q_rid, q_entry, q_stamp = snap["queue"]
        k = q_rid.shape[0]
        if self._q_rid.shape[0] < k:
            cap = max(k, 2 * self._q_rid.shape[0])
            self._q_rid = np.zeros(cap, dtype=np.int64)
            self._q_entry = np.zeros(cap, dtype=np.int64)
            self._q_stamp = np.zeros(cap, dtype=np.int64)
        self._q_rid[:k] = q_rid
        self._q_entry[:k] = q_entry
        self._q_stamp[:k] = q_stamp
        self._q_head = 0
        self._q_len = k

    # -- introspection ------------------------------------------------------
    @property
    def occupancy_lines(self) -> int:
        return self._weight_used

    def dirty_entries(self, name: str) -> np.ndarray:
        r = self._regions[name]
        return np.flatnonzero(r.present & r.dirty).astype(np.int64)

    def has_dirty(self, name: str) -> bool:
        r = self._regions[name]
        return bool(np.any(r.present & r.dirty))

    def dirty_eviction_order(self):
        return self._dirty_eviction_order()

    def entry_geometry(self, name: str):
        r = self._regions[name]
        return r.epe, r.truth.shape[0], r.itemsize
