"""Unified transformer LM covering the dense / moe / audio / vlm families.

One parameterization + three entry points per family:

  init(cfg, key)                        -> (params, axes)
  forward(cfg, params, batch, mesh)     -> logits  (train / prefill)
  decode_step(cfg, params, cache, ...)  -> (logits, new cache)

Layers are *stacked* (leading n_layers dim) and driven by ``lax.scan`` so
a 61-layer model lowers to the same HLO size as a 2-layer one — essential
for the 512-device dry-run compiles. Family switches (GQA vs MLA, dense
FFN vs MoE, causal vs bidirectional, RoPE vs M-RoPE vs none) all come
from ModelConfig; there is no per-arch forward code.

Batch dicts (built by launch/dryrun.input_specs):
  dense/moe : tokens (B,S) int32, labels (B,S) int32
  audio     : frames (B,S,D) f32 (frontend stub), labels (B,S)
  vlm       : tokens (B,S_text), patches (B,P,D), positions (3,B,S),
              labels (B,S)  [patch positions carry label -100 -> masked]
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import mla as MLA
from . import moe as MOE

__all__ = ["init", "forward", "loss_fn", "init_cache", "decode_step",
           "stacked_init", "cross_entropy"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, key):
    ka, kf, kn = jax.random.split(key, 3)
    p, a = {}, {}
    if cfg.use_mla:
        p["attn"], a["attn"] = MLA.mla_init(cfg, ka)
    else:
        p["attn"], a["attn"] = L.attention_init(cfg, ka)
    if cfg.n_experts:
        p["moe"], a["moe"] = MOE.moe_init(cfg, kf)
        if cfg.n_shared_experts:
            import dataclasses
            shared_ff = cfg.moe_d_ff * cfg.n_shared_experts
            p["shared"], a["shared"] = L.swiglu_init(cfg, kn, d_ff=shared_ff)
    else:
        p["ffn"], a["ffn"] = L.swiglu_init(cfg, kf)
    p["norm_attn"], a["norm_attn"] = L.rmsnorm_init(cfg.d_model,
                                                    jnp.dtype(cfg.param_dtype))
    p["norm_ffn"], a["norm_ffn"] = L.rmsnorm_init(cfg.d_model,
                                                  jnp.dtype(cfg.param_dtype))
    return p, a


def stack_axes(axes):
    """Prefix every axis tuple in a tree with the scanned 'layers' dim."""
    return jax.tree.map(
        lambda t: ("layers",) + t, axes,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(s, str) for s in t))


def stacked_init(init_one_with_axes, n: int, key):
    """vmap a (params, axes)-returning layer init over n rngs."""
    keys = jax.random.split(key, n)
    axes_box = {}

    def params_only(k):
        p, a = init_one_with_axes(k)
        axes_box["axes"] = a
        return p

    params = jax.vmap(params_only)(keys)
    return params, stack_axes(axes_box["axes"])


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    p, a = {}, {}
    if cfg.embed_inputs:
        p["embed"], a["embed"] = L.embed_init(k_emb, cfg.padded_vocab,
                                              cfg.d_model,
                                              jnp.dtype(cfg.param_dtype))
    p["layers"], a["layers"] = stacked_init(
        lambda k: _layer_init(cfg, k), cfg.n_layers, k_layers)
    p["norm_f"], a["norm_f"] = L.rmsnorm_init(cfg.d_model,
                                              jnp.dtype(cfg.param_dtype))
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        p["head"], a["head"] = L.dense_init(k_head, cfg.d_model,
                                            cfg.padded_vocab, "embed",
                                            "vocab",
                                            jnp.dtype(cfg.param_dtype))
    return p, a


import functools


@functools.lru_cache(maxsize=None)
def layer_axes(cfg: ModelConfig):
    """Axes tree for ONE layer (metadata only, no arrays — eval_shape)."""
    box = {}

    def f(k):
        prms, a = _layer_init(cfg, k)
        box["a"] = a
        return prms

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["a"]


def abstract_init(cfg: ModelConfig, key):
    """(ShapeDtypeStruct params, axes) — no allocation; dry-run entry."""
    box = {}

    def params_only(k):
        prms, axes = init(cfg, k)
        box["axes"] = axes
        return prms

    shapes = jax.eval_shape(params_only, key)
    return shapes, box["axes"]


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _ffn_block(cfg: ModelConfig, lp: Dict, h_norm: jax.Array,
               mesh) -> jax.Array:
    B, S, D = h_norm.shape
    if not cfg.n_experts:
        return L.swiglu_apply(lp["ffn"], h_norm)
    tokens = h_norm.reshape(B * S, D)
    if mesh is None:
        y = MOE.moe_apply_dense(cfg, lp["moe"], tokens)
    else:
        token_axes = tuple(n for n in mesh.axis_names)
        y = MOE.moe_apply_ep(cfg, lp["moe"], tokens, mesh,
                             token_axes=token_axes)
    if cfg.n_shared_experts:
        y = y + L.swiglu_apply(lp["shared"], tokens)
    return y.reshape(B, S, D)


def _layer_apply(cfg: ModelConfig, lp: Dict, h: jax.Array,
                 positions: jax.Array, mrope_positions, mesh,
                 cache: Optional[Dict] = None, cache_index=None,
                 flash: bool = False):
    h = L.shard_act(h, mesh)
    h_norm = L.rmsnorm(h, lp["norm_attn"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out, new_cache = MLA.mla_apply(
            cfg, lp["attn"], h_norm, positions, cache=cache,
            cache_index=cache_index)
    else:
        attn_out, new_cache = L.attention_apply(
            cfg, lp["attn"], h_norm, positions,
            mrope_positions=mrope_positions, cache=cache,
            cache_index=cache_index, mesh=mesh, flash=flash)
    h = L.shard_act(h + attn_out, mesh)
    h = h + _ffn_block(cfg, lp, L.rmsnorm(h, lp["norm_ffn"], cfg.norm_eps),
                       mesh)
    return L.shard_act(h, mesh), new_cache


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _embed_batch(cfg: ModelConfig, params: Dict, batch: Dict):
    """-> (h (B,S,D), positions (B,S) or None, mrope (3,B,S) or None)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "audio":
        h = batch["frames"].astype(dt)
        B, S = h.shape[:2]
        return h, jnp.arange(S)[None, :].repeat(B, 0), None
    if cfg.family == "vlm":
        text = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        h = jnp.concatenate([batch["patches"].astype(dt), text], axis=1)
        return h, None, batch["positions"]
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    B, S = batch["tokens"].shape
    return h, jnp.arange(S)[None, :].repeat(B, 0), None


def _head(cfg: ModelConfig, params: Dict, h: jax.Array) -> jax.Array:
    logits = (h @ params["embed"].T.astype(h.dtype)
              if cfg.tie_embeddings and cfg.embed_inputs
              else h @ params["head"].astype(h.dtype))
    # tables are padded to cfg.padded_vocab for even TP sharding
    return logits[..., :cfg.vocab_size]


def forward(cfg: ModelConfig, params: Dict, batch: Dict, mesh=None,
            remat: str = "none", flash: bool = False) -> jax.Array:
    h, positions, mrope = _embed_batch(cfg, params, batch)
    h = L.shard_act(h, mesh)
    lax_ = layer_axes(cfg)

    def body(h, lp):
        lp = L.gather_weights(lp, lax_, mesh)   # ZeRO-3 per-layer gather
        out, _ = _layer_apply(cfg, lp, h, positions, mrope, mesh,
                              flash=flash)
        return out, None

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = L.rmsnorm(h, params["norm_f"], cfg.norm_eps)
    return _head(cfg, params, h)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore: int = -100) -> jax.Array:
    """Masked CE in f32; labels == ``ignore`` are excluded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict, mesh=None,
            remat: str = "none") -> jax.Array:
    logits = forward(cfg, params, batch, mesh, remat=remat)
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.use_mla:
        one, one_axes = MLA.mla_cache_init(cfg, batch, max_len)
    else:
        one, one_axes = L.attention_cache_init(cfg, batch, max_len)
    cache = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
    axes = jax.tree.map(lambda t: ("layers",) + t, one_axes,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and all(isinstance(s, str) for s in t))
    return cache, axes


def decode_step(cfg: ModelConfig, params: Dict, cache, tokens: jax.Array,
                pos: jax.Array, mesh=None):
    """One decode step. tokens: (B, 1) int (or frames (B,1,D) for audio);
    pos: scalar int32 — current cache length. Returns (logits (B,1,V),
    new cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "audio":
        raise ValueError("encoder-only architecture has no decode step")
    h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    B = tokens.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    mrope = None
    if cfg.mrope_sections:
        mrope = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)

    def body(h, xs):
        lp, layer_cache = xs
        out, new_cache = _layer_apply(cfg, lp, h, positions, mrope, mesh,
                                      cache=layer_cache, cache_index=pos)
        return out, new_cache

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = L.rmsnorm(h, params["norm_f"], cfg.norm_eps)
    return _head(cfg, params, h), new_cache
