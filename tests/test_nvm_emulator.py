"""Unit + property tests for the NVM crash emulator (core/nvm.py).

Every test in this module runs twice — once per emulation backend
(reference oracle / vectorized default) — via the autouse fixture below.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.nvm import CrashEmulator, NVMConfig


@pytest.fixture(params=["reference", "vectorized"], autouse=True)
def nvm_backend(request, monkeypatch):
    """NVMConfig picks its default backend up from the environment."""
    monkeypatch.setenv("REPRO_NVM_BACKEND", request.param)
    return request.param


def small_emu(cache_bytes=256, replacement="lru"):
    return CrashEmulator(NVMConfig(cache_bytes=cache_bytes, line_bytes=64,
                                   replacement=replacement))


class TestBasics:
    def test_truth_always_latest(self):
        emu = small_emu()
        r = emu.alloc("x", (64,))
        r[...] = np.arange(64.0)
        assert np.array_equal(r.view, np.arange(64.0))

    def test_flush_persists(self):
        emu = small_emu()
        r = emu.alloc("x", (64,))
        r[...] = np.arange(64.0)
        r.flush()
        emu.crash()
        assert np.array_equal(r.view, np.arange(64.0))

    def test_crash_loses_cached_dirty_data(self):
        emu = small_emu(cache_bytes=256)  # 4 lines = 32 float64
        r = emu.alloc("x", (64,))
        r[...] = np.arange(64.0)
        emu.crash()
        # last-written 32 elements were cached and are lost
        assert np.all(r.view[32:] == 0)
        # earlier lines were evicted to NVM and survive
        assert np.array_equal(r.view[:32], np.arange(32.0))

    def test_eviction_writes_back(self):
        emu = small_emu(cache_bytes=128)  # 2 lines
        r = emu.alloc("x", (32,))
        r[0:8] = 1.0   # line 0
        r[8:16] = 2.0  # line 1
        r[16:24] = 3.0  # line 2 -> evicts line 0
        assert np.all(r.nvm[0:8] == 1.0)

    def test_partial_line_crash_granularity(self):
        emu = small_emu()
        r = emu.alloc("x", (16,))
        r[0:4] = 5.0
        emu.crash()
        assert np.all(r.view == 0)  # nothing flushed/evicted -> all lost

    def test_scalar_region_flush(self):
        emu = small_emu()
        r = emu.alloc("i", (1,), np.int64)
        r[0] = 42
        r.flush()
        emu.crash()
        assert int(r.view[0]) == 42

    def test_2d_row_indexing(self):
        emu = small_emu(cache_bytes=1 << 20)
        m = emu.alloc("M", (16, 16))
        m[3:5, :] = 7.0
        m.flush((slice(3, 5), slice(None)))
        emu.crash()
        assert np.all(m.view[3:5] == 7.0) and np.all(m.view[:3] == 0)

    def test_modeled_time_monotone(self):
        emu = small_emu()
        r = emu.alloc("x", (1024,))
        t0 = emu.modeled_seconds()
        r[...] = 1.0
        r.flush()
        assert emu.modeled_seconds() > t0

    def test_stats_flush_counts(self):
        emu = small_emu()
        r = emu.alloc("x", (8,))  # one line
        r[...] = 1.0
        r.flush()
        assert emu.stats.lines_flushed >= 1
        assert emu.stats.nvm_bytes_written >= 64

    def test_fifo_evicts_hot_lines(self):
        # under FIFO a repeatedly-touched line still ages out
        emu = small_emu(cache_bytes=256, replacement="fifo")
        hot = emu.alloc("hot", (8,))
        big = emu.alloc("big", (512,))
        hot[0] = 1.0
        for i in range(0, 512, 8):
            big[i:i + 8] = float(i)
            hot[0] = hot.view[0] + 1.0  # touch hot line every iteration
        # FIFO must have evicted (and persisted) some historical hot value
        assert hot.nvm[0] > 0

    def test_lru_keeps_hot_lines(self):
        emu = small_emu(cache_bytes=256, replacement="lru")
        hot = emu.alloc("hot", (8,))
        big = emu.alloc("big", (512,))
        for i in range(0, 512, 8):
            big[i:i + 8] = float(i)
            hot[0] = hot.view[0] + 1.0
        # LRU never evicts the per-iteration-touched line
        assert hot.nvm[0] == 0


class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        writes=st.lists(
            st.tuples(st.integers(0, 248), st.integers(1, 8),
                      st.floats(-100, 100, allow_nan=False)),
            min_size=1, max_size=40),
        cache_lines=st.integers(1, 8),
        do_flush=st.booleans(),
    )
    def test_nvm_view_is_prefix_consistent(self, writes, cache_lines, do_flush):
        """After a crash, every element is either the latest value written
        to it that got persisted, or an older persisted value — never a
        value that was never written."""
        emu = CrashEmulator(NVMConfig(cache_bytes=cache_lines * 64, line_bytes=64))
        r = emu.alloc("x", (256,))
        history = {i: [0.0] for i in range(256)}
        for (lo, length, val) in writes:
            hi = min(256, lo + length)
            r[lo:hi] = val
            for i in range(lo, hi):
                history[i].append(val)
        if do_flush:
            r.flush()
        emu.crash()
        out = r.view
        for i in range(256):
            assert out[i] in history[i], f"elem {i}: {out[i]} not ever written"

    @settings(max_examples=25, deadline=None)
    @given(writes=st.lists(st.tuples(st.integers(0, 31), st.floats(-10, 10,
                                                                   allow_nan=False)),
                           min_size=1, max_size=50))
    def test_flush_then_crash_preserves_everything(self, writes):
        emu = small_emu(cache_bytes=128)
        r = emu.alloc("x", (32,))
        expect = np.zeros(32)
        for i, v in writes:
            r[i] = v
            expect[i] = v
        r.flush()
        emu.crash()
        assert np.array_equal(r.view, expect)

    @settings(max_examples=20, deadline=None)
    @given(n_lines=st.integers(1, 16), cache_lines=st.integers(1, 4))
    def test_capacity_never_exceeded(self, n_lines, cache_lines):
        emu = CrashEmulator(NVMConfig(cache_bytes=cache_lines * 64, line_bytes=64))
        r = emu.alloc("x", (n_lines * 8,))
        for i in range(n_lines):
            r[i * 8:(i + 1) * 8] = float(i)
            assert emu.cache.occupancy_lines <= cache_lines


class TestUndoLog:
    def test_commit_then_crash_keeps_new_values(self):
        from repro.core.transactions import TxManager
        emu = small_emu(cache_bytes=1 << 16)
        r = emu.alloc("x", (8,))
        r[...] = 1.0
        r.flush()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.write(r, Ellipsis, np.full(8, 2.0))
        mgr.commit()
        emu.crash()
        assert np.all(r.view == 2.0)

    def test_crash_mid_tx_rolls_back(self):
        from repro.core.transactions import TxManager
        emu = small_emu(cache_bytes=1 << 16)
        r = emu.alloc("x", (8,))
        r[...] = 1.0
        r.flush()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.write(r, Ellipsis, np.full(8, 2.0))
        r.flush()  # even if new data hit NVM, recovery must undo it
        emu.crash()
        assert mgr.recover()
        assert np.all(emu.post_crash_view("x") == 1.0)

    def test_second_crash_after_rollback_reloads_rolled_back_image(self):
        # crash() fast-paths regions with a clean cache (truth == image
        # there) — EXCEPT after an undo-log rollback, which rewrites the
        # image with pre-tx values truth never saw. A second crash
        # before resync_truth must still see the rolled-back image.
        from repro.core.transactions import TxManager
        emu = small_emu(cache_bytes=1 << 16)
        r = emu.alloc("x", (8,))
        r[...] = 1.0
        r.flush()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.write(r, Ellipsis, np.full(8, 2.0))
        r.flush()
        emu.crash()
        assert mgr.recover()          # image rolled back to 1.0; truth
        emu.crash()                   # not yet resynced; crash again
        assert np.all(r.view == 1.0)
        assert np.all(emu.post_crash_view("x") == 1.0)

    def test_snapshot_between_rollback_and_resync_carries_divergence(self):
        # EmuSnapshot must carry the pending rollback-induced
        # truth/image divergence: restoring a snapshot taken before
        # resync_truth and crashing again must still reload the
        # rolled-back image
        from repro.core.transactions import TxManager
        emu = small_emu(cache_bytes=1 << 16)
        r = emu.alloc("x", (8,))
        r[...] = 1.0
        r.flush()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.write(r, Ellipsis, np.full(8, 2.0))
        r.flush()
        emu.crash()
        assert mgr.recover()
        snap = emu.snapshot()         # divergence pending at capture
        emu.resync_truth("x")         # move the live state past it
        emu.restore(snap)
        emu.crash()
        assert np.all(r.view == 1.0)

    def test_undo_log_charges_persist_cost(self):
        from repro.core.transactions import TxManager
        emu = small_emu(cache_bytes=1 << 16)
        r = emu.alloc("x", (1024,))
        r[...] = 1.0
        base = emu.modeled_seconds()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.write(r, Ellipsis, np.full(1024, 2.0))
        mgr.commit()
        # old-value copy + flushes must be charged
        assert emu.modeled_seconds() - base > 0


class TestCheckpointBaseline:
    @pytest.mark.parametrize("target", ["hdd", "nvm_only", "nvm_dram"])
    def test_checkpoint_restore(self, target):
        from repro.core.checkpoint_baseline import CheckpointBaseline
        emu = small_emu(cache_bytes=1 << 16)
        r = emu.alloc("x", (64,))
        r[...] = np.arange(64.0)
        ck = CheckpointBaseline(emu, target)
        cost = ck.checkpoint(3, [r])
        assert cost > 0
        r[...] = -1.0
        emu.crash()
        restored = ck.restore()
        assert np.array_equal(restored["x"], np.arange(64.0))

    def test_hdd_slower_than_nvm(self):
        from repro.core.checkpoint_baseline import CheckpointBaseline
        costs = {}
        for target in ("hdd", "nvm_only"):
            emu = small_emu(cache_bytes=1 << 16)
            r = emu.alloc("x", (1 << 16,))
            r[...] = 1.0
            costs[target] = CheckpointBaseline(emu, target).checkpoint(0, [r])
        assert costs["hdd"] > costs["nvm_only"]
