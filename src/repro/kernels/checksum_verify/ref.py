"""Pure-jnp oracle for checksum verification."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["verify_ref"]


@jax.jit
def verify_ref(cf: jax.Array, rtol: float = 1e-6, atol: float = 1e-4):
    """Residuals + verdict for a full-checksum matrix cf (m+1, n+1).

    Returns (ok: bool scalar, row_resid (m,), col_resid (n,)).
    """
    data = cf[:-1, :-1].astype(jnp.float32)
    row_resid = cf[:-1, -1].astype(jnp.float32) - jnp.sum(data, axis=1)
    col_resid = cf[-1, :-1].astype(jnp.float32) - jnp.sum(data, axis=0)
    scale = jnp.maximum(jnp.max(jnp.abs(cf)).astype(jnp.float32), 1.0)
    tol = atol + rtol * scale
    ok = (jnp.max(jnp.abs(row_resid)) <= tol) & (jnp.max(jnp.abs(col_resid)) <= tol)
    return ok, row_resid, col_resid
