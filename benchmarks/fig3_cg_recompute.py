"""Paper Fig. 3: CG recomputation cost vs input problem size.

A declarative scenario matrix over the unified driver: ADCC strategy,
problem size swept, and — unlike the paper's fixed crash iteration —
EVERY crash step enumerated via ``CrashPlan.at_every_step()`` through
``sweep(mode="measure")``: each cell forks from its snapshot, crashes,
runs ADCC recovery, and computes the recompute fields from the
recovered state (no tail re-execution), so the exhaustive curve costs
O(restore + recover) per crash point. Reported per (size, crash step):
iterations lost and the recomputation time (detect + resume) normalized
by the average per-iteration time, plus per-size mean/worst aggregates
— small problems fit in cache and lose everything, large problems lose
~1 iteration.

Every run — ``--smoke`` (the CI size axis) or full — passes the
dense-matrix gates (``scenarios_sweep.check_dense_gates``): the
parallel (``--workers``) sweep must merge to the identical cell list
as the serial one, and every measure-mode field must match the
full-execution fork engine. The gate's full-execution sweep is also
where crashed cells' end-of-run correctness gets checked (measure
cells carry correct=None by design): asserted strictly at smoke sizes;
at full sizes ADCC CG's invariant-scan restart is *approximately*
consistent (the paper's iterative-method tolerance argument) and the
handful of cells off the strict 1e-7 criterion — but within the scan's
own residual tolerance — are reclassified as the pinned
``approx_consistent_full_cells`` population, with the genuinely
incorrect count gated at zero (``incorrect_full_cells``).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, sweep

from .common import Row

ARTIFACT = "fig3_cg_recompute.json"

SIZES = [2048, 8192, 32768, 131072]   # paper: classes S, W, A, B/C
ITERS = 16
SMOKE_SIZES = [1024, 4096]
SMOKE_ITERS = 10

PLANS = (CrashPlan.no_crash(), CrashPlan.at_every_step())

# ADCC CG's invariant-scan restart is APPROXIMATELY consistent (the
# paper's iterative-method tolerance argument): the backward scan
# admits a restart candidate when its invariants hold to the scan
# tolerances (ResidualInvariant: 1e-6 relative residual), so a
# restarted run can carry a perturbation up to that tolerance which CG
# contracts but — on cells crashing late enough — has not fully damped
# by the final iteration. Those cells finalize off the strict 1e-7
# max-error criterion while their final RELATIVE RESIDUAL stays within
# the very tolerance that admitted the candidate: consistent to the
# scan's own documented accuracy class, not incorrect. The gate below
# reclassifies exactly that population (``approx_consistent_full_cells``,
# pinned EXACTLY) and pins the genuinely-incorrect count at ZERO — a
# cell off the strict criterion whose residual also exceeds the scan
# tolerance is a real defect and fails the run. Re-pin only after
# inspecting the offending cells.
CG_SCAN_RESIDUAL_TOL = 1e-6   # == repro.algorithms.cg ResidualInvariant tol
EXPECTED_INCORRECT_FULL_CELLS = 0
EXPECTED_APPROX_FULL_CELLS = 7


def _within_scan_tolerance(cell) -> bool:
    """Documented tolerance class: the cell's final relative residual is
    within the invariant-scan tolerance that admitted its restart
    candidate (full-execution cells only — measure cells never reach
    the correctness gate)."""
    resid = (cell.metrics or {}).get("rel_residual")
    return resid is not None and resid <= CG_SCAN_RESIDUAL_TOL


def _workloads(sizes: Sequence[int], iters: int) -> Tuple:
    return tuple(("cg", {"n": n, "iters": iters, "seed": n}) for n in sizes)


def _cfg() -> NVMConfig:
    return NVMConfig(cache_bytes=2 * 1024 * 1024)


def _sweep_kw(smoke: bool) -> Dict:
    sizes, iters = (SMOKE_SIZES, SMOKE_ITERS) if smoke else (SIZES, ITERS)
    return dict(workloads=_workloads(sizes, iters), strategies=("adcc",),
                plans=PLANS, cfg=_cfg())


def run(smoke: bool = None, workers: int = None,
        mode: str = "measure") -> List[Row]:
    from .scenarios_sweep import check_dense_gates, resolve_sweep_env

    smoke, workers = resolve_sweep_env(smoke, workers)
    kw = _sweep_kw(smoke)
    cells = sweep(mode=mode, workers=workers, **kw)
    # with mode="batched" the same gate stack pins the batched cells
    # against a fresh measure-mode sweep cell-for-cell (the alternate-
    # workers comparison inside) on top of the measure==fork contract.
    # parallel==serial and measure==fork gate at EVERY size; the strict
    # per-cell correctness assert only at smoke sizes — at full sizes
    # ADCC CG's approximate invariant-scan restart leaves EXACTLY
    # EXPECTED_APPROX_FULL_CELLS cells off the strict 1e-7 criterion
    # but within the scan's own residual tolerance (see the pin comment
    # above); both the tolerated and the genuinely-incorrect counts are
    # exact gates so neither can silently drift
    incorrect, approx = check_dense_gates(
        kw, cells, workers, strict_correct=smoke,
        expected_incorrect=None if smoke else EXPECTED_INCORRECT_FULL_CELLS,
        tolerance_class=_within_scan_tolerance,
        expected_tolerated=None if smoke else EXPECTED_APPROX_FULL_CELLS)

    rows = [Row("fig3/cg_recompute/incorrect_full_cells", len(incorrect),
                "off the strict 1e-7 criterion AND outside the scan "
                "residual tolerance (pinned 0)"),
            Row("fig3/cg_recompute/approx_consistent_full_cells",
                len(approx),
                f"off the strict criterion but within the invariant-scan "
                f"residual tolerance {CG_SCAN_RESIDUAL_TOL:g}")]
    for spec in kw["workloads"]:
        n = spec[1]["n"]
        mine = [c for c in cells if c.workload_params.get("n") == n]
        baseline = [c for c in mine if c.crash_step is None]
        assert baseline and all(c.correct for c in baseline), \
            (n, "no_crash baseline must finalize correct")
        crashed = [c for c in mine if c.crash_step is not None]
        assert [c.crash_step for c in crashed] == list(
            range(spec[1]["iters"])), (n, "dense curve must be exhaustive")
        norms = []
        for c in crashed:
            norm = ((c.detect_seconds + c.resume_seconds)
                    / max(c.avg_step_seconds, 1e-12))
            norms.append(norm)
            rows.append(Row(
                f"fig3/cg_recompute/n={n}/crash={c.crash_step}/iters_lost",
                c.steps_lost,
                f"restart={c.restart_point} class={c.correctness_class}"))
            rows.append(Row(
                f"fig3/cg_recompute/n={n}/crash={c.crash_step}"
                f"/normalized_recompute",
                norm, f"detect={c.detect_seconds:.4f}s"))
        rows.append(Row(f"fig3/cg_recompute/n={n}/mean_iters_lost",
                        sum(c.steps_lost for c in crashed) / len(crashed),
                        f"crash_points={len(crashed)}"))
        rows.append(Row(f"fig3/cg_recompute/n={n}/worst_normalized_recompute",
                        max(norms), "over every crash step"))
    return rows


def main(argv=None) -> None:
    from .common import dense_figure_cli
    dense_figure_cli(run, ARTIFACT, argv)


if __name__ == "__main__":
    main()
