"""Tests for the persistent KV-serving workload family.

Covers: store semantics (zipfian stream determinism, index/value-log
integrity, oracle-checked finalize), the shadow_snapshot strategy
(copy-on-write extent sharing, root flip, scratch recovery before the
first flip), the durability/atomicity correctness classes across
strategies and recovery policies, the commit-record coherence of the
validating mount recovery, the batched-engine fallback for auditing
workloads, and the registry collision guards.
"""

import numpy as np
import pytest

from repro.core.backends import LineSurvival
from repro.core.nvm import NVMConfig
from repro.scenarios import (
    KV_PROFILES,
    CrashPlan,
    KVWorkload,
    ShadowSnapshotStrategy,
    TornSpec,
    deterministic_cell_dict,
    make_strategy,
    measure_divergence_fields,
    register_strategy,
    register_workload,
    run_scenario,
    strategy_names,
    sweep,
)
from repro.scenarios.strategies import STRATEGIES
from repro.scenarios.workloads import WORKLOADS, Workload

KV = ("kv", {"n_steps": 18})
KV_UDB = ("kv", {"n_steps": 18, "profile": "udb"})
STRATS = ("none", "adcc", "undo_log", "checkpoint_nvm@4", "shadow_snapshot")
TORN_EVERY = CrashPlan.at_every_step(torn=TornSpec(fraction=0.5, seed=5,
                                                   samples=2))


def _run_pair(wl, strat, upto):
    """Drive (workload, strategy) through steps [0, upto)."""
    for i in range(upto):
        strat.before_step(i)
        wl.step(i)
        strat.after_step(i)


# ---------------------------------------------------------------------------
# store semantics
# ---------------------------------------------------------------------------

class TestKVStore:
    def test_request_stream_deterministic_and_profiled(self):
        wl = KVWorkload(profile="udb", n_steps=200, n_keys=32)
        reqs = [wl._request(i) for i in range(200)]
        assert reqs == [wl._request(i) for i in range(200)]
        ops = [op for op, _, _ in reqs]
        p = KV_PROFILES["udb"]
        # write-heavy profile: puts materially present, gets dominate puts'
        # complement; exact fractions are seeded so just sanity-band them
        assert 0.4 < ops.count("get") / len(ops) < 0.8
        assert 0.2 < ops.count("put") / len(ops) < 0.6
        sizes = {nw for op, _, nw in reqs if op == "put"}
        assert sizes <= {w for w, _ in p.value_words}
        keys = [k for _, k, _ in reqs]
        assert all(0 <= k < 32 for k in keys)
        # zipfian skew: the hottest key is hit far more than the median
        counts = np.bincount(keys, minlength=32)
        assert counts.max() > 3 * np.median(counts[counts > 0])

    def test_no_crash_run_is_correct_and_oracle_checked(self):
        wl = KVWorkload(n_steps=24)
        wl.setup(NVMConfig(), "plain")
        _run_pair(wl, make_strategy("none"), 24)
        rep = wl.finalize()
        assert rep.correct
        assert rep.metrics["requests"] == 24.0
        maps, counters = wl._oracle()
        assert rep.metrics["live_keys"] == float(len(maps[24]))
        # corrupting one live value is caught by the finalize oracle
        sem = wl._semantic_map()
        key, ent = sorted(sem.items())[0]
        e, off = divmod(ent["goff"], wl.extent_words)
        wl._rvlog[e][off] = int(wl._rvlog[e].view[off]) ^ 1
        assert not wl.finalize().correct

    def test_versioned_slot_rows_preserve_previous_value(self):
        wl = KVWorkload(n_steps=18, profile="udb")
        wl.setup(NVMConfig(), "plain")
        strat = make_strategy("none")
        overwrites = 0
        seen = {}
        for i in range(18):
            op, key, _ = wl._request(i)
            before = seen.get(key)
            strat.before_step(i)
            wl.step(i)
            strat.after_step(i)
            if op != "put":
                continue
            seen[key] = i + 1
            if before is None:
                continue
            overwrites += 1
            _s, rows, found = wl._slot_lookup(key)
            assert found
            # the superseded version row survives in the slot pair, intact
            seqs = sorted(int(rows[v, 1]) for v in (0, 1))
            assert seqs == sorted([before, i + 1])
            assert all(wl._row_ok(rows[v]) for v in (0, 1))
        assert overwrites, "stream never overwrote a key; enlarge n_steps"

    def test_value_log_never_spans_extents(self):
        wl = KVWorkload(n_steps=40, profile="udb", extent_words=32)
        wl.setup(NVMConfig(), "plain")
        _run_pair(wl, make_strategy("none"), 40)
        for key, ent in wl._semantic_map().items():
            e, off = divmod(ent["goff"], wl.extent_words)
            assert off + ent["nw"] <= wl.extent_words

    def test_capacity_exhaustion_raises(self):
        wl = KVWorkload(n_steps=40, profile="udb", extent_words=32,
                        n_extents=1)
        wl.setup(NVMConfig(), "plain")
        with pytest.raises(RuntimeError, match="exhausted"):
            _run_pair(wl, make_strategy("none"), 40)

    def test_constructor_validation(self):
        with pytest.raises(KeyError, match="unknown KV profile"):
            KVWorkload(profile="nope")
        with pytest.raises(ValueError, match="policy"):
            KVWorkload(policy="hope")
        with pytest.raises(ValueError, match="n_slots"):
            KVWorkload(n_keys=8, n_slots=4)


# ---------------------------------------------------------------------------
# shadow_snapshot strategy
# ---------------------------------------------------------------------------

class TestShadowSnapshot:
    def test_registered(self):
        assert "shadow_snapshot" in strategy_names()
        assert isinstance(make_strategy("shadow_snapshot@3"),
                          ShadowSnapshotStrategy)

    def test_scratch_before_first_flip(self):
        # interval 50 > n_steps: the root pointer never flips, so
        # recovery discards the staged shadow and restarts from scratch —
        # losing the acked prefix (KV makes that a durability class)
        r = run_scenario(KV_UDB, "shadow_snapshot@50", CrashPlan.at_step(11))
        assert r.restart_point == -1
        assert r.correctness_class == "durability_violation"

    def test_root_flip_alternates_and_cow_shares_cold_extents(self):
        wl = KVWorkload(n_steps=18, profile="udb")
        wl.setup(NVMConfig(), "plain")
        strat = make_strategy("shadow_snapshot")
        strat.attach(wl)
        actives = []
        shared = 0
        for i in range(18):
            strat.before_step(i)
            wl.step(i)
            strat.after_step(i)
            actives.append(strat._active)
            slots = strat._slots
            if i >= 1:
                prev = slots[1 - strat._active]
                cur = slots[strat._active]
                shared += sum(cur["arrays"][n] is prev["arrays"][n]
                              for n in cur["arrays"])
        assert actives[:4] == [0, 1, 0, 1]
        # with per-extent regions most extents are cold between snapshots
        assert shared > 0

    def test_recovery_discards_unflipped_shadow(self):
        cells = sweep(workloads=(KV_UDB,), strategies=("shadow_snapshot",),
                      plans=(CrashPlan.at_step(11, torn=True),))
        (r,) = cells
        # torn crash mid-step: the staged snapshot of step 11 was never
        # flipped; recovery resumes from the step-10 root
        assert r.restart_point == 10
        assert r.correctness_class in ("consistent_rollback",
                                       "torn_detected")
        assert r.correct

    def test_modeled_overhead_positive_and_below_full_checkpoint(self):
        none = run_scenario(KV_UDB, "none", CrashPlan.no_crash())
        shad = run_scenario(KV_UDB, "shadow_snapshot", CrashPlan.no_crash())
        ckpt = run_scenario(KV_UDB, "checkpoint_nvm", CrashPlan.no_crash())
        assert shad.modeled_total_seconds > none.modeled_total_seconds
        # COW sharing: per-step shadow traffic < full-footprint checkpoint
        assert (shad.traffic["nvm_bytes_written"]
                < ckpt.traffic["nvm_bytes_written"])


# ---------------------------------------------------------------------------
# durability / atomicity correctness classes
# ---------------------------------------------------------------------------

class TestDurabilityClasses:
    def test_scratch_restart_loses_acked_updates(self):
        r = run_scenario(KV_UDB, "none", CrashPlan.at_step(11))
        assert r.correctness_class == "durability_violation"
        assert r.correct is False

    def test_protected_strategies_show_zero_violations(self):
        for s in ("undo_log", "checkpoint_nvm", "shadow_snapshot", "adcc"):
            r = run_scenario(KV_UDB, s, CrashPlan.at_step(11))
            assert r.correctness_class not in ("durability_violation",
                                               "atomicity_violation"), s
            assert r.correct, s

    def test_checkpoint_interval_opens_durability_window(self):
        # ack-on-apply + periodic checkpoint: acked requests since the
        # last checkpoint are lost on crash
        r = run_scenario(KV_UDB, "checkpoint_nvm@6", CrashPlan.at_step(15))
        assert r.restart_point == 11
        assert r.correctness_class == "durability_violation"

    def test_blind_mount_shows_atomicity_violations_validate_never(self):
        blind_kv = ("kv", {"n_steps": 18, "profile": "udb",
                           "policy": "blind"})
        blind_hits = 0
        for step in (0, 7, 10, 16):      # put steps of the udb stream
            for seed in range(4):
                torn = TornSpec(fraction=0.5, seed=seed)
                b = run_scenario(blind_kv, "adcc",
                                 CrashPlan.at_step(step, torn=torn))
                v = run_scenario(KV_UDB, "adcc",
                                 CrashPlan.at_step(step, torn=torn))
                blind_hits += b.correctness_class == "atomicity_violation"
                assert v.correctness_class != "atomicity_violation"
                assert v.info["durability_violations"] == 0
        assert blind_hits > 0

    def test_validate_commit_record_rejects_rootless_writes(self):
        # torn crash where the meta root survives but the request's index
        # row dies: a validating mount must fall back to the previous
        # root instead of adopting a root whose write-set is gone
        wl = KVWorkload(n_steps=18)
        strat = make_strategy("adcc")
        wl.setup(None, "adcc")
        strat.attach(wl)
        _run_pair(wl, strat, 15)
        strat.before_step(15)
        wl.step(15)                      # a put; crash before its flush
        assert any(n == "kv.index" for n, _, _ in wl._touched)
        wl.emu.crash(LineSurvival(fraction=0.5, seed=5))
        rec = strat.recover(15, True, None)
        wl.audit_recovery(rec, 15, True)
        assert rec.info["atomicity_violations"] == 0
        assert rec.info["durability_violations"] == 0
        if rec.resume_step == 16:
            # root adopted => the whole write-set must have survived
            sem = wl._semantic_map()
            _, key, _ = wl._request(15)
            assert sem[key]["seq"] == 16 and sem[key]["ok"]

    def test_audit_acked_prefix_depends_on_torn(self):
        wl = KVWorkload(n_steps=12)
        wl.setup(None, "plain")
        strat = make_strategy("none")
        strat.attach(wl)
        _run_pair(wl, strat, 8)
        wl.emu.crash(None)
        rec = strat.recover(7, False, None)
        wl.audit_recovery(rec, 7, False)
        assert rec.info["acked_requests"] == 8     # boundary: step 7 acked
        maps, _ = wl._oracle()
        assert rec.info["durability_violations"] == len(maps[8])


# ---------------------------------------------------------------------------
# engine paths
# ---------------------------------------------------------------------------

class TestKVEngines:
    def test_batched_mode_is_analytic_and_matches_measure(self):
        # KV used to be the fallback family (its audit override routed
        # every batched cell through per-cell measure); the analytic KV
        # evaluators retired that, so batched must now produce the same
        # cells WITHOUT any cell taking the measure fallback.
        kw = dict(workloads=(KV,), strategies=("shadow_snapshot", "none"),
                  plans=(CrashPlan.no_crash(), TORN_EVERY))
        meas = sweep(mode="measure", **kw)
        bat = sweep(mode="batched", **kw)
        assert len(bat) == len(meas)
        for b, m in zip(bat, meas):
            assert deterministic_cell_dict(b) == deterministic_cell_dict(m)
            assert "batched_fallback" not in b.info

    def test_certification_validate_clean_blind_dirty(self):
        kw = dict(plans=(TORN_EVERY,), mode="measure")
        vcells = sweep(workloads=(KV,), strategies=("adcc",), **kw)
        assert all(c.state_certified is not False for c in vcells)
        bcells = sweep(workloads=(("kv", {"n_steps": 18,
                                          "policy": "blind"}),),
                       strategies=("adcc",), **kw)
        assert any(c.state_certified is False for c in bcells)

    def test_shadow_and_checkpoint_cells_always_certify(self):
        cells = sweep(workloads=(KV_UDB,),
                      strategies=("shadow_snapshot", "checkpoint_nvm@4",
                                  "undo_log"),
                      plans=(TORN_EVERY,), mode="measure")
        assert all(c.state_certified is not False for c in cells)


# ---------------------------------------------------------------------------
# registry collision guards
# ---------------------------------------------------------------------------

class TestRegistryGuards:
    def test_workload_collision_raises_with_names(self):
        with pytest.raises(ValueError) as e:
            register_workload("kv", lambda **kw: KVWorkload(**kw))
        assert "already registered" in str(e.value)
        assert "'kv'" in str(e.value) and "'cg'" in str(e.value)
        assert "override=True" in str(e.value)
        assert WORKLOADS["kv"] is KVWorkload

    def test_workload_override_and_idempotent_reregister(self):
        # same factory re-registration is a no-op, not a collision
        register_workload("kv", KVWorkload)
        sentinel = lambda **kw: KVWorkload(**kw)   # noqa: E731
        register_workload("kv", sentinel, override=True)
        try:
            assert WORKLOADS["kv"] is sentinel
        finally:
            register_workload("kv", KVWorkload, override=True)

    def test_strategy_collision_raises_with_names(self):
        with pytest.raises(ValueError) as e:
            register_strategy("shadow_snapshot",
                              lambda interval=1:
                              ShadowSnapshotStrategy(interval))
        msg = str(e.value)
        assert "already registered" in msg and "override=True" in msg
        assert "shadow_snapshot" in msg and "undo_log" in msg
        assert STRATEGIES["shadow_snapshot"] is ShadowSnapshotStrategy

    def test_strategy_override_allows_replacement(self):
        class Custom(ShadowSnapshotStrategy):
            pass

        register_strategy("shadow_snapshot", Custom, override=True)
        try:
            assert STRATEGIES["shadow_snapshot"] is Custom
        finally:
            register_strategy("shadow_snapshot", ShadowSnapshotStrategy,
                              override=True)

    def test_audit_hook_default_is_noop(self):
        # the batched-engine gate keys on the hook being overridden
        assert type(KVWorkload(n_steps=4)).audit_recovery \
            is not Workload.audit_recovery
        from repro.scenarios.workloads import CGWorkload
        assert CGWorkload.audit_recovery is Workload.audit_recovery
