"""Consistency strategies — *how* a run persists, behind one protocol.

A :class:`ConsistencyStrategy` observes the workload's step axis through
``before_step``/``after_step`` hooks and owns post-crash
:meth:`~ConsistencyStrategy.recover`. The registry covers the paper's
mechanism space:

  none                 no fault tolerance: crash => restart from scratch
  adcc                 algorithm-directed consistence (delegates the
                       flush policy and invariant-scan recovery to the
                       workload's ``adcc_*`` hooks — §III.B-D)
  undo_log             PMEM-style transactions over the critical regions
                       (wraps :class:`repro.core.transactions.TxManager`)
  checkpoint_hdd       synchronous full-copy checkpoint to a hard drive
  checkpoint_nvm       ... to NVM (copy + cache flush)
  checkpoint_nvm_dram  ... on the heterogeneous NVM/DRAM system
                       (wrap :class:`repro.core.checkpoint_baseline.CheckpointBaseline`)
  shadow_snapshot      copy-on-write shadow copy of the critical regions
                       + atomic root-pointer flip; recovery discards the
                       unflipped shadow (the kv-engine atomic-replace
                       design — beyond-paper, motivated by KV serving)

Per-interval variants are spelled ``"<name>@<k>"`` ("checkpoint_nvm@5"
checkpoints every 5 steps). Every strategy also exposes the *modeled*
per-persist-event cost (``modeled_step_seconds``) used by the paper's
runtime figures — see :mod:`repro.scenarios.costmodel`.
"""

from __future__ import annotations

import zlib
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.checkpoint_baseline import CheckpointBaseline
from ..core.nvm import NVMConfig
from ..core.transactions import TxManager
from . import costmodel
from .workloads import RecoveryResult, Workload, unknown_name_error

__all__ = [
    "ConsistencyStrategy",
    "NativeStrategy",
    "AdccStrategy",
    "UndoLogStrategy",
    "CheckpointStrategy",
    "ShadowSnapshotStrategy",
    "STRATEGIES",
    "register_strategy",
    "make_strategy",
    "strategy_names",
]


class ConsistencyStrategy:
    """Base: a no-op mechanism (also the "none"/native baseline)."""

    key: str = "none"
    wants_adcc: bool = False

    def __init__(self, interval: int = 1):
        if interval < 1:
            raise ValueError("interval must be >= 1")
        self.interval = int(interval)
        self.wl: Optional[Workload] = None

    @property
    def name(self) -> str:
        return self.key if self.interval == 1 else f"{self.key}@{self.interval}"

    def attach(self, workload: Workload) -> None:
        self.wl = workload

    # -- step hooks -------------------------------------------------------------
    def before_step(self, i: int) -> None:
        pass

    def after_step(self, i: int) -> None:
        pass

    # -- crash recovery ----------------------------------------------------------
    def recover(self, crash_step: int, torn: bool,
                survival=None) -> RecoveryResult:
        """Post-crash recovery. ``survival`` is the crash point's
        :class:`~repro.core.backends.LineSurvival` (None for boundary
        and all-or-nothing torn crashes) — mechanisms with their own
        integrity machinery (the undo log's log validation) consume it;
        the base restart-from-scratch discards torn state wholesale."""
        self.wl.reset()
        return RecoveryResult(resume_step=0, restart_point=-1,
                              redo_steps=crash_step + 1,
                              steps_lost=crash_step + 1, from_scratch=True)

    # -- snapshot / fork ----------------------------------------------------------
    def snapshot(self) -> object:
        """Capture mid-run mechanism state (undo log, checkpoint area,
        commit counters) for the fork sweep engine. The base strategy —
        and ADCC, whose state lives entirely in the workload's regions —
        has nothing to carry."""
        return None

    def restore_snapshot(self, snap: object) -> None:
        """Reset to a :meth:`snapshot` taken on this attached instance."""

    # -- modeled cost -------------------------------------------------------------
    @classmethod
    def modeled_step_seconds(cls, profile: costmodel.StepCostProfile,
                             cfg: NVMConfig) -> float:
        return costmodel.mechanism_step_seconds(cls.key, profile, cfg)

    def modeled_overhead_seconds(self, profile: costmodel.StepCostProfile,
                                 cfg: NVMConfig, steps_run: int) -> float:
        """Total modeled mechanism cost of a run that executed
        ``steps_run`` steps — the ``overhead_seconds`` cell field,
        charged identically by full and measure-mode evaluation."""
        events = costmodel.persist_events(steps_run, self.interval,
                                          profile, self.wants_adcc)
        return events * self.modeled_step_seconds(profile, cfg)


class NativeStrategy(ConsistencyStrategy):
    key = "none"


class AdccStrategy(ConsistencyStrategy):
    """Algorithm-directed crash consistence: persistence and recovery
    are the workload's own (paper's central mechanism)."""

    key = "adcc"
    wants_adcc = True

    def __init__(self, interval: int = 1):
        if interval != 1:
            raise ValueError(
                "adcc cadence is algorithm-directed: configure it on the "
                "workload (e.g. xsbench flush_every_frac), not via @interval")
        super().__init__(interval)

    def before_step(self, i):
        self.wl.adcc_before_step(i)

    def after_step(self, i):
        self.wl.adcc_after_step(i)

    def recover(self, crash_step, torn, survival=None):
        return self.wl.adcc_recover(crash_step)


class UndoLogStrategy(ConsistencyStrategy):
    """One undo-log transaction per ``interval`` steps over the critical
    regions (copy-before-write at tx begin, flush at commit; a crash
    mid-interval rolls the open transaction back to its begin point)."""

    key = "undo_log"

    def __init__(self, interval: int = 1):
        super().__init__(interval)
        self._mgr: Optional[TxManager] = None
        self._last_commit: Optional[int] = None
        self._scalars: Dict[str, float] = {}
        self._commit_crcs: Dict = {}

    def attach(self, workload):
        super().attach(workload)
        # per-run state: a reused instance must not recover from a
        # previous run's commit point
        self._mgr = TxManager(workload.emu)
        self._last_commit = None
        self._scalars = {}
        self._commit_crcs = {}

    def before_step(self, i):
        if i % self.interval == 0:
            tx = self._mgr.begin()
            for region in self.wl.live_regions():
                tx.snapshot(region)

    def after_step(self, i):
        if (i + 1) % self.interval == 0:
            self._commit_crcs = self._mgr.commit()
            self._last_commit = i
            self._scalars = self.wl.scalar_state()

    def _validate_committed_spans(self) -> int:
        """Post-recovery integrity check: crc32 of every span the last
        commit covered, against the (possibly rolled-back) NVM image.
        Both recovery paths land those spans on exactly the last-commit
        state — rollback rewrites them from the undo records' absolute
        old values, the committed path leaves them as the flush left
        them — so a mismatch is a media fault, not ordinary crash
        damage. Reads are uncharged (``.nvm`` views): the rollback just
        touched these spans or they are resident from the commit, so the
        check rides the recovery's existing traffic."""
        by_name = {r.name: r for r in self.wl.live_regions()}
        bad = 0
        for (name, lo, hi), crc in self._commit_crcs.items():
            reg = by_name.get(name)
            if reg is None:
                continue
            span = reg.nvm.reshape(-1)[lo:hi]
            if zlib.crc32(np.ascontiguousarray(span).tobytes()) != crc:
                bad += 1
        return bad

    def recover(self, crash_step, torn, survival=None):
        report = self._mgr.recover()
        rolled_back = report is not None
        rejected = report.entries_rejected if rolled_back else 0
        if rolled_back:
            # the rollback mutated the NVM image after the crash reload:
            # re-sync program truth with the restored image
            self.wl.resync_from_nvm()
        crc_bad = self._validate_committed_spans()
        # torn_flagged: the mechanism positively identified inconsistent
        # post-crash state — an open (uncommitted) tx means the data it
        # covers may be torn, and the rollback discards it; a rejected
        # torn log-tail is the same signal at the log level
        info = {"rolled_back": rolled_back,
                "log_entries_rejected": rejected,
                "payload_crc_mismatches": crc_bad,
                "torn_flagged": rolled_back or rejected > 0}
        if self._last_commit is None:
            self.wl.reset()
            return RecoveryResult(resume_step=0, restart_point=-1,
                                  redo_steps=crash_step + 1,
                                  steps_lost=crash_step + 1,
                                  from_scratch=True, info=info)
        self.wl.restore(None, self._scalars, self._last_commit)
        resume = self._last_commit + 1
        return RecoveryResult(
            resume_step=resume, restart_point=self._last_commit,
            redo_steps=crash_step + 1 - resume,
            steps_lost=crash_step - self._last_commit, info=info)

    def snapshot(self):
        return {"last_commit": self._last_commit,
                "scalars": dict(self._scalars),
                "commit_crcs": dict(self._commit_crcs),
                "mgr": self._mgr.state_snapshot()}

    def restore_snapshot(self, snap):
        self._last_commit = snap["last_commit"]
        self._scalars = dict(snap["scalars"])
        self._commit_crcs = dict(snap["commit_crcs"])
        self._mgr.restore_state(snap["mgr"])


class CheckpointStrategy(ConsistencyStrategy):
    """Synchronous full-copy checkpoint every ``interval`` steps."""

    key = "checkpoint_nvm"
    target = "nvm_only"

    def __init__(self, interval: int = 1):
        super().__init__(interval)
        self._base: Optional[CheckpointBaseline] = None
        self._last_ckpt: Optional[int] = None
        self._scalars: Dict[str, float] = {}

    def attach(self, workload):
        super().attach(workload)
        # per-run state: a reused instance must not recover from a
        # previous run's checkpoint step
        self._base = CheckpointBaseline(workload.emu, self.target)
        self._last_ckpt = None
        self._scalars = {}

    def after_step(self, i):
        if (i + 1) % self.interval == 0:
            self._base.checkpoint(i, self.wl.live_regions())
            self._last_ckpt = i
            self._scalars = self.wl.scalar_state()

    def recover(self, crash_step, torn, survival=None):
        if self._last_ckpt is None:
            self.wl.reset()
            return RecoveryResult(resume_step=0, restart_point=-1,
                                  redo_steps=crash_step + 1,
                                  steps_lost=crash_step + 1,
                                  from_scratch=True)
        arrays = self._base.restore()
        self.wl.restore(arrays, self._scalars, self._last_ckpt)
        resume = self._last_ckpt + 1
        return RecoveryResult(
            resume_step=resume, restart_point=self._last_ckpt,
            redo_steps=crash_step + 1 - resume,
            steps_lost=crash_step - self._last_ckpt)

    def snapshot(self):
        return {"last_ckpt": self._last_ckpt,
                "scalars": dict(self._scalars),
                "base": self._base.state_snapshot()}

    def restore_snapshot(self, snap):
        self._last_ckpt = snap["last_ckpt"]
        self._scalars = dict(snap["scalars"])
        self._base.restore_state(snap["base"])


class CheckpointHddStrategy(CheckpointStrategy):
    key = "checkpoint_hdd"
    target = "hdd"


class CheckpointNvmDramStrategy(CheckpointStrategy):
    key = "checkpoint_nvm_dram"
    target = "nvm_dram"


class ShadowSnapshotStrategy(ConsistencyStrategy):
    """Copy-on-write shadow snapshot + atomic root-pointer flip every
    ``interval`` steps (the kv-engine atomic-replace design).

    Two snapshot slots alternate: a persist event copies the critical
    regions into the *staging* slot — sharing (not recopying) any region
    whose truth epoch is unchanged since the active snapshot, which is
    what makes this cheaper than a full checkpoint on workloads with
    cold regions (a KV store's untouched value extents) — then flips the
    root pointer to the staging slot with one persisted 8-byte write.
    A crash mid-copy loses nothing: the root still points at the old
    slot, and recovery simply discards the unflipped shadow."""

    key = "shadow_snapshot"

    def __init__(self, interval: int = 1):
        super().__init__(interval)
        self._slots: List[Optional[Dict[str, object]]] = [None, None]
        self._active: int = -1       # root pointer; -1 = never flipped

    def attach(self, workload):
        super().attach(workload)
        # per-run state: a reused instance must not recover from a
        # previous run's snapshot
        self._slots = [None, None]
        self._active = -1

    def after_step(self, i):
        if (i + 1) % self.interval:
            return
        emu = self.wl.emu
        cfg, stats = emu.cfg, emu.stats
        prev = self._slots[self._active] if self._active >= 0 else None
        arrays: Dict[str, object] = {}
        epochs: Dict[str, int] = {}
        for r in self.wl.live_regions():
            e = emu.truth_epoch(r.name)
            if prev is not None and prev["epochs"].get(r.name) == e:
                # unchanged since the active snapshot: share its copy
                arrays[r.name] = prev["arrays"][r.name]
            else:
                data = r.view.copy()
                # copy into the shadow area = source cache flush + NVM
                # write (the checkpoint_nvm charging model)
                self.wl.emu.flush(r.name)
                stats.charge_write(data.nbytes, cfg)
                arrays[r.name] = data
            epochs[r.name] = e
        staging = 1 - self._active if self._active >= 0 else 0
        self._slots[staging] = {"arrays": arrays,
                                "scalars": dict(self.wl.scalar_state()),
                                "step": i, "epochs": epochs}
        # the atomic commit: one persisted root-pointer write
        stats.charge_write(8, cfg)
        stats.charge_flush_issue(1, cfg)
        self._active = staging

    def recover(self, crash_step, torn, survival=None):
        # any half-written staging slot is simply discarded: the root
        # pointer only ever references a fully-persisted snapshot
        discarded = (self._slots[1 - self._active] is not None
                     if self._active >= 0 else self._slots[0] is not None)
        info = {"shadow_discarded": discarded}
        if self._active < 0:
            self.wl.reset()
            return RecoveryResult(resume_step=0, restart_point=-1,
                                  redo_steps=crash_step + 1,
                                  steps_lost=crash_step + 1,
                                  from_scratch=True, info=info)
        slot = self._slots[self._active]
        cfg, stats = self.wl.emu.cfg, self.wl.emu.stats
        for data in slot["arrays"].values():
            stats.charge_read(data.nbytes, cfg)
        self.wl.restore(dict(slot["arrays"]), dict(slot["scalars"]),
                        slot["step"])
        resume = slot["step"] + 1
        return RecoveryResult(
            resume_step=resume, restart_point=slot["step"],
            redo_steps=crash_step + 1 - resume,
            steps_lost=crash_step - slot["step"], info=info)

    def snapshot(self):
        # slots are replaced wholesale (never mutated in place), so a
        # shallow copy of the slot list is a true capture
        return {"slots": list(self._slots), "active": self._active}

    def restore_snapshot(self, snap):
        self._slots = list(snap["slots"])
        self._active = snap["active"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Callable[..., ConsistencyStrategy]] = {
    "none": NativeStrategy,
    "adcc": AdccStrategy,
    "undo_log": UndoLogStrategy,
    "checkpoint_hdd": CheckpointHddStrategy,
    "checkpoint_nvm": CheckpointStrategy,
    "checkpoint_nvm_dram": CheckpointNvmDramStrategy,
    "shadow_snapshot": ShadowSnapshotStrategy,
}


def register_strategy(name: str,
                      factory: Callable[..., ConsistencyStrategy], *,
                      override: bool = False) -> None:
    """Register a strategy factory under ``name``.

    Re-registering an existing name raises (a silent overwrite would
    make every subsequent sweep spec mean something else) unless the
    factory is identical (idempotent re-import) or ``override=True``.
    """
    if not override and name in STRATEGIES and STRATEGIES[name] is not factory:
        raise ValueError(
            f"strategy {name!r} already registered "
            f"(registered: {strategy_names()}); pass override=True "
            f"to replace it")
    STRATEGIES[name] = factory


def strategy_names() -> List[str]:
    return sorted(STRATEGIES)


def make_strategy(spec) -> ConsistencyStrategy:
    """spec: instance | "name" | "name@interval" (e.g. "checkpoint_nvm@5")."""
    if isinstance(spec, ConsistencyStrategy):
        return spec
    name, _, interval = str(spec).partition("@")
    if name not in STRATEGIES:
        raise unknown_name_error("strategy", name, STRATEGIES)
    return STRATEGIES[name](interval=int(interval)) if interval \
        else STRATEGIES[name]()
