"""Shared benchmark utilities: timing, CSV emission, cost models.

Runtime-overhead figures (paper Figs. 4/8/13) report
``(native_compute + modeled_mechanism_seconds) / native_compute``:
compute time is measured wall-clock on this host, mechanism cost is
charged through the NVM emulator's bandwidth model (NVM = DRAM/8,
Quartz-style — paper §III.A). Recomputation/correctness figures
(Figs. 3/7/10/12) run the real crash emulator end to end.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

ART = os.path.join(os.path.dirname(__file__), "artifacts")


@dataclasses.dataclass
class Row:
    name: str
    value: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.value:.6g},{self.derived}"


def write_json(path: str, payload) -> None:
    """Shared machine-readable artifact writer (suite artifacts, the
    ``--json`` combined output, and the scenario sweep all use the same
    underlying writer — repro.scenarios.driver.dump_json)."""
    from repro.scenarios.driver import dump_json

    dump_json(path, payload)


def rows_to_records(rows: List[Row]) -> List[Dict]:
    return [dataclasses.asdict(r) for r in rows]


def emit(rows: List[Row], save_as: Optional[str] = None) -> None:
    for r in rows:
        print(r.csv(), flush=True)
    if save_as:
        write_json(os.path.join(ART, save_as), rows_to_records(rows))


def dense_figure_cli(run_fn: Callable, artifact: str, argv=None) -> None:
    """Shared ``__main__`` entry for the dense-matrix figure suites
    (fig3/fig7/fig_torn): ``--smoke`` + ``--workers`` + ``--mode`` flags
    over a ``run(smoke=, workers=, mode=)`` suite function. With
    ``--mode batched`` the matrix is evaluated by the batched engine and
    the suites' gate stack pins it cell-for-cell against a fresh
    measure-mode sweep."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI size axis (gates run at every size)")
    ap.add_argument("--workers", type=int, default=None,
                    help="processes for the sweep "
                         "(default: REPRO_SWEEP_WORKERS or 2)")
    ap.add_argument("--mode", default="measure",
                    choices=["measure", "batched"],
                    help="cell evaluation mode (default: measure)")
    args = ap.parse_args(argv)
    emit(run_fn(smoke=args.smoke or None, workers=args.workers,
                mode=args.mode),
         save_as=artifact)


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Best-of wall time."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best
