"""Paper Figs. 10 + 12: XSBench result correctness after crash+restart.

Three scenario cells on identical counter-based random inputs (the flush
*policy* — the algorithm-directed part — is a workload parameter):

  no crash, selective            -> ground truth counts
  crash, basic restart (index)   -> loses counts (Fig. 10's failure)
  crash, selective flush (Fig.11)-> bitwise-identical counts (Fig. 12)
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, run_scenario

from .common import Row, emit

ARTIFACT = "fig10_12_mc_correctness.json"

PARAMS = dict(lookups=60_000, grid_points=20_000, n_nuclides=34,
              n_materials=12, max_nuclides_per_material=8,
              flush_every_frac=1e-4, seed=7)
CRASH_AT = 6_000   # 10% of lookups, as in the paper


def run() -> List[Row]:
    cfg = NVMConfig(cache_bytes=2 * 1024 * 1024, replacement="fifo")
    crash = CrashPlan.at_step(CRASH_AT - 1)
    ok = run_scenario(("xsbench", {**PARAMS, "policy": "selective"}),
                      "adcc", CrashPlan.no_crash(), cfg=cfg)
    basic = run_scenario(("xsbench", {**PARAMS, "policy": "basic"}),
                         "adcc", crash, cfg=cfg)
    sel = run_scenario(("xsbench", {**PARAMS, "policy": "selective"}),
                       "adcc", crash, cfg=cfg)

    rows = []
    for t in range(5):
        rows.append(Row(f"fig10/type{t+1}/no_crash_pct",
                        100 * ok.info["fractions"][t]))
        rows.append(Row(f"fig10/type{t+1}/basic_restart_pct",
                        100 * basic.info["fractions"][t]))
        rows.append(Row(f"fig12/type{t+1}/selective_restart_pct",
                        100 * sel.info["fractions"][t]))
    lookups = PARAMS["lookups"]
    rows.append(Row("fig10/basic_restart/counts_lost",
                    lookups - int(basic.info["counts"].sum()),
                    f"iterations_lost={basic.steps_lost}"))
    rows.append(Row("fig12/selective_restart/exact_match",
                    float(np.array_equal(sel.info["counts"],
                                         ok.info["counts"])),
                    "counts bitwise-identical to no-crash run"))
    rows.append(Row("fig12/selective_restart/iterations_lost",
                    sel.steps_lost,
                    f"bound={int(lookups * PARAMS['flush_every_frac'])}"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
