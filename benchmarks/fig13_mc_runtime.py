"""Paper Fig. 13: XSBench runtime with the seven mechanisms.

The persisted objects are tiny (macro_xs_vector + 5 counters + index =
~13 cache lines), flushed/checkpointed every 0.01% of lookups. The
NVM/DRAM checkpoint still pays a whole-DRAM-cache flush per checkpoint —
the paper's 13% outlier; ADCC flushes ~13 lines: <=0.05% overhead.
Runtime measured as wall-clock lookup loop (numpy, no emulator) with
per-interval mechanism costs charged through the central cost model
(``repro.scenarios.xsbench_step_profile`` + ``mechanism_cases()``).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.scenarios import mechanism_cases, xsbench_step_profile

from .common import Row, emit

ARTIFACT = "fig13_mc_runtime.json"

LOOKUPS = 200_000
# paper-matched ABSOLUTE interval: 0.01% of the paper's 1.5e7 lookups
# (tying it to our scaled-down total would shrink intervals 75x and
# exaggerate every mechanism's overhead equally)
FLUSH_EVERY = 1_500
GRID = 40_000
NUCLIDES = 34


def _native_lookup_seconds() -> float:
    """Vectorized XSBench-like lookup kernel (compute only)."""
    rng = np.random.default_rng(0)
    egrid = np.sort(rng.uniform(0, 20, GRID))
    nuc = rng.uniform(0.1, 10, (GRID, NUCLIDES, 5))
    t0 = time.perf_counter()
    B = 2000
    for i in range(0, LOOKUPS, B):
        e = rng.uniform(0, 20, B)
        idx = np.clip(np.searchsorted(egrid, e) - 1, 0, GRID - 2)
        sel = rng.integers(0, NUCLIDES, (B, 6))
        x0 = nuc[idx[:, None], sel]
        x1 = nuc[idx[:, None] + 1, sel]
        t = ((e - egrid[idx]) / np.maximum(egrid[idx + 1] - egrid[idx],
                                           1e-30))[:, None, None]
        macro = (x0 * (1 - t) + x1 * t).sum(axis=1)
        cdf = np.cumsum(macro, axis=1)
        cdf /= cdf[:, -1:]
        _ = (rng.uniform(0, 1, (B, 1)) < cdf).argmax(axis=1)
    return time.perf_counter() - t0


def run() -> List[Row]:
    native = _native_lookup_seconds()
    rows = [Row("fig13/mc_runtime/native_seconds", native,
                f"{LOOKUPS} lookups")]
    n_flushes = LOOKUPS // FLUSH_EVERY
    for case in mechanism_cases():
        cfg = case.config()
        profile = xsbench_step_profile(cfg.line_bytes,
                                       interval_steps=FLUSH_EVERY)
        mech = n_flushes * case.step_seconds(profile, cfg)
        rows.append(Row(f"fig13/mc_runtime/{case.name}/normalized",
                        (native + mech) / native, f"mech={mech*1e3:.2f}ms"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
