"""Beyond-paper figure: persistent KV serving — durability overhead vs
throughput, and safe-fraction vs torn survival.

The paper budgets algorithm-directed crash consistence at <= 8.2%
runtime overhead on HPC kernels. This suite asks the serving-side
version of that question: what does each mechanism cost *per
acknowledged request*, and which of them actually honor the
acknowledgement after a crash?

Matrix: KV request-stream profiles (ETC read-heavy / UDB write-heavy,
plus a blind-recovery UDB variant) x strategies {none, adcc, undo_log,
checkpoint_nvm@k, shadow_snapshot@k} x (no_crash + dense torn
``at_every_step`` plans across survival fractions), evaluated in
measure mode through the shared sweep stack.

Reported:

  * per (profile, strategy): mechanism overhead in us/request and as a
    percentage of a modeled in-memory service envelope
    (``SERVICE_SECONDS`` per request, ~100k req/s per core — a
    conservative memcached-class service time), plus the implied
    throughput. The paper's <= 8.2% budget is the headline: the
    algorithm-directed per-request strategy (``adcc``) must fit it;
    wholesale mechanisms (full-footprint checkpoints, region-copy undo
    logs) are reported blowing through it — the serving restatement of
    the paper's Figs. 4/8.
  * per (profile, strategy, survival fraction): the correctness-class
    census and the *violation-free fraction* — cells free of
    ``durability_violation`` / ``atomicity_violation`` /
    ``torn_corrupt`` / ``lost_updates``.

Gates (every run, smoke or full — ``check_kv_gates``):

  * the shared dense-gate core: sharded merge identical, every
    measure-cell field equals the full-execution cell;
  * class/correctness coherence: a violation-classified cell never
    finalizes correct; a ``complete`` cell always does;
  * ``shadow_snapshot`` and ``adcc`` (validating) show ZERO
    durability/atomicity violations across every crash cell;
  * scratch-restart (``none``) shows a NONZERO ``durability_violation``
    count — the audit actually bites;
  * the blind-recovery variant shows at least one
    ``atomicity_violation`` cell (the class is reachable);
  * headline budget: adcc per-request overhead <= 8.2% of the service
    envelope on every profile.
"""

from __future__ import annotations

import os
from collections import Counter
from typing import Dict, List, Tuple

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, TornSpec, sweep

from .common import ART, Row, write_json

ARTIFACT = "fig_kv.json"
BENCH_JSON = os.path.join(ART, "BENCH_kv.json")

SEED = 31
OVERHEAD_BUDGET_PCT = 8.2          # the paper's headline budget
SERVICE_SECONDS = 10e-6            # modeled per-request service envelope

FRACTIONS = (0.25, 0.5, 0.75)
SMOKE_FRACTIONS = (0.5,)
SAMPLES = 2

WORKLOADS = (
    ("kv", {"profile": "etc", "n_steps": 48, "seed": 11}),
    ("kv", {"profile": "udb", "n_steps": 48, "seed": 11}),
    ("kv", {"profile": "udb", "n_steps": 48, "seed": 11,
            "policy": "blind"}),
)
SMOKE_WORKLOADS = (
    ("kv", {"profile": "etc", "n_steps": 20, "seed": 11}),
    ("kv", {"profile": "udb", "n_steps": 20, "seed": 11}),
    ("kv", {"profile": "udb", "n_steps": 20, "seed": 11,
            "policy": "blind"}),
)
STRATEGIES = ("none", "adcc", "undo_log", "checkpoint_nvm@4",
              "shadow_snapshot")

VIOLATION_CLASSES = ("durability_violation", "atomicity_violation",
                     "torn_corrupt", "lost_updates")
# strategies that preserve the acknowledged prefix by construction
# (per-request persistence or interval-1 rollback): they must never
# surface a violation cell. checkpoint_nvm@4 is the deliberate
# counterexample — ack-on-apply plus a periodic checkpoint opens a
# durability window, and the census reports it.
CLEAN_STRATEGIES = ("adcc", "shadow_snapshot", "undo_log")


def _plans(fractions) -> Tuple[CrashPlan, ...]:
    dense = tuple(
        CrashPlan.at_every_step(
            torn=TornSpec(fraction=f, seed=SEED, mode="random",
                          samples=SAMPLES))
        for f in fractions)
    return (CrashPlan.no_crash(),) + dense


def _sweep_kw(smoke: bool) -> Dict:
    wls, fr = ((SMOKE_WORKLOADS, SMOKE_FRACTIONS) if smoke
               else (WORKLOADS, FRACTIONS))
    return dict(workloads=wls, strategies=STRATEGIES, plans=_plans(fr),
                cfg=NVMConfig(cache_bytes=1024 * 1024))


def _wl_key(cell) -> str:
    p = cell.workload_params
    key = p.get("profile", "etc")
    if p.get("policy", "validate") != "validate":
        key += f"+{p['policy']}"
    return key


def _frac_of(cell) -> float:
    _mode, frac, _seed = cell.torn_survival.split(":", 2)
    return float(frac[1:])


def overhead_table(cells) -> Dict[Tuple[str, str], Dict[str, float]]:
    """Per (profile, strategy): us/request mechanism cost, % of the
    service envelope, implied throughput — from the no_crash cells."""
    base: Dict[str, float] = {}
    totals: Dict[Tuple[str, str], float] = {}
    steps: Dict[Tuple[str, str], int] = {}
    for c in cells:
        if c.crash_step is not None:
            continue
        key = (_wl_key(c), c.strategy)
        totals[key] = c.modeled_total_seconds
        steps[key] = c.workload_params["n_steps"]
        if c.strategy == "none":
            base[_wl_key(c)] = c.modeled_total_seconds
    table = {}
    for (wl, strat), total in totals.items():
        mech_s = (total - base[wl]) / steps[(wl, strat)]
        pct = 100.0 * mech_s / SERVICE_SECONDS
        table[(wl, strat)] = {
            "mechanism_us_per_request": 1e6 * mech_s,
            "overhead_pct": pct,
            "within_budget": pct <= OVERHEAD_BUDGET_PCT,
            "requests_per_second": 1.0 / (SERVICE_SECONDS + mech_s),
        }
    return table


def check_kv_gates(kw: Dict, cells, workers: int) -> None:
    """The gate stack documented in the module docstring. Explicit
    raises (not asserts): these are CI gates and must survive
    ``python -O``."""
    from .scenarios_sweep import run_dense_cross_checks

    full = run_dense_cross_checks(kw, cells, workers)

    violations: Counter = Counter()
    atom_by_policy: Counter = Counter()
    for c in full:
        key = (c.workload, _wl_key(c), c.strategy, c.plan, c.crash_step)
        if c.correctness_class in VIOLATION_CLASSES and c.correct:
            raise AssertionError(
                f"violation-classified cell finalized CORRECT: {key} "
                f"class={c.correctness_class}")
        if c.correctness_class == "complete" and not c.correct:
            raise AssertionError(
                f"complete cell finalized INCORRECT: {key}")
        if c.correctness_class in ("durability_violation",
                                   "atomicity_violation"):
            if c.workload_params.get("policy", "validate") == "validate":
                violations[c.strategy] += 1
            if c.correctness_class == "atomicity_violation":
                atom_by_policy[c.workload_params.get("policy",
                                                     "validate")] += 1

    for strat in CLEAN_STRATEGIES:
        if violations.get(strat):
            raise AssertionError(
                f"{strat} surfaced {violations[strat]} durability/"
                f"atomicity violation cells; expected zero")
    if not violations.get("none"):
        raise AssertionError(
            "scratch-restart baseline shows no durability_violation "
            "cells — the acked-prefix audit is not biting")
    if not atom_by_policy.get("blind"):
        raise AssertionError(
            "blind-recovery variant surfaced no atomicity_violation "
            "cells — the torn-visibility audit is not biting")
    if atom_by_policy.get("validate"):
        raise AssertionError(
            "validating recovery surfaced atomicity_violation cells")

    for (wl, strat), row in sorted(overhead_table(full).items()):
        if strat == "adcc" and not row["within_budget"]:
            raise AssertionError(
                f"adcc on {wl}: {row['overhead_pct']:.2f}% per-request "
                f"overhead exceeds the {OVERHEAD_BUDGET_PCT}% budget")


def run(smoke: bool = None, workers: int = None,
        mode: str = "measure") -> List[Row]:
    from .scenarios_sweep import resolve_sweep_env

    smoke, workers = resolve_sweep_env(smoke, workers)
    kw = _sweep_kw(smoke)
    cells = sweep(mode=mode, workers=workers, **kw)
    check_kv_gates(kw, cells, workers)

    table = overhead_table(cells)
    census: Dict[Tuple, Counter] = {}
    for c in cells:
        if c.torn_survival is None:
            continue
        key = (_wl_key(c), c.strategy, _frac_of(c))
        census.setdefault(key, Counter())[c.correctness_class] += 1

    rows = []
    for (wl, strat), t in sorted(table.items()):
        prefix = f"fig_kv/{wl}/{strat}"
        rows.append(Row(f"{prefix}/overhead_pct", t["overhead_pct"],
                        f"{t['mechanism_us_per_request']:.3f}us/req "
                        f"budget={OVERHEAD_BUDGET_PCT}% "
                        f"within={t['within_budget']}"))
        rows.append(Row(f"{prefix}/requests_per_second",
                        t["requests_per_second"],
                        f"service={1e6 * SERVICE_SECONDS:g}us/req"))
    for key in sorted(census):
        wl, strat, frac = key
        counts = census[key]
        total = sum(counts.values())
        bad = sum(counts[k] for k in VIOLATION_CLASSES)
        rows.append(Row(
            f"fig_kv/{wl}/{strat}/f={frac:g}/violation_free_fraction",
            (total - bad) / total,
            " ".join(f"{k}={v}" for k, v in sorted(counts.items()))))

    write_json(BENCH_JSON, {
        "schema": "repro.scenarios.kv/v1",
        "smoke": bool(smoke),
        "budget_pct": OVERHEAD_BUDGET_PCT,
        "service_seconds_per_request": SERVICE_SECONDS,
        "matrix": {
            "workloads": [[w, p] for w, p in kw["workloads"]],
            "strategies": list(STRATEGIES),
            "plans": [p.describe() for p in kw["plans"]],
        },
        "overhead": [
            {"profile": wl, "strategy": strat, **t}
            for (wl, strat), t in sorted(table.items())],
        "coverage": [
            {"profile": k[0], "strategy": k[1], "fraction": k[2],
             "classes": dict(census[k])}
            for k in sorted(census)],
        "cells": [c.to_json_dict() for c in cells],
    })
    rows.append(Row("fig_kv/summary/cells", len(cells),
                    f"artifact={BENCH_JSON}"))
    return rows


def main(argv=None) -> None:
    from .common import dense_figure_cli
    dense_figure_cli(run, ARTIFACT, argv)


if __name__ == "__main__":
    main()
