"""Tests for the self-healing shard pool (repro.scenarios.pool):
ordered results, chaos-injected kill/hang healing, error semantics
(real exceptions skip the retry ladder), graceful degradation, journal
resume (only missing shards re-execute), and lock hygiene.
"""

import multiprocessing
import os
import time

import pytest

from repro.scenarios.pool import ShardFailure, job_fingerprint, run_sharded


def square(job):
    return job * job


def marking_square(job):
    """job = (value, marker_dir, fail_flag_dir) — drops a 'ran-<value>'
    marker so the parent can observe which shards actually executed,
    and fails on value 3 unless the 'allow' flag file exists."""
    value, marker_dir, flag_dir = job
    with open(os.path.join(marker_dir, f"ran-{value}"), "w"):
        pass
    if value == 3 and not os.path.exists(os.path.join(flag_dir, "allow")):
        raise ValueError("shard 3 not allowed yet")
    return value * value


def failing_worker(job):
    if job >= 0:
        raise ValueError(f"boom on {job}")
    return job * job


class TestBasics:
    def test_results_in_job_order(self):
        jobs = list(range(8))
        assert run_sharded(jobs, square, 3) == [j * j for j in jobs]

    def test_single_worker(self):
        assert run_sharded([1, 2, 3], square, 1) == [1, 4, 9]

    def test_workers_validation(self):
        with pytest.raises(ValueError):
            run_sharded([1], square, 0)

    def test_fingerprint_is_stable_and_job_sensitive(self):
        assert job_fingerprint((1, "a")) == job_fingerprint((1, "a"))
        assert job_fingerprint((1, "a")) != job_fingerprint((2, "a"))


class TestChaosHealing:
    def test_killed_shard_is_retried(self):
        events = []
        got = run_sharded(list(range(6)), square, 2, chaos={0: "kill"},
                          retries=2, backoff=0.01,
                          progress_cb=events.append)
        assert got == [j * j for j in range(6)]
        assert any(e["event"] == "retry" and e["job"] == 0
                   and e["reason"] == "died" for e in events)

    def test_hung_shard_times_out_and_retries(self):
        events = []
        got = run_sharded(list(range(4)), square, 2, chaos={1: "hang"},
                          timeout=1.0, retries=2, backoff=0.01,
                          progress_cb=events.append)
        assert got == [j * j for j in range(4)]
        assert any(e["event"] == "retry" and e["job"] == 1
                   and e["reason"] == "timeout" for e in events)

    def test_chaos_injected_only_on_first_attempt(self):
        # kill + hang on the same sweep, one worker slot: both heal
        got = run_sharded([5, 6], square, 1, chaos={0: "kill", 1: "hang"},
                          timeout=1.0, retries=1, backoff=0.01)
        assert got == [25, 36]


class TestErrorsAndDegradation:
    def test_worker_exception_skips_retry_ladder(self):
        events = []
        with pytest.raises(ShardFailure) as exc:
            run_sharded([7], failing_worker, 1, retries=3, backoff=0.01,
                        progress_cb=events.append)
        assert exc.value.reason == "error"
        assert "boom on 7" in exc.value.detail
        # a deterministic exception is never retried — re-running
        # identical code on an identical job only re-raises
        assert not any(e["event"] == "retry" for e in events)

    def test_degrade_maps_job_to_fallback(self):
        events = []
        got = run_sharded([4, -2], failing_worker, 2, retries=0,
                          backoff=0.01, degrade=lambda job, reason: -job,
                          progress_cb=events.append)
        assert got == [16, 4]        # shard 0 ran as its degraded twin
        assert any(e["event"] == "degrade" and e["job"] == 0
                   for e in events)

    def test_degrade_exhausted_raises(self):
        with pytest.raises(ShardFailure):
            run_sharded([4], failing_worker, 1, retries=0,
                        degrade=lambda job, reason: None)

    def test_no_child_processes_survive_failure(self):
        with pytest.raises(ShardFailure):
            run_sharded([1], failing_worker, 1, retries=0)
        deadline = time.monotonic() + 5.0
        while multiprocessing.active_children():
            assert time.monotonic() < deadline, "orphaned shard children"
            time.sleep(0.05)


class TestJournalResume:
    def _jobs(self, tmp_path):
        return [(i, str(tmp_path), str(tmp_path)) for i in range(4)]

    def test_resume_reexecutes_only_missing_shards(self, tmp_path):
        journal = str(tmp_path / "sweep.jsonl")
        jobs = self._jobs(tmp_path)
        with pytest.raises(ShardFailure):
            run_sharded(jobs, marking_square, 2, retries=0,
                        journal=journal)
        assert os.path.exists(journal)           # partial progress kept
        assert not os.path.exists(journal + ".lock")   # lock released
        ran_first = {f for f in os.listdir(str(tmp_path))
                     if f.startswith("ran-")}
        assert ran_first == {"ran-0", "ran-1", "ran-2", "ran-3"}

        for f in ran_first:
            os.unlink(str(tmp_path / f))
        with open(str(tmp_path / "allow"), "w"):
            pass
        events = []
        got = run_sharded(jobs, marking_square, 2, retries=0,
                          journal=journal, progress_cb=events.append)
        assert got == [0, 1, 4, 9]
        # shards 0-2 came from the journal; only shard 3 re-executed
        ran_second = {f for f in os.listdir(str(tmp_path))
                      if f.startswith("ran-")}
        assert ran_second == {"ran-3"}
        assert sorted(e["job"] for e in events
                      if e["event"] == "resumed") == [0, 1, 2]
        assert not os.path.exists(journal)       # consumed on success

    def test_changed_job_invalidates_its_entry_only(self, tmp_path):
        journal = str(tmp_path / "sweep.jsonl")
        jobs = list(range(3))
        assert run_sharded(jobs, square, 2, journal=journal) == [0, 1, 4]
        # journal was deleted on success: a fresh run re-executes all
        assert run_sharded([0, 1, 5], square, 2, journal=journal) \
            == [0, 1, 25]

    def test_live_lock_owner_blocks(self, tmp_path):
        proc = multiprocessing.Process(target=time.sleep, args=(30,))
        proc.start()
        journal = str(tmp_path / "sweep.jsonl")
        try:
            with open(journal + ".lock", "w") as fh:
                fh.write(str(proc.pid))          # someone else, alive
            with pytest.raises(RuntimeError, match="locked by live pid"):
                run_sharded([1], square, 1, journal=journal)
        finally:
            proc.terminate()
            proc.join()
            os.unlink(journal + ".lock")

    def test_stale_lock_from_dead_owner_is_taken_over(self, tmp_path):
        proc = multiprocessing.Process(target=lambda: None)
        proc.start()
        proc.join()                              # a provably dead pid
        journal = str(tmp_path / "sweep.jsonl")
        with open(journal + ".lock", "w") as fh:
            fh.write(str(proc.pid))
        assert run_sharded([2], square, 1, journal=journal) == [4]
        assert not os.path.exists(journal + ".lock")
