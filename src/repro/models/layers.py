"""Shared transformer layers: norms, RoPE/M-RoPE, GQA attention, SwiGLU.

Conventions
-----------
* Pure-functional: params are nested dicts of jnp arrays; every ``init_*``
  returns ``(params, axes)`` where ``axes`` mirrors params with tuples of
  *logical dimension names* consumed by sharding/partition.py:

    embed    model width D            -> FSDP axis ("data") when enabled
    qheads   fused H*head_dim         -> TP axis ("model")
    kvheads  fused KV*head_dim        -> replicated (KV < TP in all archs)
    mlp      FFN hidden F             -> TP axis ("model")
    vocab    vocabulary               -> TP axis ("model")
    experts  MoE expert count         -> EP axis ("model")
    layers   stacked-scan leading dim -> never sharded

* Compute runs in ``cfg.compute_dtype`` (bf16 by default); params stay in
  ``cfg.param_dtype``. Attention logits/softmax in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]


def shard_act(x: jax.Array, mesh, *, seq_axis: Optional[int] = 1):
    """Pin a (B, S, ...) activation's batch dim to the DP mesh axes.

    GSPMD's sharding propagation does not survive ``lax.scan`` while-loop
    boundaries without in-body constraints — unconstrained residual
    streams come out *batch-replicated* across the data axis (measured:
    16x redundant attention compute on llama3 train_4k; EXPERIMENTS.md
    §Perf iteration 1). Applied at every layer boundary.
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    if not dp:
        return x
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if x.shape[0] % dp_size != 0:
        return x  # e.g. batch=1 long-context decode
    spec = [dp] + [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# logical dims that stay TP-sharded when a layer's weights are gathered
# (first matching dim wins — expert weights keep EP on the experts dim,
# their FFN dim replicates)
_TP_NAMES = ("experts", "qheads", "mlp", "vocab", "ssm_inner")


def gather_weights(lp, axes, mesh):
    """ZeRO-3 weight gather at the layer boundary: re-constrain every
    weight leaf to its TP-only sharding (FSDP 'embed' dim unsharded).

    Left to its own cost model, GSPMD keeps weights 2D-sharded and
    all-reduces f32 *activations* over the data axis instead (~247 GB/chip
    per llama3-8b train step — §Perf iteration 4). Applying the
    constraint inside the scan body makes the compiler all-gather each
    layer's bf16 weights once per direction, which is ~8x less traffic.
    """
    if mesh is None:
        return lp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    is_axes = lambda t: (isinstance(t, tuple)
                         and all(isinstance(s, str) for s in t))

    def one(w, ax):
        if ax and ax[0] == "layers":
            ax = ax[1:]  # the body sees a single layer slice
        if len(ax) != w.ndim or "model" not in mesh.axis_names:
            return w
        entries = []
        used = False
        for i, a in enumerate(ax):
            take = (not used and a in _TP_NAMES
                    and w.shape[i] % mesh.shape["model"] == 0)
            entries.append("model" if take else None)
            used = used or take
        spec = P(*entries)
        return jax.lax.with_sharding_constraint(w, NamedSharding(mesh, spec))

    leaves, treedef = jax.tree.flatten(lp)
    ax_leaves = treedef.flatten_up_to(axes)
    return treedef.unflatten([one(w, a) for w, a in zip(leaves, ax_leaves)])


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, in_name: str, out_name: str,
               dtype) -> Tuple[jax.Array, Tuple[str, str]]:
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    return w.astype(dtype), (in_name, out_name)


def embed_init(key, vocab: int, dim: int, dtype):
    w = jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02
    return w.astype(dtype), ("vocab", "embed")


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype):
    return jnp.ones((dim,), dtype=dtype), ("embed",)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the even head dims (head_dim must be even)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]              # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions3: (3, B, S) temporal/height/width position
    streams. ``sections`` partitions the hd/2 frequency slots among the
    three streams (e.g. (16, 24, 24) for hd=128)."""
    import numpy as _np
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # pick, per frequency slot, which position stream drives it (static)
    sec_ids = _np.repeat(_np.arange(3), _np.asarray(sections))  # (hd/2,)
    assert sec_ids.shape[0] == hd // 2, "mrope sections must sum to hd/2"
    pos = positions3.astype(jnp.float32)[sec_ids]       # (hd/2, B, S)
    angles = jnp.moveaxis(pos, 0, -1) * freqs           # (B, S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# flash (blockwise) attention — forward-only prefill path
# --------------------------------------------------------------------------

def flash_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mesh, *,
               causal: bool = True) -> jax.Array:
    """Pallas blockwise attention for prefill (no S^2 HBM traffic).

    Heads stay TP-sharded: a shard_map wrapper gives every model-shard
    its query heads plus a dynamic slice of the (replicated) KV heads —
    contiguous GQA ordering makes each shard's heads span whole KV
    groups whenever H/tp divides G or vice versa. Falls back to the
    caller's jnp path when the head count does not tile (checked by the
    caller). Forward-only: the Pallas kernel has no VJP, so training
    keeps the XLA attention."""
    from jax.sharding import PartitionSpec as P

    from ..kernels.flash_attention.ops import flash_attention

    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] == 1:
        return flash_attention(q, k, v, causal=causal)

    B, S, H, hd = q.shape
    KV = k.shape[2]
    tp = mesh.shape["model"]
    H_loc = H // tp
    G = H // KV
    n_kv_loc = max(1, -(-H_loc // G))
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local(qs, ks, vs):
        idx = jax.lax.axis_index("model")
        kv0 = (idx * H_loc) // G
        ks_l = jax.lax.dynamic_slice(
            ks, (0, 0, kv0, 0), ks.shape[:2] + (n_kv_loc, hd))
        vs_l = jax.lax.dynamic_slice(
            vs, (0, 0, kv0, 0), vs.shape[:2] + (n_kv_loc, hd))
        return flash_attention(qs, ks_l, vs_l, causal=causal)

    q_spec = P(dp, None, "model", None)
    kv_spec = P(dp, None, None, None)
    return jax.shard_map(local, mesh=mesh,
                         in_specs=(q_spec, kv_spec, kv_spec),
                         out_specs=q_spec, check_vma=False)(q, k, v)


def flash_applicable(cfg, q_heads: int, seq: int, mesh) -> bool:
    tp = mesh.shape["model"] if (mesh is not None
                                 and "model" in mesh.axis_names) else 1
    if q_heads % tp != 0 or seq % 8 != 0:
        return False
    H_loc = q_heads // tp
    G = q_heads // max(cfg.n_kv_heads, 1)
    return (H_loc % G == 0) or (G % H_loc == 0)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------

def attention_init(cfg: ModelConfig, key) -> Tuple[Params, Axes]:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], D, H * hd, "embed", "qheads", dtype)
    p["wk"], a["wk"] = dense_init(ks[1], D, KV * hd, "embed", "kvheads", dtype)
    p["wv"], a["wv"] = dense_init(ks[2], D, KV * hd, "embed", "kvheads", dtype)
    p["wo"], a["wo"] = dense_init(ks[3], H * hd, D, "qheads", "embed", dtype)
    return p, a


def _sdpa(q, k, v, *, causal: bool, q_pos0: int | jax.Array = 0,
          kv_len: Optional[jax.Array] = None):
    """Grouped dot-product attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd). H % KV == 0. f32 softmax
    accumulation via preferred_element_type (no materialized f32 copies
    of q/k/v).

    GQA is computed by repeating KV heads up to H rather than splitting
    the H dim into (KV, G): H is TP-sharded, and reshaping a sharded dim
    into (KV, G) factors that do not divide the TP degree forces GSPMD
    into involuntary full rematerialization — batch-replicated S^2
    tensors (measured: 40x memory-term inflation on llama3 train_4k;
    EXPERIMENTS.md §Perf iteration 1).

    ``q_pos0``: absolute position of q[0] (decode offsets).
    ``kv_len``: valid prefix length of k/v (decode with preallocated cache).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    Sk = k.shape[1]
    grouped = (kv_len is not None) and KV != H
    if grouped:
        # decode path: grouped einsum, never materialize the KV repeat
        # (repeating a 32k-token cache G-fold costs G x cache bytes per
        # step and triggers a full-cache kv-axis all-gather under TP —
        # §Perf iteration 6; decode runs with attention heads replicated
        # so the (KV, G) q reshape is shard-free).
        G = H // KV
        qg = q.reshape(B, Sq, KV, G, hd)
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                            preferred_element_type=jnp.float32) / (hd ** 0.5)
    else:
        if KV != H:  # train/prefill: repeat is S-bounded and TP-friendly
            k = jnp.repeat(k, H // KV, axis=2)
            v = jnp.repeat(v, H // KV, axis=2)
        logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                            preferred_element_type=jnp.float32) / (hd ** 0.5)
    if causal:
        qpos = q_pos0 + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]           # (Sq, Sk)
        shape = (1, 1, 1) if grouped else (1, 1)
        logits = jnp.where(mask.reshape(shape + mask.shape), logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
        vshape = ((-1, 1, 1, 1, Sk) if grouped else (-1, 1, 1, Sk))
        logits = jnp.where(valid.reshape(vshape), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    if grouped:
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                    positions: jax.Array, *,
                    mrope_positions: Optional[jax.Array] = None,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    cache_index: Optional[jax.Array] = None,
                    mesh=None, flash: bool = False):
    """Full attention. With ``cache`` (dict k/v (B, Smax, KV, hd)) performs
    one decode step: x is (B, 1, D), cache_index is the write position.
    Returns (out, new_cache)."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, KV, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, KV, hd)

    if cfg.mrope_sections:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.family != "audio":  # hubert frontend embeds positions already
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        out = _sdpa(q, k_cache, v_cache, causal=False,
                    kv_len=cache_index + S)
    elif flash and cfg.causal and flash_applicable(cfg, H, S, mesh):
        # Pallas blockwise attention: prefill only (forward-only kernel)
        out = flash_sdpa(q, k, v, mesh, causal=True)
    else:
        out = _sdpa(q, k, v, causal=cfg.causal)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"].astype(x.dtype), new_cache


def attention_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    """(cache pytree, axes) for one attention layer."""
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.compute_dtype)
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    ax = ("batch", "seq_cache", "kvheads_sep", "head_dim")
    return cache, {"k": ax, "v": ax}


# --------------------------------------------------------------------------
# SwiGLU FFN
# --------------------------------------------------------------------------

def swiglu_init(cfg: ModelConfig, key, d_ff: Optional[int] = None
                ) -> Tuple[Params, Axes]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w_gate"], a["w_gate"] = dense_init(ks[0], D, F, "embed", "mlp", dtype)
    p["w_up"], a["w_up"] = dense_init(ks[1], D, F, "embed", "mlp", dtype)
    p["w_down"], a["w_down"] = dense_init(ks[2], F, D, "mlp", "embed", dtype)
    return p, a


def swiglu_apply(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = jax.nn.silu(x @ p["w_gate"].astype(dt))
    up = x @ p["w_up"].astype(dt)
    return (gate * up) @ p["w_down"].astype(dt)
