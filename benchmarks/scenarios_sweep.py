"""Scenario sweep: the full workloads × strategies × crash-points matrix
through ``repro.scenarios.sweep()`` in one call, on the vectorized
emulation backend. Emits one row per cell plus the machine-readable
``BENCH_scenarios.json`` artifact (the EasyCrash-style systematic
characterization of post-crash consistence).

Default matrix: 3 workloads × 6 strategies × 4 crash points = 72 cells.
``--smoke`` (or REPRO_SCENARIOS_SMOKE=1) shrinks it to the CI matrix:
3 workloads × 3 strategies × 2 crash plans. ``--engine fork|rerun``
selects the sweep engine (fork default).

This module also hosts the fork-vs-rerun engine comparison
(:func:`fork_vs_rerun_timing` / :func:`run_timing`, surfaced as the
``sweep`` suite in benchmarks/run.py and benchmarks/sweep_timing.py):
a dense one-crash-point-per-step matrix timed under both engines,
emitted to ``BENCH_sweep.json``, with a hard divergence gate — any
cell whose deterministic payload differs between engines fails the run
(CI relies on this).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

from repro.core.nvm import NVMConfig
from repro.scenarios import (DEFAULT_SWEEP_PLANS, CrashPlan,
                             deterministic_cell_dict, sweep)

from .common import ART, Row, emit, write_json

ARTIFACT = "scenarios_sweep.json"
BENCH_JSON = os.path.join(ART, "BENCH_scenarios.json")
BENCH_SWEEP_JSON = os.path.join(ART, "BENCH_sweep.json")

WORKLOADS = (
    ("cg", {"n": 4096, "iters": 12}),
    ("mm", {"n": 128, "k": 32}),
    ("xsbench", {"lookups": 1500, "grid_points": 2000,
                 "flush_every_frac": 0.01}),
)
STRATEGIES = ("none", "adcc", "undo_log", "checkpoint_hdd",
              "checkpoint_nvm", "checkpoint_nvm_dram")
PLANS = DEFAULT_SWEEP_PLANS

SMOKE_WORKLOADS = (
    ("cg", {"n": 1024, "iters": 8}),
    ("mm", {"n": 64, "k": 16}),
    ("xsbench", {"lookups": 400, "grid_points": 800,
                 "flush_every_frac": 0.02}),
)
SMOKE_STRATEGIES = ("none", "adcc", "checkpoint_nvm")
SMOKE_PLANS = (CrashPlan.no_crash(), CrashPlan.at_fraction(0.5))


# -- fork-vs-rerun engine comparison (BENCH_sweep.json) ----------------------
#
# The dense matrix exercises the fork engine's reason to exist: ONE
# crash point per step (exhaustive fig 3/7-style recompute curves), so
# the rerun baseline pays O(setup + prefix + tail) per cell while fork
# pays O(restore + tail) off a single shared forward pass. XSBench is
# sized the way the application actually looks — large read-only
# cross-section tables (copy-on-write snapshots capture them once) in
# front of a comparatively short lookup loop — which is exactly the
# shape where per-cell re-initialization dominates an EasyCrash-style
# dense sweep.
TIMING_WORKLOADS = (
    ("cg", {"n": 4096, "iters": 16}),
    ("mm", {"n": 48, "k": 4}),
    ("xsbench", {"lookups": 40, "grid_points": 10_000, "n_nuclides": 40,
                 "n_materials": 12, "max_nuclides_per_material": 8,
                 "flush_every_frac": 0.1, "seed": 7}),
)
SMOKE_TIMING_WORKLOADS = (
    ("cg", {"n": 2048, "iters": 10}),
    ("mm", {"n": 48, "k": 4}),
    ("xsbench", {"lookups": 24, "grid_points": 8000, "n_nuclides": 32,
                 "n_materials": 8, "max_nuclides_per_material": 6,
                 "flush_every_frac": 0.1, "seed": 7}),
)
TIMING_STRATEGIES = ("adcc", "undo_log", "checkpoint_nvm")
TIMING_PLANS = (CrashPlan.no_crash(), CrashPlan.at_every_step())


def fork_vs_rerun_timing(smoke: bool = None) -> Dict:
    """Time the dense matrix under both engines and cross-check every
    cell's deterministic payload. Returns the BENCH_sweep.json payload
    (divergences included — callers decide whether to fail)."""
    if smoke is None:
        smoke = bool(int(os.environ.get("REPRO_SCENARIOS_SMOKE", "0")))
    workloads = SMOKE_TIMING_WORKLOADS if smoke else TIMING_WORKLOADS
    cfg = NVMConfig(cache_bytes=1 * 1024 * 1024)
    kw = dict(workloads=workloads, strategies=TIMING_STRATEGIES,
              plans=TIMING_PLANS, cfg=cfg)
    seconds = {}
    cells = {}
    for engine in ("rerun", "fork"):
        t0 = time.perf_counter()
        cells[engine] = sweep(engine=engine, **kw)
        seconds[engine] = time.perf_counter() - t0
    divergences = []
    for a, b in zip(cells["rerun"], cells["fork"]):
        da, db = deterministic_cell_dict(a), deterministic_cell_dict(b)
        if da != db:
            divergences.append({
                "workload": a.workload, "strategy": a.strategy,
                "plan": a.plan, "crash_step": a.crash_step,
                "fields": sorted(k for k in da if da[k] != db[k]),
            })
    if len(cells["rerun"]) != len(cells["fork"]):
        divergences.append({"reason": "cell count mismatch",
                            "rerun": len(cells["rerun"]),
                            "fork": len(cells["fork"])})
    return {
        "schema": "repro.scenarios.sweep_timing/v1",
        "smoke": bool(smoke),
        "matrix": {
            "workloads": [[w, p] for w, p in workloads],
            "strategies": list(TIMING_STRATEGIES),
            "plans": [p.describe() for p in TIMING_PLANS],
        },
        "cells": len(cells["fork"]),
        "rerun_seconds": seconds["rerun"],
        "fork_seconds": seconds["fork"],
        "speedup": seconds["rerun"] / max(seconds["fork"], 1e-12),
        "divergences": divergences,
    }


def run_timing(smoke: bool = None) -> List[Row]:
    """The ``sweep`` suite: write BENCH_sweep.json, emit summary rows,
    and FAIL on any fork/rerun divergence (the CI gate)."""
    payload = fork_vs_rerun_timing(smoke)
    write_json(BENCH_SWEEP_JSON, payload)
    rows = [
        Row("sweep/cells", payload["cells"],
            f"plans={'+'.join(payload['matrix']['plans'])}"),
        Row("sweep/rerun_seconds", payload["rerun_seconds"],
            "every cell re-runs from step 0"),
        Row("sweep/fork_seconds", payload["fork_seconds"],
            "one forward pass per pair + per-cell tails"),
        Row("sweep/speedup", payload["speedup"],
            f"artifact={BENCH_SWEEP_JSON}"),
        Row("sweep/divergences", len(payload["divergences"]),
            "fork vs rerun deterministic payload mismatches (must be 0)"),
    ]
    if payload["divergences"]:
        raise AssertionError(
            f"fork and rerun sweep engines diverged on "
            f"{len(payload['divergences'])} cells: "
            f"{payload['divergences'][:3]} (see {BENCH_SWEEP_JSON})")
    return rows


def run(smoke: bool = None, engine: str = "fork") -> List[Row]:
    if smoke is None:
        smoke = bool(int(os.environ.get("REPRO_SCENARIOS_SMOKE", "0")))
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    strategies = SMOKE_STRATEGIES if smoke else STRATEGIES
    plans = SMOKE_PLANS if smoke else PLANS
    cfg = NVMConfig(cache_bytes=1 * 1024 * 1024)
    cells = sweep(workloads=workloads, strategies=strategies, plans=plans,
                  cfg=cfg, out_json=BENCH_JSON, engine=engine)
    rows = []
    n_correct = 0
    for c in cells:
        cell = f"scenarios/{c.workload}/{c.strategy}/{c.plan}"
        n_correct += int(c.correct)
        rows.append(Row(f"{cell}/correct", float(c.correct),
                        f"crash_step={c.crash_step}"))
        rows.append(Row(f"{cell}/steps_lost", c.steps_lost,
                        f"restart={c.restart_point}"))
        rows.append(Row(f"{cell}/overhead_seconds", c.overhead_seconds,
                        f"modeled_total={c.modeled_total_seconds:.3e}s"))
    rows.append(Row("scenarios/summary/cells", len(cells),
                    f"matrix={len(workloads)}x{len(strategies)}x{len(plans)}"))
    rows.append(Row("scenarios/summary/correct_cells", n_correct,
                    f"artifact={BENCH_JSON}"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI matrix: 3 workloads x 3 strategies x 2 plans")
    ap.add_argument("--engine", default="fork", choices=["fork", "rerun"],
                    help="sweep execution engine (default: fork)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke or None, engine=args.engine), save_as=ARTIFACT)
