"""Kernel micro-bench: ABFT-matmul fused checksum overhead vs plain
matmul (the paper's §III.C "ignorable overhead" claim, kernel-level).

CPU wall numbers are indicative only (interpret-mode Pallas is not the
TPU path); the structural claim measured here is the *flop/byte delta*
of the fused epilogue: +2 reductions over an already-resident VMEM
accumulator tile, amortized to O(1/bn + 1/bm) relative overhead.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.abft_matmul.ref import abft_matmul_ref

from .common import Row, emit, timeit

ARTIFACT = "kernel_bench.json"

SIZES = [256, 512]


def run() -> List[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for n in SIZES:
        a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)

        plain = jax.jit(lambda x, y: x @ y)
        fused = jax.jit(lambda x, y: abft_matmul_ref(x, y))
        jax.block_until_ready(plain(a, b))
        jax.block_until_ready(fused(a, b))
        t_plain = timeit(lambda: jax.block_until_ready(plain(a, b)), 5)
        t_fused = timeit(lambda: jax.block_until_ready(fused(a, b)), 5)
        rows.append(Row(f"kernel/abft_matmul/n={n}/us_per_call",
                        t_fused * 1e6))
        rows.append(Row(f"kernel/abft_matmul/n={n}/checksum_overhead",
                        t_fused / max(t_plain, 1e-12),
                        f"plain={t_plain*1e6:.1f}us"))
        # structural overhead: extra flops of the checksum epilogue
        extra = 2.0 * n * n            # row + col sums
        mm = 2.0 * n * n * n
        rows.append(Row(f"kernel/abft_matmul/n={n}/extra_flops_frac",
                        extra / mm))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
