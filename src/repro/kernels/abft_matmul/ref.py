"""Pure-jnp oracle for the ABFT matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["abft_matmul_ref", "abft_encode_full_ref"]


@jax.jit
def abft_matmul_ref(a: jax.Array, b: jax.Array):
    """Reference: (C, row_checksums (m,), col_checksums (n,)) in f32
    accumulation regardless of input dtype (matches the kernel's MXU
    accumulation semantics)."""
    c32 = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return c32.astype(a.dtype), jnp.sum(c32, axis=1), jnp.sum(c32, axis=0)


@jax.jit
def abft_encode_full_ref(a: jax.Array, b: jax.Array):
    """Full-checksum product C_f = A_c @ B_r (paper Eq. 5), (m+1, n+1)."""
    c, row, col = abft_matmul_ref(a, b)
    total = jnp.sum(row)
    top = jnp.concatenate([c.astype(jnp.float32), row[:, None]], axis=1)
    bottom = jnp.concatenate([col, total[None]])[None, :]
    return jnp.concatenate([top, bottom], axis=0)
