"""Conjugate Gradient with algorithm-directed crash consistence (§III.B).

Implements Fig. 2 of the paper: the four hot vectors p, q, r, z gain an
iteration dimension (VersionedArray), only the cache line holding the
iteration counter is flushed per iteration, and recovery backward-scans
iterations testing the two algorithm invariants

    Eq. 1:  p^(i+1) . q^(i) = 0            (A-conjugacy of directions)
    Eq. 2:  r^(i+1) = b - A z^(i+1)        (residual equality)

against the post-crash NVM image until a consistent iteration is found.

Note on the paper's pseudocode: Fig. 1/2 contain two classic typos
(line 7 should be ``r <- r - alpha*q`` and line 10 ``p <- r + beta*p``;
p must be initialized to r). We implement standard CG — the invariants
the paper states (Eqs. 1-2) hold for it exactly.

The sparse matrix is CSR, built as an NPB-CG-style random SPD system;
its data/index arrays live in NVM as read-only regions registered with
coarse cache sectors (DESIGN.md §7) so matvec read-traffic creates the
eviction pressure the paper's performance characterization relies on.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..core.invariants import InvariantSet, OrthogonalityInvariant, ResidualInvariant
from ..core.nvm import CrashEmulator, NVMConfig
from ..core.recovery import RecoveryOutcome, backward_scan
from ..core.versioned import FlushedCounter, VersionedArray

__all__ = ["CsrMatrix", "make_spd_system", "CGRunResult", "ADCC_CG", "plain_cg"]


@dataclasses.dataclass
class CsrMatrix:
    """Minimal CSR sparse matrix (numpy-only; scipy is not installed)."""

    n: int
    data: np.ndarray      # (nnz,) float64
    indices: np.ndarray   # (nnz,) int32 column ids
    indptr: np.ndarray    # (n+1,) int64

    def matvec(self, x: np.ndarray) -> np.ndarray:
        prod = self.data * x[self.indices]
        # rows are equal-width in our generator; general path via reduceat
        return np.add.reduceat(prod, self.indptr[:-1])

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def nbytes(self) -> int:
        return self.data.nbytes + self.indices.nbytes + self.indptr.nbytes


def make_spd_system(n: int, nnz_per_row: int = 8, seed: int = 0
                    ) -> Tuple[CsrMatrix, np.ndarray]:
    """Random symmetric positive-definite CSR system (diagonally dominant),
    NPB-CG-flavoured: fixed nnz per row, random off-diagonal pattern."""
    rng = np.random.default_rng(seed)
    k = max(2, nnz_per_row)
    cols = np.empty((n, k), dtype=np.int32)
    vals = np.empty((n, k), dtype=np.float64)
    off = rng.integers(0, n, size=(n, k - 1), dtype=np.int64)
    offv = rng.uniform(-1.0, 1.0, size=(n, k - 1)) * 0.5 / (k - 1)
    # symmetrize implicitly by diagonal dominance (sufficient for SPD here):
    cols[:, :-1] = off
    vals[:, :-1] = offv
    cols[:, -1] = np.arange(n, dtype=np.int32)
    vals[:, -1] = 1.0 + np.abs(offv).sum(axis=1) + rng.uniform(0.1, 1.0, size=n)
    # CSR with equal-width rows; sort columns within the row for realism
    order = np.argsort(cols, axis=1)
    cols = np.take_along_axis(cols, order, axis=1)
    vals = np.take_along_axis(vals, order, axis=1)
    A_unsym = CsrMatrix(
        n=n,
        data=vals.reshape(-1),
        indices=cols.reshape(-1),
        indptr=np.arange(0, n * k + 1, k, dtype=np.int64),
    )
    # make it symmetric: A := (A + A^T)/2 done implicitly by using
    # M(x) = 0.5*(A x + A^T x); cheaper: build normal-equations-free SPD by
    # keeping the unsymmetric pattern but using A^T A would square cond.
    # Diagonal dominance already gives positive-definiteness of (A+A^T)/2,
    # so expose the symmetrized operator while storing A once.
    return A_unsym, rng.uniform(-1.0, 1.0, size=n)


def _sym_matvec(A: CsrMatrix, x: np.ndarray) -> np.ndarray:
    """(A + A^T)/2 @ x without materializing A^T: scatter-add transpose."""
    ax = A.matvec(x)
    prod = A.data * np.repeat(x, np.diff(A.indptr))
    atx = np.bincount(A.indices, weights=prod, minlength=A.n)
    return 0.5 * (ax + atx)


def plain_cg(A: CsrMatrix, b: np.ndarray, iters: int) -> np.ndarray:
    """Reference CG (no persistence machinery) — the oracle."""
    n = A.n
    z = np.zeros(n)
    r = b.copy()
    p = r.copy()
    rho = float(r @ r)
    for _ in range(iters):
        q = _sym_matvec(A, p)
        pq = float(p @ q)
        if pq <= 0.0 or rho == 0.0:   # converged (or numerically exhausted)
            break
        alpha = rho / pq
        z = z + alpha * p
        r = r - alpha * q
        rho_new = float(r @ r)
        beta = rho_new / rho
        rho = rho_new
        p = r + beta * p
    return z


@dataclasses.dataclass
class CGRunResult:
    z: np.ndarray
    iters_done: int
    crashed_at: Optional[int]
    restart_iter: Optional[int]
    iterations_lost: Optional[int]
    detect_seconds: float
    resume_seconds: float
    avg_iter_seconds: float
    modeled_overhead_seconds: float
    recovery: Optional[RecoveryOutcome] = None


class ADCC_CG:
    """CG with the paper's ADCC extension over the crash emulator."""

    def __init__(self, A: CsrMatrix, b: np.ndarray, iters: int,
                 cfg: Optional[NVMConfig] = None, emulate_reads: bool = True):
        self.A, self.b, self.iters = A, b, iters
        self.emu = CrashEmulator(cfg or NVMConfig())
        self.emulate_reads = emulate_reads
        n = A.n
        V = iters + 2  # versions 0..iters+1
        # big read-mostly regions get coarse sectors (16 lines = 1KB)
        self._rA = self.emu.alloc("A.data", A.data.shape, np.float64,
                                  init=A.data, sector_lines=16)
        self._rAi = self.emu.alloc("A.indices", A.indices.shape, np.int32,
                                   init=A.indices, sector_lines=16)
        self._rb = self.emu.alloc("b", b.shape, np.float64, init=b, sector_lines=16)
        self.p = VersionedArray(self.emu, "p", V, n, sector_lines=4)
        self.q = VersionedArray(self.emu, "q", V, n, sector_lines=4)
        self.r = VersionedArray(self.emu, "r", V, n, sector_lines=4)
        self.z = VersionedArray(self.emu, "z", V, n, sector_lines=4)
        self.counter = FlushedCounter(self.emu, "iter")
        # inputs are persisted once up-front (they are program inputs)
        for reg in (self._rA, self._rAi, self._rb):
            reg.flush()

    # -- one CG iteration against the emulator ---------------------------------
    def _touch_matvec_reads(self) -> None:
        if self.emulate_reads:
            self.emu.read("A.data", 0, self.A.data.shape[0])
            self.emu.read("A.indices", 0, self.A.indices.shape[0])

    def _iterate(self, i: int, rho: float) -> float:
        """Iteration i: consumes version i, produces version i+1."""
        self.counter.set(i)                      # flush one cache line
        p_i = self.p.get(i)
        self._touch_matvec_reads()
        q_i = _sym_matvec(self.A, p_i)
        self.q.set(i, q_i)
        pq = float(p_i @ q_i)
        if pq <= 0.0 or rho == 0.0:
            # converged: carry the iterates forward unchanged (restarting
            # anywhere past convergence yields the same solution)
            self.z.set(i + 1, self.z.get(i))
            self.r.set(i + 1, self.r.get(i))
            self.p.set(i + 1, p_i)
            return rho
        alpha = rho / pq
        self.z.set(i + 1, self.z.get(i) + alpha * p_i)
        r_next = self.r.get(i) - alpha * q_i
        self.r.set(i + 1, r_next)
        rho_new = float(r_next @ r_next)
        beta = rho_new / rho if rho > 0 else 0.0
        self.p.set(i + 1, r_next + beta * p_i)
        return rho_new

    def _init_iterates(self) -> float:
        n = self.A.n
        r0 = self.b.copy()  # z0 = 0
        self.z.set(0, np.zeros(n))
        self.r.set(0, r0)
        self.p.set(0, r0)
        return float(r0 @ r0)

    # -- driver -----------------------------------------------------------------
    def run(self, crash_at_iter: Optional[int] = None) -> CGRunResult:
        """Deprecated: run CG, optionally crashing at the *end* of
        iteration ``crash_at_iter`` (after its stores, before the next
        counter flush), then recover and resume to completion.

        This is a legacy shim over the unified scenario driver — use
        ``repro.scenarios.run_scenario(("cg", {...}), "adcc", plan)``.
        """
        warnings.warn(
            "ADCC_CG.run() is deprecated; use repro.scenarios.run_scenario("
            "('cg', params), 'adcc', CrashPlan.at_step(k))",
            DeprecationWarning, stacklevel=2)
        from ..scenarios import CrashPlan, run_scenario
        from ..scenarios.workloads import CGWorkload

        # old semantics: a crash point past the last iteration never fires
        plan = (CrashPlan.at_step(crash_at_iter)
                if crash_at_iter is not None and 0 <= crash_at_iter < self.iters
                else CrashPlan.no_crash())
        res = run_scenario(CGWorkload(impl=self), "adcc", plan)
        return CGRunResult(
            z=res.info["z"], iters_done=self.iters,
            crashed_at=res.crash_step, restart_iter=res.restart_point,
            iterations_lost=res.info.get("iterations_lost"),
            detect_seconds=res.detect_seconds,
            resume_seconds=res.resume_seconds,
            avg_iter_seconds=res.avg_step_seconds,
            modeled_overhead_seconds=res.modeled_total_seconds,
            recovery=res.info.get("recovery"),
        )

    # -- recovery ------------------------------------------------------------------
    def recover(self, upper_iter: int) -> RecoveryOutcome:
        """Backward-scan from the persisted counter, checking Eqs. 1-2
        against the NVM image."""
        b_nvm = self._rb.nvm.copy()

        def load(j: int) -> Dict[str, np.ndarray]:
            return {
                "p_next": self.p.nvm_version(j + 1),
                "q_cur": self.q.nvm_version(j),
                "r_next": self.r.nvm_version(j + 1),
                "z_next": self.z.nvm_version(j + 1),
            }

        def invs(_j: int) -> InvariantSet:
            return InvariantSet([
                OrthogonalityInvariant("p_next", "q_cur", tol=1e-7),
                ResidualInvariant("r_next", "z_next", b=b_nvm,
                                  matvec=lambda x: _sym_matvec(self.A, x),
                                  tol=1e-6),
            ])

        def charge(data: Dict[str, np.ndarray]) -> float:
            nbytes = sum(a.nbytes for a in data.values()) + self.A.nbytes()
            return nbytes / self.emu.cfg.read_bw

        return backward_scan(upper_iter, 0, load, invs, charge)
