"""Paper §III.C end to end: ABFT matrix multiplication with ADCC.

1. Runs the two-loop checksum-extended MM (Fig. 6) under the crash
   emulator, crashes mid-loop-1 and mid-loop-2, and recovers via
   checksum verification (+ recomputation of torn chunks).
2. Shows single-element error *correction* from checksums alone.
3. Runs the fused-epilogue Pallas kernel (TPU target, interpret mode on
   CPU) and verifies its checksums against the jnp oracle.

    PYTHONPATH=src python examples/abft_matmul_demo.py
"""

import numpy as np

from repro.algorithms.mm_abft import ABFTMatmul
from repro.core import abft
from repro.core.nvm import NVMConfig


def crash_demo() -> None:
    rng = np.random.default_rng(0)
    n, k = 512, 128
    A = rng.uniform(-1, 1, (n, n))
    B = rng.uniform(-1, 1, (n, n))
    for loop, it in [("loop1", 2), ("loop2", 2)]:
        mm = ABFTMatmul(A, B, k, NVMConfig(cache_bytes=2 * 1024 * 1024))
        res = mm.run(crash_after=(loop, it))
        print(f"== crash in {loop}: {res.chunks_lost} chunk(s) torn, "
              f"{res.corrected_elements} element(s) checksum-corrected, "
              f"final |C - A@B|_max = {res.max_error:.2e}")


def correction_demo() -> None:
    rng = np.random.default_rng(1)
    C = rng.uniform(-1, 1, (64, 64))
    Cf = abft.encode_full(C)
    Cf[17, 42] += 3.14159          # single corrupted element
    fixed, nfix = abft.correct_single_error(Cf)
    print(f"== single-error correction: fixed {nfix} element, "
          f"recovered exactly: {np.allclose(fixed, abft.encode_full(C))}")


def kernel_demo() -> None:
    import jax.numpy as jnp
    from repro.kernels.abft_matmul.ops import abft_matmul_full
    from repro.kernels.checksum_verify.ops import verify_checksums
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(192, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 160)), jnp.float32)
    cf = abft_matmul_full(a, b)           # Pallas fused epilogue
    ok, _, _ = verify_checksums(cf)       # Pallas detection kernel
    print(f"== Pallas fused-checksum matmul: C_f {cf.shape}, "
          f"checksums verify: {bool(ok)}")
    bad = cf.at[5, 7].add(10.0)
    ok2, rres, cres = verify_checksums(bad)
    import jax.numpy as jnp2
    print(f"== tampered element detected at row "
          f"{int(jnp2.argmax(jnp2.abs(rres)))}, col "
          f"{int(jnp2.argmax(jnp2.abs(cres)))} (truth: 5, 7)")


def main() -> None:
    crash_demo()
    correction_demo()
    kernel_demo()


if __name__ == "__main__":
    main()
