"""Per-architecture configs (one module per assigned arch) + base types."""

from .base import SHAPES, MeshConfig, ModelConfig, ShapeConfig, TrainConfig

__all__ = ["SHAPES", "MeshConfig", "ModelConfig", "ShapeConfig", "TrainConfig"]
