"""Emulated NVM + volatile cache + crash semantics (paper §III.A).

The paper studies crash consistence with a PIN-based emulator: program
loads/stores go through a configurable LRU cache sitting in front of
NVM-based main memory; on a crash, cache contents are lost and only the
NVM image survives. This module reproduces that machinery in pure
numpy at cache-line granularity, plus a bandwidth/latency *cost model*
(Quartz-style: NVM bandwidth = DRAM/8 by default) so mechanism overheads
can be charged in modeled seconds independent of host speed.

Three layers:

  NVMStore        persistent image (survives ``crash()``) + traffic stats
  MemoryBackend   volatile write-back cache emulation over the store —
                  pluggable (repro.core.backends): an exact per-entry
                  ``reference`` oracle, a batched ``vectorized``
                  default, and a jax-jit ``device`` backend — all with
                  identical semantics
  CrashEmulator   couples program "truth" arrays with backend+store;
                  provides ``crash()`` / ``recover()``, region
                  allocation, and the program-visible read/write/flush
                  facade consumers go through

Granularity: a *line* is ``line_bytes`` of a region's flattened buffer.
Program views ("truth") always hold the latest values — the backend
tracks *which lines would still be dirty in a volatile cache*, i.e.
which bytes have NOT yet reached NVM. ``crash()`` discards exactly
those bytes.

Cost model notes (paper §II): flushing a clean or absent line costs the
same order as flushing a dirty one, so ``flush`` charges per-line cost
unconditionally. CLFLUSH also invalidates, so flushed lines leave the
cache. The full set of cost-model invariants backends must uphold is
documented in backends/base.py.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

import numpy as np

from .backends import corrupt_image_words, make_backend
from .backends.reference import ReferenceLRUBackend

__all__ = [
    "NVMConfig",
    "TrafficStats",
    "NVMStore",
    "VolatileCache",
    "CrashEmulator",
    "EmuSnapshot",
    "NestedCrashFault",
]


class NestedCrashFault(RuntimeError):
    """Raised by the emulator when an armed nested-crash trap fires:
    power failed *again*, mid-recovery. Strategies must let it propagate
    (recovery code never catches it); the driver crashes the emulator a
    second time and retries recovery — which is what makes re-entrancy
    a tested property instead of an assumption."""

# Back-compat alias: the pre-backend cache class lives on as the
# reference backend (same semantics, entry-at-a-time OrderedDict).
VolatileCache = ReferenceLRUBackend


def _default_backend() -> str:
    return os.environ.get("REPRO_NVM_BACKEND", "vectorized")


@dataclasses.dataclass(frozen=True)
class NVMConfig:
    """Cache geometry + bandwidth cost model + backend selection.

    Defaults mirror the paper's setup: 32 MB cache (their DRAM cache size;
    we use it as the volatile-cache capacity for crash experiments can be
    overridden per-test), 64 B lines, NVM bandwidth = DRAM/8 (Quartz
    configuration), DRAM ~25.6 GB/s (2×DDR3-1600 as on their Xeon E5606
    box), local HDD ~120 MB/s for checkpoint baselines.
    """

    cache_bytes: int = 32 * 1024 * 1024
    dram_cache_bytes: int = 32 * 1024 * 1024  # NVM/DRAM system's DRAM cache
    line_bytes: int = 64
    dram_bw: float = 25.6e9          # B/s
    nvm_read_bw: float = 25.6e9 / 8  # B/s (paper: up to 8x lower bandwidth)
    nvm_write_bw: float = 25.6e9 / 8
    hdd_bw: float = 120e6            # B/s, local hard drive baseline
    flush_latency: float = 100e-9    # s per CLFLUSH instruction issue
    nvm_same_as_dram: bool = False   # the paper's optimistic "NVM-only" config
    # "lru": fully-associative LRU (paper's emulator default).
    # "fifo": insertion-order replacement — models the conflict evictions a
    # real set-associative cache inflicts on *hot* lines, which is what
    # leaves XSBench's counters stale-by-different-amounts in NVM (Fig. 10).
    replacement: str = "lru"
    # emulation backend: "vectorized" (default), "reference" (oracle), or
    # "device" (jax-jit forward pass; falls back to vectorized semantics
    # without jax) — all byte/stat-identical; overridable via the
    # REPRO_NVM_BACKEND environment variable.
    backend: str = dataclasses.field(default_factory=_default_backend)

    @property
    def read_bw(self) -> float:
        return self.dram_bw if self.nvm_same_as_dram else self.nvm_read_bw

    @property
    def write_bw(self) -> float:
        return self.dram_bw if self.nvm_same_as_dram else self.nvm_write_bw


@dataclasses.dataclass
class TrafficStats:
    """Byte-accurate traffic + modeled-time accounting."""

    nvm_bytes_written: int = 0
    nvm_bytes_read: int = 0
    lines_flushed: int = 0
    lines_evicted: int = 0
    # writebacks that were in flight when power failed (torn crashes
    # with a LineSurvival spec): they reach the image but are never
    # charged to modeled_seconds — the program did not wait for them
    torn_bytes_persisted: int = 0
    torn_entries_persisted: int = 0
    modeled_seconds: float = 0.0

    def charge_write(self, nbytes: int, cfg: NVMConfig) -> None:
        self.nvm_bytes_written += nbytes
        self.modeled_seconds += nbytes / cfg.write_bw

    def charge_read(self, nbytes: int, cfg: NVMConfig) -> None:
        self.nvm_bytes_read += nbytes
        self.modeled_seconds += nbytes / cfg.read_bw

    def charge_flush_issue(self, nlines: int, cfg: NVMConfig) -> None:
        self.lines_flushed += nlines
        self.modeled_seconds += nlines * cfg.flush_latency

    def charge_batch(self, cfg: NVMConfig, *, write_bytes: int = 0,
                     read_bytes: int = 0, flush_lines: int = 0,
                     clean_flush_bytes: int = 0, evict_lines: int = 0) -> None:
        """Apply one program-visible operation's aggregated charges.

        Backends accumulate integer byte/line counts per operation and
        charge exactly once through here, in this fixed order — which is
        what makes TrafficStats (including the float ``modeled_seconds``)
        bit-identical across backends for identical traces.
        """
        if write_bytes:
            self.charge_write(write_bytes, cfg)
        if read_bytes:
            self.charge_read(read_bytes, cfg)
        if flush_lines:
            self.charge_flush_issue(flush_lines, cfg)
        if clean_flush_bytes:
            # clean/absent flushes still occupy the memory pipeline
            self.modeled_seconds += clean_flush_bytes / cfg.write_bw
        self.lines_evicted += evict_lines

    def note_torn_persist(self, nbytes: int, entries: int) -> None:
        """Record the dirty-entry writebacks a torn crash completed
        before power loss (backends call this at most once per crash).
        Pure bookkeeping: no modeled time is charged."""
        self.torn_bytes_persisted += nbytes
        self.torn_entries_persisted += entries

    def snapshot(self) -> "TrafficStats":
        return dataclasses.replace(self)

    def delta_since(self, prev: "TrafficStats") -> "TrafficStats":
        return TrafficStats(
            nvm_bytes_written=self.nvm_bytes_written - prev.nvm_bytes_written,
            nvm_bytes_read=self.nvm_bytes_read - prev.nvm_bytes_read,
            lines_flushed=self.lines_flushed - prev.lines_flushed,
            lines_evicted=self.lines_evicted - prev.lines_evicted,
            torn_bytes_persisted=(self.torn_bytes_persisted
                                  - prev.torn_bytes_persisted),
            torn_entries_persisted=(self.torn_entries_persisted
                                    - prev.torn_entries_persisted),
            modeled_seconds=self.modeled_seconds - prev.modeled_seconds,
        )


class NVMStore:
    """The persistent image: named flat byte-addressable regions.

    ``image[name]`` is the array of bytes that would survive a crash.
    Backends copy truth spans in via :meth:`persist` (uncharged — the
    backend aggregates and charges traffic per operation, see
    ``TrafficStats.charge_batch``).
    """

    def __init__(self, cfg: NVMConfig):
        self.cfg = cfg
        self.image: Dict[str, np.ndarray] = {}
        self.meta: Dict[str, Tuple[Tuple[int, ...], np.dtype]] = {}
        self.stats = TrafficStats()
        # monotonic per-region mutation counters: every image change bumps,
        # so equal epochs mean equal contents — the copy-on-write predicate
        # snapshots use to share/skip unchanged regions (mostly the big
        # read-only inputs: CSR matrices, ABFT-encoded operands, MC grids)
        self.image_epoch: Dict[str, int] = {}

    def alloc(self, name: str, shape: Tuple[int, ...], dtype) -> None:
        if name in self.image:
            raise KeyError(f"region {name!r} already allocated")
        dt = np.dtype(dtype)
        self.image[name] = np.zeros(int(np.prod(shape)), dtype=dt)
        self.meta[name] = (tuple(shape), dt)
        self.image_epoch[name] = 0

    def free(self, name: str) -> None:
        self.image.pop(name, None)
        self.meta.pop(name, None)
        self.image_epoch.pop(name, None)

    def mark_image_dirty(self, name: str) -> None:
        """Record an image mutation done outside :meth:`persist` (the
        vectorized backend's direct writebacks, undo-log rollbacks)."""
        self.image_epoch[name] += 1

    def persist(self, name: str, lo: int, hi: int, src: np.ndarray) -> None:
        """Copy src[lo:hi) (flat element indices) into the image."""
        self.image[name][lo:hi] = src[lo:hi]
        self.image_epoch[name] += 1

    def read_view(self, name: str) -> np.ndarray:
        """The surviving (post-crash) contents, shaped. No cost charged:
        recovery-time reads are charged by the recovery code itself."""
        shape, _ = self.meta[name]
        return self.image[name].reshape(shape)


@dataclasses.dataclass(frozen=True)
class EmuSnapshot:
    """Full emulator state captured by :meth:`CrashEmulator.snapshot`.

    Immutable (arrays are marked read-only): one snapshot can seed any
    number of forked executions. Covers everything a replayed suffix
    can observe — program truth, the persistent NVM image, traffic
    stats (including the float ``modeled_seconds``), the backend's
    volatile-cache state, and the crashed flag.

    Truth/image arrays are copy-on-write at region granularity: a
    region whose mutation epoch is unchanged since the previous
    snapshot SHARES that snapshot's frozen array instead of recopying
    it, and :meth:`CrashEmulator.restore` skips regions whose live
    epoch still equals the snapshot's — so repeated snapshot/fork
    cycles pay O(changed state), not O(total footprint).
    """

    truth: Dict[str, np.ndarray]
    image: Dict[str, np.ndarray]
    truth_epoch: Dict[str, int]
    image_epoch: Dict[str, int]
    stats: TrafficStats
    backend: object
    crashed: bool
    # regions with a rollback-induced truth/image divergence pending at
    # capture time (empty in normal step-boundary snapshots)
    truth_desynced: frozenset = frozenset()


class CrashEmulator:
    """Couples program arrays with the backend+NVM pair (paper's crash
    emulator). Allocate regions, compute on their ``.view`` arrays through
    :class:`PersistentRegion` (see regions.py), then ``crash()`` to lose
    volatile state and ``post_crash_view()`` to inspect what survived.

    This is a thin facade: cache semantics live in the selected
    :class:`~repro.core.backends.MemoryBackend`
    (``cfg.backend`` — "vectorized" by default, "reference" for oracle
    runs).
    """

    def __init__(self, cfg: Optional[NVMConfig] = None):
        self.cfg = cfg or NVMConfig()
        self.store = NVMStore(self.cfg)
        self.backend = make_backend(self.cfg.backend, self.store, self.cfg)
        self._truth: Dict[str, np.ndarray] = {}
        # truth-side mutation epochs (see NVMStore.image_epoch); every
        # content change flows through write()/crash()/restore()/
        # resync_truth(), each of which bumps
        self._truth_epoch: Dict[str, int] = {}
        # copy-on-write caches: name -> (epoch, frozen copy at that epoch)
        self._cow_truth: Dict[str, Tuple[int, np.ndarray]] = {}
        self._cow_image: Dict[str, Tuple[int, np.ndarray]] = {}
        # regions whose image was mutated from data NOT sourced from
        # truth (undo-log rollback): truth != image there even with a
        # clean cache, so crash() must reload them (see crash())
        self._truth_desynced: set = set()
        self.crashed = False
        # nested-crash trap: when armed (int), every completed emulator
        # action during recovery decrements it; reaching zero raises
        # NestedCrashFault. Never part of snapshots — it is armed only
        # transiently around a recovery attempt (see arm_nested_crash)
        self._nested_trap: Optional[int] = None

    # back-compat: the pre-backend attribute name for the cache layer
    @property
    def cache(self):
        return self.backend

    # region management ------------------------------------------------------
    def alloc(self, name: str, shape, dtype=np.float64,
              init: Optional[np.ndarray] = None, sector_lines: int = 1):
        from .regions import PersistentRegion  # local to avoid cycle

        shape = tuple(int(s) for s in shape)
        self.store.alloc(name, shape, dtype)
        truth = np.zeros(int(np.prod(shape)), dtype=np.dtype(dtype))
        self._truth[name] = truth
        self._truth_epoch[name] = 0
        self.backend.register(name, truth, sector_lines=sector_lines)
        region = PersistentRegion(self, name, shape, np.dtype(dtype))
        if init is not None:
            region[...] = np.asarray(init, dtype=dtype).reshape(shape)
        return region

    def free(self, name: str) -> None:
        self.backend.unregister(name)
        self.store.free(name)
        self._truth.pop(name, None)
        self._truth_epoch.pop(name, None)
        self._cow_truth.pop(name, None)
        self._cow_image.pop(name, None)
        self._truth_desynced.discard(name)

    # nested-crash trap (fault injection during recovery) ----------------------
    def arm_nested_crash(self, after_actions: int) -> None:
        """Arm the trap: the ``after_actions``-th completed emulator
        action from now raises :class:`NestedCrashFault` — power fails
        again while recovery is mutating state. An *action* is any
        completed facade operation (write/read/flush/drain), a
        recovery-path truth resync, or an undo-record application: the
        units in which a recovery procedure makes externally-visible
        progress, so the trap lands between two of them, exactly where
        a real second power loss could."""
        if after_actions < 1:
            raise ValueError("nested crash must fire after >= 1 actions")
        self._nested_trap = int(after_actions)

    def disarm_nested_crash(self) -> None:
        self._nested_trap = None

    def _trap_tick(self) -> None:
        if self._nested_trap is None:
            return
        self._nested_trap -= 1
        if self._nested_trap <= 0:
            self._nested_trap = None
            raise NestedCrashFault(
                "nested crash: power failed during recovery")

    # program-visible operations (facade over the backend) --------------------
    def write(self, name: str, lo: int, hi: int) -> None:
        """Program stored truth[lo:hi) of ``name``."""
        self._truth_epoch[name] += 1
        self.backend.write(name, lo, hi)
        self._trap_tick()

    def read(self, name: str, lo: int, hi: int) -> None:
        """Program loaded truth[lo:hi) of ``name``."""
        self.backend.read(name, lo, hi)
        self._trap_tick()

    def flush(self, name: str, lo: int = 0, hi: Optional[int] = None) -> None:
        """CLFLUSH the lines covering truth[lo:hi) of ``name``."""
        self.backend.flush(name, lo, hi)
        self._trap_tick()

    def drain(self) -> None:
        """Write back everything (normal program termination)."""
        self.backend.drain()
        self._trap_tick()

    # crash / recovery ---------------------------------------------------------
    def crash(self, survival=None) -> int:
        """Drop the volatile cache; reload every truth array from the NVM
        image (the program must now see only what survived).

        ``survival`` (a :class:`~repro.core.backends.LineSurvival`)
        makes the crash *torn*: a deterministic subset of the dirty
        entries is written back to the image first — the crash-state
        space EasyCrash samples and WITCHER enumerates — instead of the
        all-or-nothing worst case."""
        # truth diverges from the image exactly where unwritten-back
        # dirty entries sit — plus any region whose image was rewritten
        # from non-truth data (undo-log rollback; see
        # note_image_divergence). Reloading only those regions makes a
        # crash O(diverged footprint), which dense measure-mode sweeps
        # (one crash per cell) rely on when big read-only inputs sit in
        # the emulator. Torn survivors only ever *narrow* the diverged
        # span (image moves toward truth), so the same region list is
        # still the superset to reload.
        changed = [name for name in self._truth
                   if name in self._truth_desynced
                   or self.backend.has_dirty(name)]
        lost = self.backend.crash(survival)
        for name in changed:
            self._truth[name][:] = self.store.image[name]
            self._truth_epoch[name] += 1
        self._truth_desynced.clear()
        self.crashed = True
        return lost

    def post_crash_view(self, name: str) -> np.ndarray:
        return self.store.read_view(name)

    def resync_truth(self, name: str) -> None:
        """Reload one region's truth from the (possibly rolled-back) NVM
        image — the undo-log recovery path. Routed through the emulator
        so snapshot epochs stay coherent."""
        self._truth[name][:] = self.store.image[name]
        self._truth_epoch[name] += 1
        self._truth_desynced.discard(name)
        self._trap_tick()

    def apply_undo(self, name: str, lo: int, hi: int,
                   old: np.ndarray) -> None:
        """Apply one undo-log record: rewrite image[lo:hi) of ``name``
        with pre-transaction values (element indices). The single
        emulator-mediated path for rollback image writes — epoch bump
        and divergence note happen BEFORE the nested-crash trap can
        fire, so a re-crash between two undo records still sees a
        coherent image/snapshot state and reloads truth from it."""
        self.store.image[name][lo:hi] = old
        self.store.mark_image_dirty(name)
        # the image now holds pre-tx values truth never saw — a further
        # crash() must reload truth even with a clean cache
        self.note_image_divergence(name)
        self.store.stats.charge_write(old.nbytes, self.cfg)
        self._trap_tick()

    def inject_media_fault(self, fault, region_names=None):
        """Silently corrupt the post-crash NVM image (a
        :class:`~repro.core.backends.MediaFault`): seeded word poisoning
        or bit flips via the shared, backend-independent
        :func:`~repro.core.backends.corrupt_image_words`. Only valid on
        a crashed emulator — media faults model what recovery *finds*,
        not in-flight corruption. Truth is reloaded for the affected
        regions (post-crash truth mirrors the image); nothing is charged
        (the hardware lied for free). Returns the corrupted
        ``(name, lo, hi)`` byte spans."""
        if not self.crashed:
            raise RuntimeError(
                "inject_media_fault requires a crashed emulator "
                "(call crash() first)")
        spans = corrupt_image_words(self.store.image, fault, region_names)
        for name in sorted({name for name, _lo, _hi in spans}):
            self.store.mark_image_dirty(name)
            self._truth[name][:] = self.store.image[name]
            self._truth_epoch[name] += 1
        return spans

    def note_image_divergence(self, name: str) -> None:
        """Record that ``name``'s NVM image was just rewritten from data
        NOT sourced from truth (undo-log rollback applying old values):
        truth != image there despite a clean cache. Without this, the
        clean-region fast path in :meth:`crash` would skip the reload
        if a second crash landed before :meth:`resync_truth`."""
        self._truth_desynced.add(name)

    # snapshot / fork ----------------------------------------------------------
    def snapshot(self) -> EmuSnapshot:
        """Capture the complete emulator state (truth arrays, NVM image,
        traffic stats, cache state) for later :meth:`restore`. The fork
        sweep engine uses this to evaluate many crash points off one
        shared prefix execution.

        Copy-on-write: regions whose mutation epoch is unchanged since
        the previous snapshot share that snapshot's frozen arrays.
        Mutating ``region.view`` directly bypasses epoch tracking the
        same way it bypasses cache accounting (regions.py) — all
        shipped workloads go through ``PersistentRegion.__setitem__``.
        """
        def _cow(arrays: Dict[str, np.ndarray], epochs: Dict[str, int],
                 cache: Dict[str, Tuple[int, np.ndarray]]
                 ) -> Dict[str, np.ndarray]:
            out = {}
            for name, arr in arrays.items():
                e = epochs[name]
                hit = cache.get(name)
                if hit is None or hit[0] != e:
                    c = arr.copy()
                    c.flags.writeable = False
                    cache[name] = hit = (e, c)
                out[name] = hit[1]
            return out

        return EmuSnapshot(
            truth=_cow(self._truth, self._truth_epoch, self._cow_truth),
            image=_cow(self.store.image, self.store.image_epoch,
                       self._cow_image),
            truth_epoch=dict(self._truth_epoch),
            image_epoch=dict(self.store.image_epoch),
            stats=self.store.stats.snapshot(),
            backend=self.backend.snapshot(),
            crashed=self.crashed,
            truth_desynced=frozenset(self._truth_desynced),
        )

    def restore(self, snap: EmuSnapshot) -> None:
        """Reset to a snapshot taken on this instance. In-place: every
        region keeps its identity (PersistentRegions, VersionedArrays
        and algorithm objects holding references stay valid). Regions
        whose epoch still matches the snapshot's are skipped — the big
        read-only inputs cost nothing to restore."""
        if set(snap.truth) != set(self._truth):
            raise ValueError(
                "snapshot regions do not match this emulator's regions "
                "(snapshots only restore into the instance that took them)")
        for name, arr in snap.truth.items():
            if self._truth_epoch[name] != snap.truth_epoch[name]:
                self._truth[name][:] = arr
                # epochs only move forward: a rewind could alias a cached
                # copy-on-write entry with different contents
                self._truth_epoch[name] += 1
        for name, arr in snap.image.items():
            if self.store.image_epoch[name] != snap.image_epoch[name]:
                self.store.image[name][:] = arr
                self.store.image_epoch[name] += 1
        self.store.stats = snap.stats.snapshot()
        self.backend.restore(snap.backend)
        self._truth_desynced = set(snap.truth_desynced)
        self.crashed = snap.crashed

    def truth_flat(self, name: str) -> np.ndarray:
        return self._truth[name]

    def truth_epoch(self, name: str) -> int:
        """Current truth-side mutation epoch of ``name``. Monotonic;
        equal epochs guarantee equal contents (the same copy-on-write
        predicate :meth:`snapshot` uses), so incremental consumers —
        the shadow-snapshot strategy's unchanged-region sharing — can
        skip recopying a region whose epoch they already hold."""
        return self._truth_epoch[name]

    # stats -------------------------------------------------------------------
    @property
    def stats(self) -> TrafficStats:
        return self.store.stats

    def modeled_seconds(self) -> float:
        return self.store.stats.modeled_seconds
