import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

The two lines above MUST run before any other import — jax locks the
device count at first init, and the production meshes need 512 host
placeholder devices. Smoke tests / benches import through other entry
points and see the real single device.

For every (architecture x applicable input shape) cell this script:
    1. builds the production mesh — (16,16) single-pod and (2,16,16)
       multi-pod — and the partition rules for that cell,
    2. lowers the jitted train_step / forward / serve_step against
       ShapeDtypeStruct stand-ins (no allocation anywhere),
    3. ``.compile()``s it (GSPMD partitioning must succeed: sharding
       mismatches, compile-time OOMs, unsupported collectives are bugs),
    4. records memory_analysis(), cost_analysis(), and the collective-op
       byte totals parsed from the optimized HLO,
    5. writes one JSON artifact per cell under benchmarks/artifacts/.

Skips (recorded, per DESIGN.md §4): decode shapes for the encoder-only
hubert; long_500k for pure full-attention archs (needs sub-quadratic
attention); long_500k runs for ssm/hybrid.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ModelConfig, ShapeConfig, TrainConfig
from ..models.registry import build_model, get_config, list_archs
from ..optim import init_error_state
from ..sharding.partition import batch_shardings, make_rules
from .mesh import make_production_mesh
from .specs import batch_specs
from .steps import build_serve_step, build_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

# dtype sizes for HLO byte parsing
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def applicable_shapes(cfg: ModelConfig) -> Dict[str, str]:
    """shape name -> 'run' | skip reason."""
    out = {}
    for name, shape in SHAPES.items():
        if shape.kind == "decode":
            if not cfg.is_decoder:
                out[name] = "skip: encoder-only arch has no decode step"
                continue
            if name == "long_500k" and not cfg.is_ssm_family:
                out[name] = ("skip: full-attention arch — 500k decode needs "
                             "sub-quadratic attention (DESIGN.md §4)")
                continue
        out[name] = "run"
    return out


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-tensor bytes of every collective op in optimized HLO."""
    totals = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # e.g.  %all-reduce.5 = f32[4096,14336]{1,0} all-reduce(...)
    #       %ag = (bf16[128,32]{...}, bf16[64]{...}) all-gather(...)
    line_re = re.compile(r"=\s*(\(.*?\)|\S+?)\s+(" + "|".join(_COLLECTIVES)
                         + r")\(")
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        if "fusion" in line and "calls=" in line:
            pass
        m = line_re.search(line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        if f" {op}-start(" in line or f" {op}-done(" in line:
            # async pairs: only count the -start (has the payload type)
            pass
        nbytes = 0.0
        for dt, dims in shape_re.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        # "-done" ops repeat the payload of their "-start": skip them
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)-done", line):
            continue
        totals[op] += nbytes
        counts[op] += 1
    return {"bytes": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def _mem_analysis_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "serialized_size_in_bytes"):
        if hasattr(ma, attr):
            try:
                out[attr] = int(getattr(ma, attr))
            except Exception:
                pass
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_analysis_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               tcfg: Optional[TrainConfig] = None) -> Tuple[object, object]:
    """-> (lowered, mesh). Lowering only (no compile)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    api = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    tcfg = tcfg or TrainConfig(
        remat="dots",
        optimizer="adafactor" if arch.startswith("kimi") else "adamw")

    if shape.kind == "train":
        rules = make_rules(mesh, fsdp=tcfg.fsdp)
        batch = batch_specs(cfg, shape)
        jitted, sh, opt_init = build_train_step(api, tcfg, rules,
                                                donate=True,
                                                batch_template=batch)
        params_shapes = sh["params_shapes"]
        opt_shapes = jax.eval_shape(opt_init, params_shapes)
        err_shapes = jax.eval_shape(init_error_state, params_shapes)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        lowered = jitted.lower(params_shapes, opt_shapes, err_shapes, batch,
                               rng)
        return lowered, mesh

    if shape.kind == "prefill":
        rules = make_rules(mesh, fsdp=False)
        from ..sharding.partition import params_shardings
        params_shapes, axes = api.abstract_init(jax.random.PRNGKey(0))
        params_sh = params_shardings(rules, axes)
        batch = batch_specs(cfg, shape)
        batch_sh = batch_shardings(rules, batch)

        # NOTE: the Pallas flash-attention path (models/layers.flash_sdpa)
        # is validated and wired for TPU runs, but the *dry-run* keeps the
        # XLA attention: interpret-mode pallas lowers to interpreter
        # machinery whose HLO is not representative of the Mosaic kernel
        # (EXPERIMENTS.md §Perf iteration 8 reports the analytic
        # projection instead).

        def prefill(params, b):
            return api.forward(params, b, mesh)

        jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
        return jitted.lower(params_shapes, batch), mesh

    # decode
    kv_ok = (cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0)
    d_inner = cfg.ssm_expand * cfg.d_model
    ssm_heads = (d_inner // cfg.ssm_head_dim) if cfg.ssm_state else 0
    rules = make_rules(
        mesh, fsdp=False,
        kv_cache_heads_shardable=kv_ok,
        shard_cache_seq=(shape.global_batch < mesh.shape["data"]),
        shard_ssm_heads=(ssm_heads > 0 and ssm_heads % tp == 0),
        replicate_attn_heads=not cfg.use_mla)
    jitted, sh = build_serve_step(api, rules, batch=shape.global_batch,
                                  max_len=shape.seq_len, donate=True)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jitted.lower(sh["params_shapes"], sh["cache_shapes"], tokens,
                           pos)
    return lowered, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, keep_hlo: bool = False) -> Dict:
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "status": "ok"}
    cfg = get_config(arch)
    reason = applicable_shapes(cfg).get(shape_name, "run")
    if reason != "run":
        rec["status"] = reason
        if save:
            _save(rec)
        return rec
    t0 = time.time()
    try:
        lowered, mesh = lower_cell(arch, shape_name, multi_pod)
        rec["lower_seconds"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_seconds"] = round(time.time() - t1, 1)
        rec["memory_analysis"] = _mem_analysis_dict(compiled)
        rec["cost_analysis"] = _cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = parse_collective_bytes(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        if keep_hlo:
            rec["hlo_path"] = _save_hlo(arch, shape_name, mesh_name, hlo)
        shape = SHAPES[shape_name]
        n = cfg.param_count()
        n_active = cfg.param_count(active_only=True)
        rec["model"] = {
            "params": n, "active_params": n_active,
            "tokens_per_step": shape.global_batch * (
                shape.seq_len if shape.kind != "decode" else 1),
            "kind": shape.kind,
        }
    except Exception as e:
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if save:
        _save(rec)
    return rec


def _save(rec: Dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    path = os.path.join(ART_DIR, fn)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    return path


def _save_hlo(arch, shape, mesh_name, hlo) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{arch}__{shape}__{mesh_name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(hlo)
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args(argv)

    archs = list_archs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_devices = len(jax.devices())
    print(f"# devices: {n_devices} (host platform)", flush=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, multi, keep_hlo=args.keep_hlo)
                status = rec["status"]
                mesh_name = "multi " if multi else "single"
                if status == "ok":
                    ca = rec.get("cost_analysis", {})
                    flops = ca.get("flops", 0.0)
                    coll = rec.get("collectives", {}).get("total_bytes", 0.0)
                    print(f"OK   {arch:24s} {shape:12s} {mesh_name} "
                          f"{time.time()-t0:6.1f}s flops={flops:.3e} "
                          f"coll={coll:.3e}B", flush=True)
                elif status.startswith("skip"):
                    print(f"SKIP {arch:24s} {shape:12s} {mesh_name} "
                          f"({status})", flush=True)
                else:
                    failures += 1
                    print(f"FAIL {arch:24s} {shape:12s} {mesh_name} "
                          f"{status}", flush=True)
    print(f"done; failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
