"""hubert-xlarge — encoder-only audio transformer (w2v2 arch). The conv
waveform frontend is a STUB per the assignment: input_specs() supplies
precomputed (B, T, 1280) frame embeddings. No decode shapes (encoder).
[arXiv:2106.07447; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    causal=False, embed_inputs=False,
)
