"""Pure-jnp oracle for blockwise causal attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  groups: int = 1, causal: bool = True) -> jax.Array:
    """q: (BH, S, hd); k/v: (BH//groups, S, hd)."""
    if groups > 1:
        k = jnp.repeat(k, groups, axis=0)
        v = jnp.repeat(v, groups, axis=0)
    S = q.shape[1]
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    if causal:
        mask = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
        logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
