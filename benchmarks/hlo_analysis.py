"""Loop-aware static cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation exactly once
— a ``lax.scan`` over 61 layers reports the flops/bytes of ONE layer
(verified empirically; see EXPERIMENTS.md §Dry-run methodology). This
module re-derives whole-step totals by parsing the optimized HLO:

  * computations are parsed into op lines with result shapes;
  * ``while`` ops are mapped to their body/condition computations and a
    trip count inferred from the loop-bound constant in the condition;
  * costs aggregate recursively: while bodies multiply by trip count
    (nesting multiplies naturally, e.g. the SSD chunk scan inside the
    layer scan);
  * FLOPs: dot ops (2 x result elements x contraction size) wherever
    they appear (including inside fusions);
  * HBM bytes: operand + result bytes of *boundary* ops only — fusions
    at their callsite, standalone dots/convs/copies/gathers/DUS — ops
    inside a fusion stay in registers/VMEM;
  * collective bytes: result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (per class).

This is a static model of a static schedule — exact for FLOPs, a close
upper-ish approximation for HBM traffic, exact for collective payloads
given known trip counts.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_hlo", "analyze", "HloCosts"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*?\)\s+->\s+.*\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems_first(type_str: str) -> Tuple[int, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # raw remainder of the line (operands + attrs)
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    param_shapes: Dict[str, str]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
        if mc and ("->" in line):
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = Op(name=mo.group(1), type_str=mo.group(2),
                    opcode=mo.group(3), rest=mo.group(4), line=line)
            cur.ops.append(op)
            if op.opcode == "parameter":
                cur.param_shapes[op.name] = op.type_str
    return comps


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """2 * result_elems * contraction_size for dot ops."""
    res_elems, _ = _shape_elems_first(op.type_str)
    # contraction size: from lhs shape + lhs_contracting_dims. Operand
    # names keep their % sigil in both HLO flavors (jax 0.4 prints
    # inline operand types, so bare-word matching would grab "f32").
    operands = re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])
    if not operands:
        operands = re.findall(r"([\w.\-]+)", op.rest.split(")")[0])
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not operands or mdims is None:
        return 2.0 * res_elems  # fallback
    lhs_type = shapes.get(operands[0])
    if lhs_type is None:
        return 2.0 * res_elems
    _, lhs_dims = _shape_elems_first(lhs_type)
    k = 1
    for d in mdims.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            k *= lhs_dims[int(d)]
    return 2.0 * res_elems * k


def _while_trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation. XLA wraps the compare
    in a kLoop fusion on some backends, so rather than pattern-matching
    the compare we take the largest positive integer constant in the
    condition — for scan-lowered loops that is exactly the trip count
    (increment constants live in the body, not the condition)."""
    best = 1
    for op in cond.ops:
        if op.opcode != "constant":
            continue
        m = re.search(r"constant\((-?\d+)\)", op.line)
        if m:
            val = int(m.group(1))
            if 0 < val < 10_000_000:
                best = max(best, val)
    return best


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # pure dtype-convert / copy fusions: the CPU backend materializes f32
    # copies of bf16 dot operands (no native bf16 FMA); the TPU MXU
    # consumes bf16 directly, so these are tracked separately and
    # excluded from the TPU roofline memory term (reported alongside).
    layout_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_OPS})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_OPS})

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            flops=self.flops * k, hbm_bytes=self.hbm_bytes * k,
            layout_bytes=self.layout_bytes * k,
            collective_bytes={o: v * k for o, v in
                              self.collective_bytes.items()},
            collective_counts={o: v * k for o, v in
                               self.collective_counts.items()})

    def add(self, other: "HloCosts") -> None:
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.layout_bytes += other.layout_bytes
        for k in _COLL_OPS:
            self.collective_bytes[k] += other.collective_bytes[k]
            self.collective_counts[k] += other.collective_counts[k]


_LAYOUT_ONLY = frozenset({"parameter", "copy", "convert", "bitcast",
                          "reshape", "tuple", "get-tuple-element",
                          "constant"})


# Ops that materialize a buffer in HBM. Each materialized value is
# charged result_bytes x 2 (one write + one downstream read) — the
# standard static traffic approximation. reshape/bitcast/tuple/gte alias
# and cost nothing; dynamic-update-slice updates in place and is charged
# by its *update* operand, not the full buffer.
_MEM_OPS = {"dot", "convolution", "copy", "gather", "scatter",
            "dynamic-slice", "transpose", "reduce", "reduce-window",
            "broadcast", "iota", "slice", "concatenate", "pad",
            "sort", "select-and-scatter", "rng", "rng-bit-generator",
            "cholesky", "triangular-solve", "reverse"}


def _op_operand_bytes(op: Op, shapes: Dict[str, str]) -> float:
    total = 0.0
    seen = set()
    for name in re.findall(r"%([\w.\-]+)", op.rest):
        if name in shapes and name not in seen:
            seen.add(name)
            total += _shape_bytes(shapes[name])
    return total


def _first_operand_names(op: Op) -> List[str]:
    head = op.rest.split(")")[0]
    return re.findall(r"%([\w.\-]+)", head)


def analyze(text: str, entry: Optional[str] = None) -> HloCosts:
    comps = parse_hlo(text)
    if not comps:
        return HloCosts()
    if entry is None:
        # the entry computation: conventionally the one containing the
        # final ROOT tuple / named like the module, detect via "ENTRY"
        entry_match = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = entry_match.group(1) if entry_match else list(comps)[-1]

    memo: Dict[Tuple[str, bool], HloCosts] = {}

    def comp_cost(name: str, inside_fusion: bool) -> HloCosts:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        out = HloCosts()
        if comp is None:
            memo[key] = out
            return out
        shapes = {op.name: op.type_str for op in comp.ops}
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _while_trip_count(comps[cond]) if cond in comps else 1
                if body:
                    inner = comp_cost(body, False).scaled(trips)
                    out.add(inner)
                continue
            if oc in ("fusion", "call", "custom-call", "map"):
                m = _CALL_ATTR_RE.search(op.line)
                if m and m.group(1) in comps:
                    callee = comps[m.group(1)]
                    inner = comp_cost(m.group(1), True)
                    # flops from inside; bytes at the fusion boundary.
                    # Fusions that update carried buffers in place via
                    # dynamic-update-slice (scan's stacked-output / cache
                    # writes; possibly several DUSes under a tuple root)
                    # are charged by their update slices, not the whole
                    # carried buffer x trip count.
                    fbytes = 2.0 * _shape_bytes(op.type_str)
                    cshapes = {o.name: o.type_str for o in callee.ops}
                    dus_results = 0.0
                    dus_updates = 0.0
                    for cop in callee.ops:
                        if cop.opcode != "dynamic-update-slice":
                            continue
                        dus_results += _shape_bytes(cop.type_str)
                        names = _first_operand_names(cop)
                        if len(names) > 1 and names[1] in cshapes:
                            dus_updates += _shape_bytes(cshapes[names[1]])
                    if dus_results:
                        total = _shape_bytes(op.type_str)
                        adj = max(0.0, total - min(dus_results, total))
                        fbytes = 2.0 * (adj + dus_updates)
                    # pure convert/copy fusions: CPU-lowering artifact of
                    # mixed-precision dots — classified as layout bytes
                    callee_ops = {o.opcode for o in callee.ops}
                    is_layout = callee_ops <= _LAYOUT_ONLY
                    boundary = HloCosts(
                        flops=inner.flops,
                        hbm_bytes=0.0 if is_layout else fbytes,
                        layout_bytes=(fbytes if is_layout
                                      else inner.layout_bytes),
                        collective_bytes=inner.collective_bytes,
                        collective_counts=inner.collective_counts)
                    out.add(boundary)
                continue
            if oc in ("conditional",):
                for sub in _CALL_ATTR_RE.findall(op.line):
                    if sub in comps:
                        out.add(comp_cost(sub, False))
                continue
            base = oc.replace("-start", "")
            if base in _COLL_OPS:
                if oc.endswith("-done"):
                    continue
                nbytes = _shape_bytes(op.type_str)
                out.collective_bytes[base] += nbytes
                out.collective_counts[base] += 1
                out.hbm_bytes += 2.0 * nbytes
                continue
            if oc == "dot":
                out.flops += _dot_flops(op, shapes)
            if inside_fusion:
                continue  # fused ops live in registers/VMEM
            if oc == "dynamic-update-slice":
                # in-place: charge only the update slice (read + write)
                names = _first_operand_names(op)
                upd = names[1] if len(names) > 1 else None
                if upd and upd in shapes:
                    out.hbm_bytes += 2.0 * _shape_bytes(shapes[upd])
                continue
            if oc in ("copy", "convert"):
                out.layout_bytes += 2.0 * _shape_bytes(op.type_str)
                continue
            if oc in _MEM_OPS:
                out.hbm_bytes += 2.0 * _shape_bytes(op.type_str)
        memo[key] = out
        return out

    return comp_cost(entry, False)
