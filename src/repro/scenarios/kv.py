"""Persistent KV-serving workload family (beyond-paper subsystem).

The paper's three workloads are batch HPC kernels; production NVM is
dominated by key-value serving. :class:`KVWorkload` runs an NVM-backed
KV store — a linear-probing hash index over version-pair slot lines
plus append-only value-log extents, all living in
:class:`~repro.core.nvm.CrashEmulator` regions — through a seeded
zipfian get/put/delete request stream (Facebook ETC/UDB-style profiles:
configurable key-space size, op mix, value-size distribution, skew).
One request is one step, so the whole sweep stack — fork snapshots,
measure mode, torn ``LineSurvival`` images, ``workers=N`` sharding —
applies per-request crash points unchanged.

Store layout (everything in regions; no host-side mutable state, so
fork snapshots capture the complete store):

  kv.index   (2*n_slots, 8) int64 — slot ``s`` owns rows ``2s``/``2s+1``,
             an A/B *version pair*: an update writes the inactive row
             (readers pick the max-seq row), so the previous committed
             version of a key is never overwritten in place — the
             paper's versioned-iterates idiom applied to an index line.
             Row words: [key+1, seq, goff, nwords, value_cksum, 0, 0,
             row_cksum]; one row = one 64 B cache line.
  kv.vlog<e> (extent_words,) int64 × n_extents — segmented append-only
             value log; values never span extents (the tail waste is
             tracked). Segmentation keeps cold extents byte-stable,
             which is what the shadow-snapshot strategy's copy-on-write
             sharing exploits.
  kv.meta    (2, 16) int64 — A/B version pair of the store root:
             [head, committed, puts, dels, gets, hits, wasted,
             slot_row+1, slot_row_cksum, 0 .. 0, row_cksum]; request
             ``i`` reads the row with ``committed == i`` and writes the
             other. Words 7-8 are the *commit record*: which index row
             this request wrote and that row's checksum — recovery may
             trust a committed count only if the fingerprinted row
             survived intact (a root that outlives its write-set must
             not be adopted).

Requests are pure functions of (seed, i) via SplitMix64 — no live RNG —
so forked tails replay exactly (the sweep-engine determinism contract).

Durability semantics: the serving layer acknowledges a request when its
step completes (boundary crash => the crashed step was acked; torn
crash => it was in flight, unacked). :meth:`KVWorkload.audit_recovery`
replays the request oracle host-side and checks the *recovered* store
against the acknowledged prefix — acked updates missing/stale =>
``durability_violations``, reader-visible torn state =>
``atomicity_violations`` — which ``classify_recovery`` maps to the
serving-side correctness classes.

Under the ``adcc`` strategy the workload persists algorithm-directedly:
``adcc_after_step`` flushes exactly the lines request ``i`` touched
(value span + slot line + meta line), and ``adcc_recover`` mounts the
surviving NVM image. ``policy="validate"`` (default) checksums every
slot/value against the recovered root and drops torn entries (falling
back to the previous version row); ``policy="blind"`` trusts the image
as-is — the WITCHER-style buggy recovery that leaves partially-applied
values reader-visible (``atomicity_violation`` cells).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.nvm import CrashEmulator, NVMConfig
from . import costmodel
from .workloads import (FinalReport, RecoveryResult, Workload,
                        register_workload)

__all__ = [
    "KVProfile",
    "KV_PROFILES",
    "KVWorkload",
]

_U = np.uint64
_MASK64 = (1 << 64) - 1
_MASK63 = (1 << 63) - 1
_META_W = 16                      # meta row width (words); cksum is last


def _splitmix(x: int) -> int:
    """SplitMix64 of an arbitrary python int (counter-based randomness —
    the same idiom XSBench's lookup sampling uses)."""
    with np.errstate(over="ignore"):
        z = _U(x & _MASK64) + _U(0x9E3779B97F4A7C15)
        z = (z ^ (z >> _U(30))) * _U(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> _U(27))) * _U(0x94D049BB133111EB)
        z = z ^ (z >> _U(31))
    return int(z)


def _u01(x: int) -> float:
    """Deterministic uniform in [0, 1) from a 64-bit hash."""
    return (x >> 11) * (1.0 / (1 << 53))


def _mix_words(words) -> int:
    """Order-sensitive 63-bit checksum of a word sequence (fits int64)."""
    acc = 0x243F6A8885A308D3
    for w in np.asarray(words, dtype=np.int64).tolist():
        acc = _splitmix(acc ^ (w & _MASK64))
    return acc & _MASK63


def _value_words(key: int, seq: int, nwords: int) -> np.ndarray:
    """The value bytes of (key, seq): recomputable by the oracle, so a
    torn value is detectable by direct comparison."""
    base = _splitmix((key << 21) ^ seq)
    out = np.empty(nwords, dtype=np.int64)
    for j in range(nwords):
        out[j] = _splitmix(base + j) & _MASK63
    return out


@dataclasses.dataclass(frozen=True)
class KVProfile:
    """One request-stream shape (ETC/UDB-style trace profile)."""

    get_frac: float
    put_frac: float
    delete_frac: float
    # ((words, weight), ...) — value-size distribution in 8-byte words
    value_words: Tuple[Tuple[int, float], ...]
    skew: float                      # zipfian exponent over the key space

    def avg_value_words(self) -> float:
        tot = sum(p for _, p in self.value_words)
        return sum(w * p for w, p in self.value_words) / tot


KV_PROFILES: Dict[str, KVProfile] = {
    # Facebook ETC-style: read-dominated cache traffic, small values,
    # heavy skew
    "etc": KVProfile(get_frac=0.85, put_frac=0.13, delete_frac=0.02,
                     value_words=((4, 0.55), (8, 0.35), (16, 0.10)),
                     skew=0.99),
    # Facebook UDB-style: write-heavy database cache, larger values,
    # milder skew
    "udb": KVProfile(get_frac=0.58, put_frac=0.40, delete_frac=0.02,
                     value_words=((8, 0.60), (16, 0.30), (24, 0.10)),
                     skew=0.80),
}


class KVWorkload(Workload):
    """NVM-backed KV store driven by a zipfian request stream."""

    name = "kv"

    def __init__(self, profile: str = "etc", n_steps: int = 36,
                 n_keys: int = 40, seed: int = 11,
                 n_slots: Optional[int] = None,
                 n_extents: Optional[int] = None, extent_words: int = 256,
                 policy: str = "validate"):
        super().__init__()
        if profile not in KV_PROFILES:
            raise KeyError(f"unknown KV profile {profile!r} "
                           f"(available: {sorted(KV_PROFILES)})")
        if policy not in ("validate", "blind"):
            raise ValueError(f"unknown KV recovery policy {policy!r} "
                             "(choose 'validate' or 'blind')")
        self.profile = profile
        self._prof = KV_PROFILES[profile]
        self._n_steps = int(n_steps)
        self.n_keys = int(n_keys)
        self.seed = int(seed)
        self.policy = policy
        self.n_slots = int(n_slots) if n_slots is not None else 2 * self.n_keys
        if self.n_slots < self.n_keys:
            raise ValueError("n_slots must be >= n_keys (open addressing "
                             "needs a free slot per key)")
        self.extent_words = int(extent_words)
        maxw = max(w for w, _ in self._prof.value_words)
        if self.extent_words < maxw:
            raise ValueError("extent_words must fit the largest value")
        if n_extents is None:
            # worst case every request is a max-size put, plus one spare
            # extent for tail waste
            need = self._n_steps * maxw
            n_extents = -(-need // self.extent_words) + 1
        self.n_extents = int(n_extents)
        # zipfian CDF over key ranks + value-size CDF (precomputed once;
        # request generation is pure lookup)
        ranks = np.arange(1, self.n_keys + 1, dtype=np.float64)
        w = ranks ** -self._prof.skew
        self._key_cdf = np.cumsum(w) / w.sum()
        sizes = [s for s, _ in self._prof.value_words]
        wts = np.array([p for _, p in self._prof.value_words], np.float64)
        self._val_sizes = sizes
        self._val_cdf = np.cumsum(wts) / wts.sum()
        self._oracle_cache = None
        self._touched: List[Tuple[str, int, int]] = []

    def params(self):
        return {"profile": self.profile, "n_steps": self._n_steps,
                "n_keys": self.n_keys, "seed": self.seed,
                "policy": self.policy}

    # -- lifecycle -------------------------------------------------------------
    def setup(self, cfg, mode):
        self._check_mode(mode)
        self.mode = mode
        self._emu = CrashEmulator(cfg or NVMConfig())
        emu = self._emu
        self._rindex = emu.alloc("kv.index", (2 * self.n_slots, 8), np.int64)
        self._rvlog = [emu.alloc(f"kv.vlog{e}", (self.extent_words,),
                                 np.int64)
                       for e in range(self.n_extents)]
        self._rmeta = emu.alloc("kv.meta", (2, _META_W), np.int64)
        self._write_initial_meta()
        # the rest of the image is all-zero, matching freshly-allocated
        # truth; only the nonzero root row needs to reach NVM
        self._rmeta.flush()

    @property
    def emu(self):
        return self._emu

    @property
    def n_steps(self):
        return self._n_steps

    def _write_initial_meta(self):
        row = np.zeros(_META_W, np.int64)
        row[-1] = _mix_words(row[:-1])
        self._rmeta[0] = row

    def reset(self):
        self._rindex[...] = 0
        for r in self._rvlog:
            r[...] = 0
        self._rmeta[...] = 0
        self._write_initial_meta()

    # -- request stream ----------------------------------------------------------
    def _request(self, i: int) -> Tuple[str, int, int]:
        """(op, key, value_words) of request ``i`` — pure in (seed, i)."""
        base = (self.seed << 20) ^ (i * 3)
        op_u = _u01(_splitmix(base))
        key_u = _u01(_splitmix(base + 1))
        val_u = _u01(_splitmix(base + 2))
        p = self._prof
        if op_u < p.get_frac:
            op = "get"
        elif op_u < p.get_frac + p.put_frac:
            op = "put"
        else:
            op = "delete"
        key = min(int(np.searchsorted(self._key_cdf, key_u, side="right")),
                  self.n_keys - 1)
        nv = min(int(np.searchsorted(self._val_cdf, val_u, side="right")),
                 len(self._val_sizes) - 1)
        return op, key, self._val_sizes[nv]

    # -- store primitives --------------------------------------------------------
    def _meta_cur(self, i: int) -> Tuple[int, np.ndarray]:
        """(row index, row copy) of the meta row for step ``i`` —
        ``committed == i``, checksum-valid rows preferred (after a
        non-validating recovery a torn row can carry the matching
        committed word; reading it is exactly the blind policy's bug)."""
        m = self._rmeta[...]
        fallback = None
        for v in (0, 1):
            if int(m[v, 1]) != i:
                continue
            if int(m[v, -1]) == _mix_words(m[v, :-1]):
                return v, m[v].copy()
            if fallback is None:
                fallback = v
        if fallback is not None:
            return fallback, m[fallback].copy()
        raise RuntimeError(f"kv.meta has no row for request {i}")

    def _probe_start(self, key: int) -> int:
        return _splitmix(key + 0x51ED2705) % self.n_slots

    def _slot_lookup(self, key: int) -> Tuple[int, np.ndarray, bool]:
        """Linear-probe for ``key``: (slot, row-pair copy, found). Stops
        at the key's slot or the first never-claimed slot. Tombstones
        keep their key word, so probe chains stay stable across
        deletes."""
        start = self._probe_start(key)
        for t in range(self.n_slots):
            s = (start + t) % self.n_slots
            rows = self._rindex[2 * s:2 * s + 2].copy()
            k0, k1 = int(rows[0, 0]), int(rows[1, 0])
            if k0 == key + 1 or k1 == key + 1:
                return s, rows, True
            if k0 == 0 and k1 == 0:
                return s, rows, False
        raise RuntimeError("kv.index is full")

    @staticmethod
    def _active_row(rows: np.ndarray) -> Optional[int]:
        """Reader-visible version of a slot: max-seq nonempty row — no
        validation (that is a recovery-policy decision, not a read-path
        one)."""
        best = None
        for v in (0, 1):
            if int(rows[v, 0]) == 0:
                continue
            if best is None or int(rows[v, 1]) > int(rows[best, 1]):
                best = v
        return best

    def _alloc_span(self, head: int, nwords: int) -> Tuple[int, int, int, int]:
        """(aligned_head, extent, offset, waste) for an append of
        ``nwords`` — values never span extents."""
        e, off = divmod(head, self.extent_words)
        waste = 0
        if off + nwords > self.extent_words:
            waste = self.extent_words - off
            head += waste
            e, off = divmod(head, self.extent_words)
        if e >= self.n_extents:
            raise RuntimeError("kv value log exhausted — size n_extents up")
        return head, e, off, waste

    def _read_value(self, goff: int, nw: int) -> None:
        """Charged read of a value span; bounds-clipped because a
        non-validating recovery can leave a mixed (goff, nwords) pair."""
        e, off = divmod(int(goff), self.extent_words)
        if 0 <= e < self.n_extents and 0 <= off < self.extent_words:
            hi = min(off + int(nw), self.extent_words)
            if hi > off:
                self._rvlog[e][off:hi]

    # -- the step ----------------------------------------------------------------
    def step(self, i):
        op, key, nwords = self._request(i)
        cur_idx, m = self._meta_cur(i)
        head, puts, dels, gets, hits, wasted = (
            int(m[0]), int(m[2]), int(m[3]), int(m[4]), int(m[5]), int(m[6]))
        touched: List[Tuple[str, int, int]] = []
        commit_row = commit_rowck = 0      # index-row fingerprint (gets: none)
        if op == "get":
            gets += 1
            _s, rows, found = self._slot_lookup(key)
            av = self._active_row(rows)
            if found and av is not None and int(rows[av, 3]) > 0:
                hits += 1
                self._read_value(int(rows[av, 2]), int(rows[av, 3]))
        elif op == "put":
            puts += 1
            vwords = _value_words(key, i + 1, nwords)
            base, e, off, waste = self._alloc_span(head, nwords)
            wasted += waste
            head = base + nwords
            self._rvlog[e][off:off + nwords] = vwords
            touched.append((f"kv.vlog{e}", off, off + nwords))
            s, rows, _found = self._slot_lookup(key)
            av = self._active_row(rows)
            wv = 1 - av if av is not None else 0
            row = np.zeros(8, np.int64)
            row[0] = key + 1
            row[1] = i + 1
            row[2] = e * self.extent_words + off
            row[3] = nwords
            row[4] = _mix_words(vwords)
            row[7] = _mix_words(row[:7])
            r = 2 * s + wv
            self._rindex[r] = row
            touched.append(("kv.index", r * 8, r * 8 + 8))
            commit_row, commit_rowck = r + 1, int(row[7])
        else:  # delete
            dels += 1
            s, rows, found = self._slot_lookup(key)
            av = self._active_row(rows)
            if found and av is not None and int(rows[av, 3]) > 0:
                row = np.zeros(8, np.int64)
                row[0] = key + 1
                row[1] = i + 1
                row[7] = _mix_words(row[:7])
                r = 2 * s + (1 - av)
                self._rindex[r] = row
                touched.append(("kv.index", r * 8, r * 8 + 8))
                commit_row, commit_rowck = r + 1, int(row[7])
        mrow = np.zeros(_META_W, np.int64)
        mrow[:9] = (head, i + 1, puts, dels, gets, hits, wasted,
                    commit_row, commit_rowck)
        mrow[-1] = _mix_words(mrow[:-1])
        mv = 1 - cur_idx
        self._rmeta[mv] = mrow
        touched.append(("kv.meta", mv * _META_W, (mv + 1) * _META_W))
        # transient flush plan for adcc_after_step — always repopulated
        # by the step that immediately precedes the hook
        self._touched = touched

    def live_regions(self):
        return [self._rindex, self._rmeta] + list(self._rvlog)

    # -- oracle ------------------------------------------------------------------
    def _oracle(self):
        """Host-side replay of the request stream: per-prefix live maps
        {key: (seq, nwords)} plus final op counters."""
        if self._oracle_cache is None:
            cur: Dict[int, Tuple[int, int]] = {}
            maps = [dict(cur)]
            puts = dels = gets = hits = 0
            for i in range(self._n_steps):
                op, key, nw = self._request(i)
                if op == "put":
                    puts += 1
                    cur[key] = (i + 1, nw)
                elif op == "delete":
                    dels += 1
                    cur.pop(key, None)
                else:
                    gets += 1
                    if key in cur:
                        hits += 1
            # snapshot AFTER applying request i => maps[k] = state
            # once k requests completed
                maps.append(dict(cur))
            self._oracle_cache = (maps, {"puts": puts, "dels": dels,
                                         "gets": gets, "hits": hits})
        return self._oracle_cache

    # -- recovered-state inspection (uncharged oracle-side reads) ---------------
    def _row_ok(self, row: np.ndarray) -> bool:
        """Row checksum valid AND the referenced value bytes are exactly
        what (key, seq) wrote — direct recomputation, stronger than the
        stored value checksum."""
        if int(row[7]) != _mix_words(row[:7]):
            return False
        nw = int(row[3])
        if nw <= 0:
            return True
        key, seq, goff = int(row[0]) - 1, int(row[1]), int(row[2])
        e, off = divmod(goff, self.extent_words)
        if not (0 <= e < self.n_extents and 0 <= off
                and off + nw <= self.extent_words):
            return False
        got = self._rvlog[e].view[off:off + nw]
        return bool(np.array_equal(got, _value_words(key, seq, nw)))

    def _semantic_map(self, bound: Optional[int] = None,
                      validated: bool = False) -> Dict[int, Dict[str, int]]:
        """Live entries a reader would serve: per slot the max-seq row
        (optionally only checksum-valid rows with seq <= bound — the
        committed-prefix view restart_digest certifies), keyed by key
        with an ``ok`` integrity verdict."""
        idx = self._rindex.view
        out: Dict[int, Dict[str, int]] = {}
        for s in range(self.n_slots):
            best = None
            for v in (0, 1):
                row = idx[2 * s + v]
                if int(row[0]) == 0:
                    continue
                if bound is not None and int(row[1]) > bound:
                    continue
                if validated and not self._row_ok(row):
                    continue
                if best is None or int(row[1]) > int(best[1]):
                    best = row
            if best is not None and int(best[3]) > 0:
                out[int(best[0]) - 1] = {
                    "seq": int(best[1]), "goff": int(best[2]),
                    "nw": int(best[3]), "ok": self._row_ok(best)}
        return out

    def _visible_corrupt_rows(self) -> int:
        """Reader-visible rows (live or tombstone) failing integrity."""
        idx = self._rindex.view
        n = 0
        for s in range(self.n_slots):
            rows = idx[2 * s:2 * s + 2]
            av = self._active_row(rows)
            if av is not None and not self._row_ok(rows[av]):
                n += 1
        return n

    def _meta_row_for(self, committed: int) -> Optional[np.ndarray]:
        m = self._rmeta.view
        for v in (0, 1):
            if (int(m[v, 1]) == committed
                    and int(m[v, -1]) == _mix_words(m[v, :-1])):
                return m[v]
        return None

    # -- durability / atomicity audit --------------------------------------------
    def audit_recovery(self, rec, crash_step, torn):
        """Check the recovered store against the acknowledged prefix.

        A request is acknowledged when its step completed: a boundary
        crash acked the crashed step, a torn crash caught it in flight.
        Violations land in ``rec.info`` for ``classify_recovery``."""
        acked_n = crash_step + (0 if torn else 1)
        maps, _counters = self._oracle()
        acked = maps[acked_n]
        visible = self._semantic_map()
        atom = self._visible_corrupt_rows()
        if self._meta_row_for(rec.resume_step) is None:
            # the root the recovered run resumes from is itself torn
            atom += 1
        # a root ahead of the acknowledged prefix asserts in-flight
        # requests were applied; replay resumes past them, so any whose
        # write-set did not fully survive is a torn, partially-applied
        # request made permanently reader-visible
        for j in range(acked_n, rec.resume_step):
            op, key, _nw = self._request(j)
            if op == "get":
                continue
            ent = visible.get(key)
            if op == "put":
                if ent is None or ent["seq"] != j + 1 or not ent["ok"]:
                    atom += 1
            elif ent is not None and ent["seq"] < j + 1:
                atom += 1          # delete committed by the root, not applied
        dur = 0
        for key, (seq_o, _nw) in acked.items():
            ent = visible.get(key)
            if (ent is None or ent["seq"] < seq_o
                    or (ent["seq"] == seq_o and not ent["ok"])):
                dur += 1
        for key, ent in visible.items():
            if key not in acked and ent["ok"] and ent["seq"] <= acked_n:
                # an acknowledged delete resurrected (or a stale value
                # an acked update chain had already superseded)
                dur += 1
        rec.info["acked_requests"] = acked_n
        rec.info["durability_violations"] = dur
        rec.info["atomicity_violations"] = atom

    # -- certification digest -----------------------------------------------------
    def restart_digest(self, restart_point):
        """Semantic store digest at a restart point: the committed-prefix
        live map (key -> seq + value bytes) plus the root row — not raw
        region bytes, because a correct recovery may legitimately differ
        bytewise from the golden prefix (validate-dropped version rows,
        alternate A/B parity) while serving identical state."""
        bound = restart_point + 1
        sem = self._semantic_map(bound=bound, validated=True)
        d: Dict[str, object] = {}
        for key in sorted(sem):
            ent = sem[key]
            e, off = divmod(ent["goff"], self.extent_words)
            val = self._rvlog[e].view[off:off + ent["nw"]]
            d[f"kv:{key}"] = np.concatenate(
                ([np.int64(ent["seq"])], val)).copy()
        mrow = self._meta_row_for(bound)
        d["meta"] = (mrow.copy() if mrow is not None
                     else np.zeros(_META_W, np.int64))
        return d

    # -- ADCC hooks: per-request selective persistence ----------------------------
    def adcc_after_step(self, i):
        emu = self.emu
        for name, lo, hi in self._touched:
            emu.flush(name, lo, hi)

    def adcc_recover(self, crash_step):
        """Mount the surviving NVM image (truth == image post-crash).

        validate: pick the newest coherent root — a committed count is
        trusted only if every slot row of that generation verifies —
        then scan the index and drop torn or newer-than-root rows
        (readers fall back to the intact previous version row).
        blind: adopt the rawest root and serve whatever survived."""
        emu = self.emu
        cfg, stats = emu.cfg, emu.stats
        mview = self._rmeta.view
        meta_bytes = mview.nbytes
        raw = max(int(mview[v, 1]) for v in (0, 1))
        if self.policy == "blind":
            stats.charge_read(meta_bytes, cfg)
            resume = raw
            return RecoveryResult(
                resume_step=resume, restart_point=resume - 1,
                detect_seconds=meta_bytes / cfg.read_bw,
                redo_steps=crash_step + 1 - resume,
                from_scratch=resume == 0,
                info={"policy": "blind", "torn_flagged": False})
        valid = [v for v in (0, 1)
                 if int(mview[v, -1]) == _mix_words(mview[v, :-1])]
        idx = self._rindex.view
        read_bytes = meta_bytes + idx.nbytes
        rows_ok: Dict[int, bool] = {}
        for r in range(2 * self.n_slots):
            row = idx[r]
            if int(row[0]) == 0:
                continue
            rows_ok[r] = self._row_ok(row)
            read_bytes += 8 * max(0, int(row[3]))
        stats.charge_read(read_bytes, cfg)
        detect = read_bytes / cfg.read_bw
        resume = None
        for c, v in sorted(((int(mview[v, 1]), v) for v in valid),
                           reverse=True):
            # every surviving row of this generation must verify ...
            ok_c = all(ok or int(idx[r, 1]) != c
                       for r, ok in rows_ok.items())
            fp = int(mview[v, 7])
            if ok_c and fp:
                # ... AND the commit record's fingerprinted row must be
                # present: a root whose write-set line died with the
                # crash would otherwise be adopted vacuously, silently
                # skipping the lost request on replay
                r = fp - 1
                ok_c = (0 <= r < 2 * self.n_slots
                        and rows_ok.get(r, False)
                        and int(idx[r, 1]) == c
                        and int(idx[r, 7]) == int(mview[v, 8]))
            if ok_c:
                resume = c
                break
        if resume is None:
            self.reset()
            return RecoveryResult(
                resume_step=0, restart_point=-1, detect_seconds=detect,
                redo_steps=crash_step + 1, steps_lost=crash_step + 1,
                from_scratch=True,
                info={"policy": "validate", "torn_flagged": True,
                      "slots_dropped": 0})
        dropped = 0
        for r, ok in rows_ok.items():
            if not ok or int(idx[r, 1]) > resume:
                self._rindex[r] = 0
                self._rindex.flush(r)
                dropped += 1
        return RecoveryResult(
            resume_step=resume, restart_point=resume - 1,
            detect_seconds=detect, redo_steps=crash_step + 1 - resume,
            from_scratch=resume == 0,
            info={"policy": "validate",
                  "torn_flagged": dropped > 0 or resume < raw,
                  "slots_dropped": dropped})

    # -- cost model ----------------------------------------------------------------
    def step_cost_profile(self):
        avg_bytes = int(8 * self._prof.avg_value_words()
                        * self._prof.put_frac) + 8
        return costmodel.kv_step_profile(
            index_bytes=self._rindex.view.nbytes,
            meta_bytes=self._rmeta.view.nbytes,
            extent_bytes=self.extent_words * 8,
            n_extents=self.n_extents,
            avg_value_bytes=avg_bytes,
            line_bytes=self.emu.cfg.line_bytes)

    # -- end-of-run verdict ---------------------------------------------------------
    def finalize(self):
        maps, counters = self._oracle()
        expected = maps[self._n_steps]
        visible = self._semantic_map()
        ok = set(visible) == set(expected)
        if ok:
            for key, ent in visible.items():
                seq_o, _nw = expected[key]
                if not ent["ok"] or ent["seq"] != seq_o:
                    ok = False
                    break
        mrow = self._meta_row_for(self._n_steps)
        if mrow is None:
            ok = False
            hits = gets = wasted = 0
        else:
            hits, gets, wasted = int(mrow[5]), int(mrow[4]), int(mrow[6])
            got = {"puts": int(mrow[2]), "dels": int(mrow[3]),
                   "gets": int(mrow[4]), "hits": int(mrow[5])}
            if got != counters:
                ok = False
        return FinalReport(
            metrics={"requests": float(self._n_steps),
                     "live_keys": float(len(visible)),
                     "hit_rate": hits / max(1, gets),
                     "wasted_words": float(wasted)},
            correct=ok,
            info={"live_keys": len(visible)})


register_workload("kv", KVWorkload)
