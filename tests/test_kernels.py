"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes (harness requirement for every kernel)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.abft_matmul.ops import abft_matmul, abft_matmul_full
from repro.kernels.abft_matmul.ref import abft_encode_full_ref, abft_matmul_ref
from repro.kernels.checksum_verify.ops import tile_sums, verify_checksums
from repro.kernels.checksum_verify.ref import verify_ref


def _tol(dtype):
    # fp32 MXU-order differences; bf16 inputs round at 2^-8
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-3)


SHAPES = [
    (128, 128, 128),   # exactly one MXU tile
    (256, 256, 256),   # multi-tile aligned
    (256, 384, 128),   # rectangular aligned
    (8, 8, 8),         # minimum sublane tile
    (100, 130, 70),    # unaligned -> exercises padding
    (257, 129, 65),    # prime-ish unaligned
    (1, 512, 1),       # degenerate rows/cols
]


class TestAbftMatmul:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, dtype):
        rng = np.random.default_rng(m * 7 + k * 3 + n)
        a = jnp.asarray(rng.normal(size=(m, k)), dtype)
        b = jnp.asarray(rng.normal(size=(k, n)), dtype)
        c, row, col = abft_matmul(a, b, interpret=True)
        cr, rowr, colr = abft_matmul_ref(a, b)
        tol = _tol(dtype)
        np.testing.assert_allclose(np.asarray(c, np.float32),
                                   np.asarray(cr, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(row), np.asarray(rowr),
                                   rtol=tol["rtol"], atol=tol["atol"] * k)
        np.testing.assert_allclose(np.asarray(col), np.asarray(colr),
                                   rtol=tol["rtol"], atol=tol["atol"] * k)

    def test_checksums_equal_true_sums(self):
        """The fused checksums must equal the actual row/col sums of C —
        the ABFT invariant the recovery layer depends on."""
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(96, 160)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(160, 64)), jnp.float32)
        c, row, col = abft_matmul(a, b, interpret=True)
        np.testing.assert_allclose(np.asarray(row),
                                   np.asarray(c, np.float32).sum(1), rtol=1e-5,
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(col),
                                   np.asarray(c, np.float32).sum(0), rtol=1e-5,
                                   atol=1e-3)

    def test_full_matrix_layout(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.normal(size=(40, 50)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(50, 30)), jnp.float32)
        cf = abft_matmul_full(a, b, interpret=True)
        cfr = abft_encode_full_ref(a, b)
        assert cf.shape == (41, 31)
        np.testing.assert_allclose(np.asarray(cf), np.asarray(cfr),
                                   rtol=1e-5, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96))
    def test_property_random_shapes(self, m, k, n):
        rng = np.random.default_rng(m + 100 * k + 10000 * n)
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        c, row, col = abft_matmul(a, b, interpret=True)
        cr, rowr, colr = abft_matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(row), np.asarray(rowr),
                                   rtol=1e-4, atol=1e-2)


class TestChecksumVerify:
    @pytest.mark.parametrize("m,n", [(128, 128), (64, 256), (100, 70), (9, 5),
                                     (257, 127)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_tile_sums_match(self, m, n, dtype):
        rng = np.random.default_rng(m * 11 + n)
        x = jnp.asarray(rng.normal(size=(m, n)), dtype)
        row, col = tile_sums(x, interpret=True)
        xr = np.asarray(x, np.float32)
        np.testing.assert_allclose(np.asarray(row), xr.sum(1), rtol=1e-2,
                                   atol=1e-2 * n)
        np.testing.assert_allclose(np.asarray(col), xr.sum(0), rtol=1e-2,
                                   atol=1e-2 * m)

    def test_verify_clean_and_tampered(self):
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        cf = abft_matmul_full(a, b, interpret=True)
        ok, _, _ = verify_checksums(cf, interpret=True)
        ok_ref, _, _ = verify_ref(cf)
        assert bool(ok) and bool(ok_ref)
        bad = cf.at[10, 20].add(50.0)
        ok2, rres, cres = verify_checksums(bad, interpret=True)
        assert not bool(ok2)
        assert int(jnp.argmax(jnp.abs(rres))) == 10
        assert int(jnp.argmax(jnp.abs(cres))) == 20

    def test_kernel_matches_ref_residuals(self):
        rng = np.random.default_rng(3)
        cf = jnp.asarray(rng.normal(size=(101, 77)), jnp.float32)
        ok_k, rr_k, cr_k = verify_checksums(cf, interpret=True)
        ok_r, rr_r, cr_r = verify_ref(cf)
        assert bool(ok_k) == bool(ok_r)
        np.testing.assert_allclose(np.asarray(rr_k), np.asarray(rr_r),
                                   rtol=1e-4, atol=1e-2)
        np.testing.assert_allclose(np.asarray(cr_k), np.asarray(cr_r),
                                   rtol=1e-4, atol=1e-2)


class TestFlashAttention:
    """Pallas blockwise attention vs jnp oracle (interpret mode)."""

    @pytest.mark.parametrize("B,S,H,KV,hd", [
        (2, 128, 4, 2, 32), (1, 256, 2, 2, 64), (2, 64, 8, 2, 16),
        (1, 64, 4, 4, 32),   # MHA
    ])
    def test_matches_ref(self, B, S, H, KV, hd):
        import numpy as np
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref
        rng = np.random.default_rng(B * 100 + S)
        q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
        out = flash_attention(q, k, v, interpret=True)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
        ref = attention_ref(qf, kf, vf, groups=H // KV)
        ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_bf16_inputs(self):
        import numpy as np
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref
        rng = np.random.default_rng(0)
        B, S, H, KV, hd = 1, 128, 4, 2, 32
        q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
        out = flash_attention(q, k, v, interpret=True)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
        ref = attention_ref(qf, kf, vf, groups=H // KV)
        ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_flash_forward_matches_plain_forward(self):
        """End-to-end: lm.forward(flash=True) == plain within bf16
        reassociation tolerance."""
        import jax as _jax
        from repro.launch.specs import make_batch
        from repro.models.registry import build_model, get_config
        cfg = get_config("llama3-8b").reduced()
        api = build_model(cfg)
        params, _ = api.init(_jax.random.PRNGKey(0))
        batch = make_batch(cfg, 2, 64, _jax.random.PRNGKey(1))
        ref = api.forward(params, batch)
        fl = api.forward(params, batch, flash=True)
        assert float(jnp.max(jnp.abs(fl - ref))) < 0.15
