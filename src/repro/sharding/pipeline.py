"""Pipeline parallelism: GPipe schedule via shard_map + ppermute.

Stages live on a dedicated mesh axis; each device holds one stage's
parameters (leading ``stages`` dim, sharded on the axis). The schedule
runs ``n_micro + n_stages - 1`` ticks of a ``lax.scan``; per tick every
device applies its stage to its current activation and passes the result
to the next stage with a single ``collective-permute`` (ring neighbor
exchange — the cheapest collective in the roofline's collective term).
Stage 0 ingests microbatch ``t``; the last stage emits microbatch
``t - (n_stages - 1)``. Bubble fraction = (n_stages-1)/(n_micro+n_stages-1),
the standard GPipe overhead — amortized by more microbatches.

Composes with the rest of the stack: inside each stage the layer fn can
still use TP/FSDP sharding on the remaining mesh axes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "stage_params"]


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with replication checks off, on both API generations
    (jax >= 0.5 top-level fn / check_vma, 0.4.x experimental / check_rep)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_experimental
    return sm_experimental(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)


def stage_params(params_stacked, n_stages: int):
    """Reshape (L, ...) stacked layer params into (n_stages, L/n_stages, ...)
    per-stage groups."""
    def split(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(split, params_stacked)


def pipeline_apply(stage_fn: Callable, params_staged, x_micro: jax.Array,
                   mesh, *, axis: str = "stage"):
    """Run the GPipe schedule.

    stage_fn(stage_local_params, act) -> act
        applies ONE stage's layer group; sees params with the leading
        per-stage layer dim (L/n_stages, ...).
    params_staged: leaves (n_stages, L/n_stages, ...), sharded over
        ``axis`` on dim 0.
    x_micro: (n_micro, mb, ...) microbatched input activations
        (replicated over ``axis``).
    Returns (n_micro, mb, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def shard_body(params_local, xs_local):
        # params_local: (1, L/n_stages, ...) — this device's stage
        p_stage = jax.tree.map(lambda w: w[0], params_local)
        stage_idx = jax.lax.axis_index(axis)
        act_shape = xs_local.shape[1:]
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            act, outs = carry
            # stage 0 ingests microbatch t (clamped; masked later)
            mb = jax.lax.dynamic_index_in_dim(
                xs_local, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
            act_in = jnp.where(stage_idx == 0, mb, act)
            act_out = stage_fn(p_stage, act_in)
            # last stage emits microbatch t - (n_stages - 1)
            out_t = t - (n_stages - 1)
            emit = (stage_idx == n_stages - 1) & (out_t >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, act_out.astype(o.dtype), jnp.maximum(out_t, 0), 0),
                lambda o: o, outs)
            # ring-shift activations to the next stage
            act_next = jax.lax.ppermute(act_out, axis, perm)
            return (act_next, outs), None

        act0 = jnp.zeros(act_shape, x_micro.dtype)
        outs0 = jnp.zeros((n_micro,) + act_shape, x_micro.dtype)
        (act, outs), _ = jax.lax.scan(tick, (act0, outs0),
                                      jnp.arange(ticks))
        # every device returns a buffer; only the last stage's is real.
        # psum over a one-hot mask broadcasts it to all (cheap: outputs
        # are per-microbatch activations, one all-reduce at the end).
        mask = (stage_idx == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), params_staged),
        P(),
    )
    return _shard_map(shard_body, mesh=mesh, in_specs=in_specs,
                      out_specs=P())(params_staged, x_micro)
