"""XSBench-style Monte-Carlo cross-section lookup with ADCC (§III.D).

Reproduces the paper's MC study:

  * two large read-only grids (unionized energy grid + per-nuclide cross
    section grids) dominate the footprint;
  * each lookup binary-searches the energy grid, gathers + interpolates
    per-nuclide cross sections for a random material, accumulates into a
    5-element ``macro_xs_vector``, then (the paper's determinism
    extension) picks an interaction type from the normalized CDF of the
    vector and bumps one of five counters;
  * contrary to intuition, the tiny hot accumulators are *never evicted*
    (each lookup touches only a few grid lines), so naive crash-restart
    loses many iterations of counts (Fig. 10);
  * the fix flushes macro_xs_vector + the five counters + the loop index
    every ``flush_every`` lookups (0.01% of total in the paper, Fig. 11),
    bounding the loss and restoring correctness (Fig. 12) at ~0.05%
    runtime overhead (Fig. 13).

Sampling is *counter-based* (hash of the lookup index) so a restarted run
replays the same per-iteration random inputs — the paper does the same
("these two tests use the same randomly sampled inputs for each lookup").
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.nvm import CrashEmulator, NVMConfig

__all__ = ["XSBenchConfig", "XSBenchResult", "ADCC_XSBench"]

N_TYPES = 5  # interaction types: total, elastic, absorption, fission, nu-fission


@dataclasses.dataclass(frozen=True)
class XSBenchConfig:
    n_nuclides: int = 34           # paper: 34 fuel nuclides (H-M model)
    grid_points: int = 40_000      # unionized energy grid size (scaled down)
    n_materials: int = 12
    max_nuclides_per_material: int = 8
    lookups: int = 200_000
    flush_every_frac: float = 1e-4  # 0.01% of total lookups (paper)
    seed: int = 7


def _hash_u64(x: np.ndarray | int) -> np.ndarray:
    """SplitMix64 — counter-based RNG so restarts replay identical inputs.
    uint64 wraparound is the intended mod-2^64 arithmetic."""
    with np.errstate(over="ignore"):
        z = (np.uint64(x) + np.uint64(0x9E3779B97F4A7C15))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def _u01(h: np.ndarray) -> np.ndarray:
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)


@dataclasses.dataclass
class XSBenchResult:
    counts: np.ndarray             # (5,) interaction-type counts
    fractions: np.ndarray          # counts / lookups completed
    macro_xs: np.ndarray           # (5,) accumulated macroscopic XS
    lookups_done: int
    crashed_at: Optional[int]
    iterations_lost: int
    modeled_overhead_seconds: float
    wall_seconds: float

    def max_fraction_spread(self) -> float:
        """Max pairwise difference between type fractions — the paper's
        Fig. 10/12 correctness metric (should be ~0 for a fair CDF)."""
        return float(np.max(self.fractions) - np.min(self.fractions))


class ADCC_XSBench:
    """XSBench over the crash emulator with selectable flush policy."""

    def __init__(self, cfg: XSBenchConfig, nvm: Optional[NVMConfig] = None,
                 policy: str = "selective"):
        """policy: 'selective' (Fig. 11), 'basic' (index-only flush,
        Fig. 10's failing scheme), or 'every' (flush accumulators every
        lookup — the 16%-overhead strawman)."""
        assert policy in ("selective", "basic", "every")
        self.cfg = cfg
        self.policy = policy
        self.emu = CrashEmulator(nvm or NVMConfig())
        rng = np.random.default_rng(cfg.seed)

        # --- build grids (read-only, large) --------------------------------
        egrid = np.sort(rng.uniform(1e-11, 20.0, size=cfg.grid_points))
        # per (grid point, nuclide, xs-type) microscopic cross sections
        nuc = rng.uniform(0.1, 10.0,
                          size=(cfg.grid_points, cfg.n_nuclides, N_TYPES))
        self._egrid = self.emu.alloc("egrid", egrid.shape, np.float64,
                                     init=egrid, sector_lines=2)
        self._nuc = self.emu.alloc("nuclide_grid", nuc.shape, np.float64,
                                   init=nuc, sector_lines=2)
        self._egrid.flush(); self._nuc.flush()
        self.egrid_np = egrid
        self.nuc_np = nuc

        # materials -> nuclide lists (host-side metadata, tiny)
        self.materials = [
            rng.choice(cfg.n_nuclides,
                       size=rng.integers(2, cfg.max_nuclides_per_material + 1),
                       replace=False)
            for _ in range(cfg.n_materials)
        ]

        # --- critical small state (each on its own cache line) --------------
        self._macro = self.emu.alloc("macro_xs_vector", (N_TYPES,), np.float64)
        self._counters = [
            self.emu.alloc(f"type_counter_{t}", (1,), np.int64)
            for t in range(N_TYPES)
        ]
        self._index = self.emu.alloc("lookup_index", (1,), np.int64)
        self.flush_every = max(1, int(cfg.lookups * cfg.flush_every_frac))

    # -- one lookup ----------------------------------------------------------
    def _lookup(self, i: int) -> None:
        cfg = self.cfg
        h = _hash_u64(np.uint64((i * 2654435761) & 0xFFFFFFFFFFFFFFFF))
        e = _u01(h) * 19.9 + 1e-11
        mat = int(_hash_u64(h) % np.uint64(cfg.n_materials))

        # binary search on the energy grid: touches log2(G) cache lines
        idx = int(np.searchsorted(self.egrid_np, e)) - 1
        idx = min(max(idx, 0), cfg.grid_points - 2)
        for probe in self._bsearch_probes(cfg.grid_points, idx):
            self.emu.read("egrid", probe, probe + 1)

        t = (e - self.egrid_np[idx]) / max(
            self.egrid_np[idx + 1] - self.egrid_np[idx], 1e-300)
        macro = np.zeros(N_TYPES)
        row = cfg.n_nuclides * N_TYPES
        for nuclide in self.materials[mat]:
            lo = idx * row + int(nuclide) * N_TYPES
            self.emu.read("nuclide_grid", lo, lo + N_TYPES)
            self.emu.read("nuclide_grid", lo + row, lo + row + N_TYPES)
            xs0 = self.nuc_np[idx, nuclide]
            xs1 = self.nuc_np[idx + 1, nuclide]
            macro += xs0 * (1.0 - t) + xs1 * t

        # accumulate into the persistent macro_xs_vector (hot line!)
        self._macro[...] = self._macro.view + macro

        # paper's determinism extension: CDF -> pick interaction type
        cdf = np.cumsum(macro)
        cdf /= cdf[-1]
        x = _u01(_hash_u64(h ^ np.uint64(0xD6E8FEB86659FD93)))
        chosen = int(np.searchsorted(cdf, x))
        chosen = min(chosen, N_TYPES - 1)
        c = self._counters[chosen]
        c[0] = int(c.view[0]) + 1

    @staticmethod
    def _bsearch_probes(n: int, target: int):
        """Indices a binary search for `target` actually touches."""
        lo, hi = 0, n - 1
        probes = []
        while lo < hi:
            mid = (lo + hi) // 2
            probes.append(mid)
            if mid < target:
                lo = mid + 1
            elif mid > target:
                hi = mid - 1
            else:
                break
            if len(probes) > 64:
                break
        return probes

    def _flush_critical(self, i: int) -> None:
        self._macro.flush()
        for c in self._counters:
            c.flush()
        self._index[0] = i
        self._index.flush()

    # -- driver ------------------------------------------------------------------
    def run(self, crash_at: Optional[int] = None,
            restart: bool = True) -> XSBenchResult:
        """Deprecated: run the lookup loop, optionally crashing after
        ``crash_at`` lookups completed; with ``restart`` recover from
        the persisted index/counters and resume.

        This is a legacy shim over the unified scenario driver — use
        ``repro.scenarios.run_scenario(("xsbench", {...}), "adcc", plan)``.
        """
        warnings.warn(
            "ADCC_XSBench.run() is deprecated; use repro.scenarios."
            "run_scenario(('xsbench', params), 'adcc', CrashPlan.at_step(k))",
            DeprecationWarning, stacklevel=2)
        from ..scenarios import CrashPlan, run_scenario
        from ..scenarios.workloads import XSBenchWorkload

        # old semantics: the crash check ran after the loop counter was
        # incremented, so crash_at=0 (or None, or > lookups) never fires
        plan = (CrashPlan.at_step(crash_at - 1)
                if crash_at and 0 < crash_at <= self.cfg.lookups
                else CrashPlan.no_crash())
        res = run_scenario(XSBenchWorkload(impl=self), "adcc", plan,
                           recover=restart)
        return XSBenchResult(
            counts=res.info["counts"], fractions=res.info["fractions"],
            macro_xs=res.info["macro_xs"],
            lookups_done=res.steps_done,
            crashed_at=(res.crash_step + 1
                        if res.crash_step is not None else None),
            iterations_lost=res.info.get("iterations_lost", 0),
            modeled_overhead_seconds=res.modeled_total_seconds,
            wall_seconds=res.wall_seconds,
        )
