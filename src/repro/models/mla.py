"""Multi-head Latent Attention (DeepSeek-V2) — latent-compressed KV.

The KV path is compressed into a rank-``kv_lora_rank`` latent c_kv plus a
small shared RoPE key; the decode cache stores only (c_kv, k_rope) per
token — the serving-memory win MLA exists for. Per-head keys/values are
re-expanded from the latent at attention time.

  q      = x W_q                         -> (H, qk_nope + qk_rope)
  c_kv   = x W_dkv                       -> (r,)
  k_rope = RoPE(x W_kr)                  -> (qk_rope,)  shared across heads
  k_nope = c_kv W_uk                     -> (H, qk_nope)
  v      = c_kv W_uv                     -> (H, v_head_dim)
  attn((q_nope, RoPE(q_rope)), (k_nope, k_rope), v) W_o
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Axes, Params, apply_rope, dense_init

__all__ = ["mla_init", "mla_apply", "mla_cache_init"]


def mla_init(cfg: ModelConfig, key) -> Tuple[Params, Axes]:
    D, H = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    qk_n, qk_r, v_h = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], D, H * (qk_n + qk_r),
                                  "embed", "qheads", dtype)
    p["w_dkv"], a["w_dkv"] = dense_init(ks[1], D, r, "embed", "kv_lora", dtype)
    p["w_kr"], a["w_kr"] = dense_init(ks[2], D, qk_r, "embed", "kvheads", dtype)
    p["w_uk"], a["w_uk"] = dense_init(ks[3], r, H * qk_n,
                                      "kv_lora", "qheads", dtype)
    p["w_uv"], a["w_uv"] = dense_init(ks[4], r, H * v_h,
                                      "kv_lora", "qheads", dtype)
    p["wo"], a["wo"] = dense_init(ks[5], H * v_h, D, "qheads", "embed", dtype)
    return p, a


def _mla_attend(q_nope, q_rope, k_nope, k_rope, v, *, causal: bool,
                kv_len: Optional[jax.Array] = None):
    """q_nope (B,Sq,H,qk_n), q_rope (B,Sq,H,qk_r), k_nope (B,Sk,H,qk_n),
    k_rope (B,Sk,qk_r) shared, v (B,Sk,H,v_h)."""
    B, Sq, H, qk_n = q_nope.shape
    Sk = k_nope.shape[1]
    scale = 1.0 / ((qk_n + q_rope.shape[-1]) ** 0.5)
    logits = (jnp.einsum("bqhd,bshd->bhqs", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_len is not None:
        # kv_len: scalar or (B,); broadcast over (B, H, Sq, Sk)
        valid = jnp.arange(Sk)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q_nope.dtype)


def mla_apply(cfg: ModelConfig, p: Params, x: jax.Array,
              positions: jax.Array, *,
              cache: Optional[Dict[str, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None):
    """With ``cache`` = {"c_kv": (B, Smax, r), "k_rope": (B, Smax, qk_r)},
    performs a decode step against the *latent* cache."""
    B, S, D = x.shape
    H = cfg.n_heads
    qk_n, qk_r, v_h = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = x @ p["w_dkv"].astype(dt)                   # (B, S, r)
    k_rope_new = apply_rope((x @ p["w_kr"].astype(dt))[:, :, None, :],
                            positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = cache
    if cache is not None:
        c_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
            (0, cache_index, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
            (0, cache_index, 0))
        new_cache = {"c_kv": c_all, "k_rope": kr_all}
        kv_len = cache_index + S
        out = _mla_attend_absorbed(cfg, p, q_nope, q_rope,
                                   c_all.astype(dt), kr_all.astype(dt),
                                   kv_len=kv_len)
        return out @ p["wo"].astype(dt), new_cache

    Sk = c_kv.shape[1]
    k_nope = (c_kv @ p["w_uk"].astype(dt)).reshape(B, Sk, H, qk_n)
    v = (c_kv @ p["w_uv"].astype(dt)).reshape(B, Sk, H, v_h)
    out = _mla_attend(q_nope, q_rope, k_nope, k_rope_new, v,
                      causal=cfg.causal, kv_len=None)
    return out.reshape(B, S, H * v_h) @ p["wo"].astype(dt), new_cache


def _mla_attend_absorbed(cfg: ModelConfig, p, q_nope, q_rope, c_all, kr_all,
                         *, kv_len):
    """Weight-absorbed MLA decode (DeepSeek-V2 §serving): attend directly
    in the rank-r latent space — never expand per-token K/V.

        q_lat  = q_nope W_uk^T            (B, S, H, r)
        logits = q_lat · c_kv + q_rope · k_rope
        ctx    = probs · c_kv             (B, S, H, r)
        out    = ctx W_uv                 (B, S, H, v_h)

    Cache traffic per token drops from O(S·H·(qk_n+v_h)) for the
    expanded keys/values to O(S·r) latent reads — measured 3.7x on the
    deepseek-v2-lite decode_32k memory term (§Perf iteration 5)."""
    B, S, H, qk_n = q_nope.shape
    r = cfg.kv_lora_rank
    dt = q_nope.dtype
    w_uk = p["w_uk"].astype(dt).reshape(r, H, qk_n)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)       # absorb W_uk
    scale = 1.0 / ((qk_n + q_rope.shape[-1]) ** 0.5)
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_all,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, kr_all,
                           preferred_element_type=jnp.float32)) * scale
    Sk = c_all.shape[1]
    valid = jnp.arange(Sk)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs, c_all)          # latent ctx
    w_uv = p["w_uv"].astype(dt).reshape(r, H, cfg.v_head_dim)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)             # absorb W_uv
    return out.reshape(B, S, H * cfg.v_head_dim)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    cache = {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }
    axes = {
        "c_kv": ("batch", "seq_cache", "kv_lora"),
        "k_rope": ("batch", "seq_cache", "head_dim"),
    }
    return cache, axes
