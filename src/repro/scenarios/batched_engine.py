"""Batched crash-image evaluation for sweeps — ``sweep(mode="batched")``.

The fork engine made dense crash-point sweeps O(restore + recover) per
cell; this engine removes the per-cell restore/recover execution
entirely. The observation: a measure-mode cell's deterministic fields
are a *pure function* of (a) the golden prefix's modeled step costs and
(b) the post-crash NVM image at the cell's crash point — the live
strategy ``recover()`` call only re-derives information the snapshot
already holds. So:

1. Run the golden forward pass once (same as the fork engine), but
   alongside each crash-point snapshot capture the backend's dirty
   replacement queue (``dirty_eviction_order``) and region geometry.
2. For each crashed cell, replay the torn-survivor selection host-side
   (the exact shared :func:`~repro.core.backends.select_survivors` /
   :func:`~repro.core.backends.select_survivor_words` code) and build
   the post-crash view as *image overlaid with surviving dirty spans'
   truth* — byte-identical to what ``CrashEmulator.crash`` leaves in
   the image, without touching the emulator.
3. Evaluate every cell's recovery analytically from that view, with the
   numerically heavy parts — CG's invariant backward-scan and ABFT's
   per-chunk checksum verification — stacked across the *entire cell
   batch* and dispatched as a handful of jax jit launches through
   :mod:`repro.core.backends.batched` (on TPU a dense symmetrized-
   operator GEMM through the Pallas kernels; elsewhere a batched
   sparse gather matvec on XLA — see
   :func:`~repro.core.backends.batched.cg_route`). Device error
   magnitudes are accepted only outside a 2x certainty band around
   each tolerance; borderline candidates are re-checked with the exact
   host invariant/ABFT code, keeping batched cells bit-identical to
   measure cells.

Identity contract: a batched cell equals the corresponding measure cell
on every field of :func:`~repro.scenarios.driver.deterministic_cell_dict`
(``state_certified`` is fork/measure-only and stays ``None`` here; wall
-clock fields are excluded as always). tests/test_batched_sweep.py and
the ``sweep_timing`` divergence gate enforce this cell-for-cell.

The KV family evaluates analytically too: the request stream is a pure
function of (seed, i), so the strategies that restore a wholesale
committed state (none/checkpoint/shadow_snapshot/undo_log) reduce to
arithmetic on the host oracle's per-prefix live maps, and the adcc
policies replay root/commit-record validation plus the
durability/atomicity audit from each cell's crash image, with the
SplitMix64 row-checksum and value-word verification stacked over every
claimed row of the batch into
:func:`~repro.core.backends.batched.kv_row_checksums` /
:func:`~repro.core.backends.batched.kv_value_match` launches (integer
math — exact on device, so no certainty band; flagged-bad rows are
still re-confirmed by the exact host code).

Pairs the analytic evaluators do not cover — user-registered strategy
or workload subclasses, CG systems too large to densify on the dense
route (:data:`~repro.core.backends.batched.GEMM_MAX_N`; the sparse
route is ungated), or an environment without jax — fall back per-cell
to restore + ``_measure``
(without byte-certification), so ``mode="batched"`` is always safe to
request. Fallback cells carry the machine-readable reason in
``info["batched_fallback"]`` so benchmarks can assert zero fallbacks
for evaluator-covered workloads.

Not public API — use ``repro.scenarios.sweep(engine="fork",
mode="batched")``.
"""

from __future__ import annotations

import logging
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..algorithms.cg import _sym_matvec
from ..core import abft
from ..core.backends import batched as device
from ..core.backends.base import (LineSurvival, entry_span,
                                  select_survivor_words, select_survivors)
from ..core.invariants import (InvariantSet, OrthogonalityInvariant,
                               ResidualInvariant)
from .crashplan import CrashPlan, CrashPoint
from .driver import (AVG_STEP_JITTER_FLOOR, ScenarioResult, _finish,
                     _measure, _recovery_bookkeeping, classify_recovery)
from .kv import (_META_W as _KV_META_W, KVWorkload,
                 _mix_words as _kv_mix_words,
                 _value_words as _kv_value_words)
from .strategies import (AdccStrategy, CheckpointHddStrategy,
                         CheckpointNvmDramStrategy, CheckpointStrategy,
                         ConsistencyStrategy, NativeStrategy,
                         ShadowSnapshotStrategy, UndoLogStrategy)
from .sweep_engine import SnapshotTier, _CellSnapshot, _make_regen
from .workloads import (CGWorkload, MMWorkload, RecoveryResult, Workload,
                        XSBenchWorkload)

__all__ = ["run_pair_batched"]

_log = logging.getLogger(__name__)

# (workload type, strategy type, reason) triples already INFO-logged as
# uncovered by an analytic evaluator — later sweeps of the same pair in
# this process log at DEBUG only
_FALLBACK_LOGGED: set = set()

# CG invariant tolerances (ADCC_CG.recover) and the certainty-band
# factor: a device error magnitude within [tol/_BAND, tol*_BAND] is
# re-checked with the exact host code. Device and host agree to a few
# ulps (~1e-15 relative), so a factor-2 band is unreachable by rounding
# yet torn garbage still lands orders of magnitude outside it.
_CG_ORTH_TOL = 1e-7
_CG_RES_TOL = 1e-6
_BAND = 2.0

# ABFT tolerances MMWorkload's recovery passes to abft.verify/correct
_MM_RTOL = 1e-9
_MM_ATOL = 1e-6


# ---------------------------------------------------------------------------
# post-crash view assembly (host-side crash replay)
# ---------------------------------------------------------------------------

def _survivor_spans(survival: Optional[LineSurvival],
                    order: Sequence[Tuple[str, int]],
                    geometry: Dict[str, Tuple[int, int, int]]
                    ) -> Tuple[Dict[str, List[Tuple[int, int]]], int]:
    """Replay torn-survivor selection for one cell: the surviving element
    spans per region plus the persisted byte total (the cell's
    ``torn_bytes_persisted``). Uses the same shared selection/span code
    the backends call inside ``crash()``, so the result can never drift
    from a real crash."""
    spans: Dict[str, List[Tuple[int, int]]] = {}
    nbytes = 0
    if survival is None:
        return spans, nbytes
    if survival.granularity == "word":
        for name, _entry, lo, hi in select_survivor_words(
                order, survival, lambda nm: geometry[nm]):
            spans.setdefault(name, []).append((lo, hi))
            nbytes += (hi - lo) * geometry[name][2]
    else:
        for name, entry in select_survivors(order, survival):
            epe, n_elems, itemsize = geometry[name]
            lo, hi = entry_span(entry, epe, n_elems)
            spans.setdefault(name, []).append((lo, hi))
            nbytes += (hi - lo) * itemsize
    return spans, nbytes


class _CrashImage:
    """The post-crash NVM view of one cell, assembled host-side: the
    snapshot's image with the surviving dirty spans' *truth* pasted over
    — exactly the image ``CrashEmulator.crash`` would leave (writeback
    always persists truth spans, and post-crash truth is reloaded from
    the image, so this view serves reads of either side)."""

    __slots__ = ("_image", "_truth", "_spans")

    def __init__(self, emu_snap, spans: Dict[str, List[Tuple[int, int]]]):
        self._image = emu_snap.image
        self._truth = emu_snap.truth
        self._spans = spans

    def region(self, name: str) -> np.ndarray:
        img = self._image[name]
        spans = self._spans.get(name)
        if not spans:
            return img          # read-only snapshot view; callers only read
        out = img.copy()
        truth = self._truth[name]
        for lo, hi in spans:
            out[lo:hi] = truth[lo:hi]
        return out

    def scalar(self, name: str) -> int:
        return int(self.region(name)[0])


class _BatchedCell:
    """One crashed cell queued for analytic evaluation.

    Holds a snapshot *handle* (a zero-argument fetch), not the snapshot
    itself: under a snapshot tier the payload may be spilled or dropped
    between capture and evaluation, and the handle re-materializes it
    on access instead of keeping a reference that defeats eviction."""

    __slots__ = ("plan_desc", "point", "_snap_get", "spans", "torn_bytes",
                 "rec")

    def __init__(self, plan_desc: str, point: CrashPoint,
                 snap_get, order: Sequence[Tuple[str, int]],
                 geometry: Dict[str, Tuple[int, int, int]]):
        self.plan_desc = plan_desc
        self.point = point
        self._snap_get = snap_get
        self.spans, self.torn_bytes = _survivor_spans(
            point.survival, order, geometry)
        self.rec: Optional[RecoveryResult] = None

    @property
    def snap(self) -> _CellSnapshot:
        return self._snap_get()

    def crash_image(self) -> _CrashImage:
        return _CrashImage(self.snap.wl_snap["emu"], self.spans)


# ---------------------------------------------------------------------------
# per-strategy analytic evaluators
# ---------------------------------------------------------------------------

class _ScratchEvaluator:
    """none/native: crash always restarts from scratch."""

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        return [RecoveryResult(resume_step=0, restart_point=-1,
                               redo_steps=c.point.step + 1,
                               steps_lost=c.point.step + 1,
                               from_scratch=True)
                for c in cells]


class _CheckpointEvaluator:
    """checkpoint_*: resume from the snapshot's last checkpoint step."""

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        out = []
        for c in cells:
            crash = c.point.step
            last = c.snap.strat_snap["last_ckpt"]
            if last is None:
                out.append(RecoveryResult(
                    resume_step=0, restart_point=-1, redo_steps=crash + 1,
                    steps_lost=crash + 1, from_scratch=True))
            else:
                out.append(RecoveryResult(
                    resume_step=last + 1, restart_point=last,
                    redo_steps=crash - last, steps_lost=crash - last))
        return out


class _UndoLogEvaluator:
    """undo_log: an open uncommitted transaction at the crash point rolls
    back to the last commit. Log appends are fenced (transactions.py), so
    every reachable crash leaves an intact log: validation rejects 0
    entries and the torn flag reduces to "was a transaction open"."""

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        out = []
        for c in cells:
            crash = c.point.step
            snap = c.snap.strat_snap
            open_tx = snap["mgr"]["open_tx"]
            rolled_back = open_tx is not None and not open_tx["committed"]
            info = {"rolled_back": rolled_back,
                    "log_entries_rejected": 0,
                    "torn_flagged": rolled_back}
            last = snap["last_commit"]
            if last is None:
                out.append(RecoveryResult(
                    resume_step=0, restart_point=-1, redo_steps=crash + 1,
                    steps_lost=crash + 1, from_scratch=True, info=info))
            else:
                out.append(RecoveryResult(
                    resume_step=last + 1, restart_point=last,
                    redo_steps=crash - last, steps_lost=crash - last,
                    info=info))
        return out


class _ShadowSnapshotEvaluator:
    """shadow_snapshot: the root pointer only ever references a fully
    persisted slot, so recovery resumes from the active slot's step (or
    scratch before the first flip); a half-written staging slot is
    simply discarded."""

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        out = []
        for c in cells:
            crash = c.point.step
            snap = c.snap.strat_snap
            active = snap["active"]
            slots = snap["slots"]
            discarded = (slots[1 - active] is not None if active >= 0
                         else slots[0] is not None)
            info = {"shadow_discarded": discarded}
            if active < 0:
                out.append(RecoveryResult(
                    resume_step=0, restart_point=-1, redo_steps=crash + 1,
                    steps_lost=crash + 1, from_scratch=True, info=info))
            else:
                step = slots[active]["step"]
                out.append(RecoveryResult(
                    resume_step=step + 1, restart_point=step,
                    redo_steps=crash - step, steps_lost=crash - step,
                    info=info))
        return out


class _CGScan:
    """One cell's backward-scan state in the wave loop."""

    __slots__ = ("cell", "upper", "p", "q", "r", "z", "b", "tested",
                 "restart")

    def __init__(self, cell, upper, p, q, r, z, b):
        self.cell = cell
        self.upper = upper
        self.p, self.q, self.r, self.z, self.b = p, q, r, z, b
        self.tested = 0
        self.restart = -1


class _CGAdccEvaluator:
    """adcc + CG: the invariant backward-scan as a *wave* scan — each
    device launch evaluates one candidate per still-unresolved cell, so
    the batch does the same early-exiting amount of invariant math as
    the host scan (most cells accept their first or second candidate)
    instead of upper+1 candidates per cell. Only band-borderline
    candidates are re-checked by the exact host invariants."""

    def __init__(self, wl: CGWorkload):
        impl = wl._impl
        self._A = impl.A
        self._n = int(impl.A.n)
        # per-candidate read charge: 4 overlay rows + the operator —
        # ADCC_CG.recover's charge() (python ints summed, one division)
        self._charge = (4 * self._n * 8 + impl.A.nbytes()) / impl.emu.cfg.read_bw
        self._op = None

    def _operator(self):
        """The symmetrized operator S = 0.5*(A + A^T) in the
        representation ``cg_invariant_errors`` will route: densified
        for the Pallas GEMM on TPU; as padded equal-width row slabs
        (vals/cols (n, K), K the widest row, zero entries padding) for
        the gather-only sparse matvec elsewhere. Duplicate (row, col)
        entries are summed either way, exactly like the host's
        ``_sym_matvec``."""
        if self._op is None:
            A, n = self._A, self._n
            rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(A.indptr))
            if device.cg_route() == "dense":
                # scatter-ADD via bincount: CSR rows may repeat a column
                # index, and assignment would silently drop the
                # duplicates' sum; bincount accumulates them like
                # np.add.at but about an order of magnitude faster
                dense = np.bincount(rows * n + A.indices, weights=A.data,
                                    minlength=n * n).reshape(n, n)
                self._op = ("dense", 0.5 * (dense + dense.T))
            else:
                keys = np.concatenate([rows * n + A.indices,
                                       A.indices.astype(np.int64) * n + rows])
                uniq, inv = np.unique(keys, return_inverse=True)
                svals = 0.5 * np.bincount(
                    inv, weights=np.concatenate([A.data, A.data]))
                srows = (uniq // n).astype(np.int64)
                counts = np.bincount(srows, minlength=n)
                K = int(counts.max()) if len(counts) else 1
                starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
                pos = np.arange(len(uniq)) - np.repeat(starts, counts)
                vals2d = np.zeros((n, K))
                cols2d = np.zeros((n, K), dtype=np.int32)
                vals2d[srows, pos] = svals
                cols2d[srows, pos] = (uniq % n).astype(np.int32)
                self._op = ("sparse", vals2d, cols2d)
        return self._op

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        n = self._n
        states: List[_CGScan] = []
        active: List[_CGScan] = []
        b0: Optional[np.ndarray] = None
        for c in cells:
            ci = c.crash_image()
            st = _CGScan(c, ci.scalar("iter"),
                         ci.region("p").reshape(-1, n),
                         ci.region("q").reshape(-1, n),
                         ci.region("r").reshape(-1, n),
                         ci.region("z").reshape(-1, n),
                         np.asarray(ci.region("b"), dtype=np.float64))
            states.append(st)
            if b0 is None:
                b0 = st.b
            if st.upper < 0:
                continue            # no candidates: scratch restart
            if np.array_equal(st.b, b0):
                active.append(st)
            else:
                # b is never written after init, so one b serves the
                # whole device batch; if a cell ever disagreed, its
                # screen verdicts would be unsound — scan it with the
                # exact host code instead
                for j in range(st.upper, -1, -1):
                    st.tested += 1
                    if self._exact_ok(st, j):
                        st.restart = j
                        break
        # (cg_invariant_errors pads each launch to a fixed block, so jit
        # sees a constant shape as the active set shrinks wave to wave)
        op = self._operator() if active else None
        while active:
            W = len(active)
            P = np.empty((W, n))
            Q = np.empty((W, n))
            R = np.empty((W, n))
            Z = np.empty((W, n))
            for k, st in enumerate(active):
                j = st.upper - st.tested
                P[k] = st.p[j + 1]
                Q[k] = st.q[j]
                R[k] = st.r[j + 1]
                Z[k] = st.z[j + 1]
            orth, rel = device.cg_invariant_errors(P, Q, R, Z, b0, op)
            nxt: List[_CGScan] = []
            for k, st in enumerate(active):
                j = st.upper - st.tested
                st.tested += 1
                o = float(orth[k])
                r = float(rel[k])
                if o <= _CG_ORTH_TOL / _BAND and r <= _CG_RES_TOL / _BAND:
                    ok = True
                elif o >= _CG_ORTH_TOL * _BAND or r >= _CG_RES_TOL * _BAND:
                    ok = False
                else:
                    ok = self._exact_ok(st, j)
                if ok:
                    st.restart = j
                elif j > 0:
                    nxt.append(st)
            active = nxt
        out = []
        for st in states:
            # backward_scan accumulates the constant charge candidate by
            # candidate; repeat the float additions so detect_seconds is
            # bit-identical, not just close
            detect = 0.0
            for _ in range(st.tested):
                detect += self._charge
            crash = st.cell.point.step
            if st.restart >= 0:
                resume, lost = st.restart + 1, crash - st.restart
            else:
                resume, lost = 0, crash + 1
            out.append(RecoveryResult(
                resume_step=resume, restart_point=st.restart,
                detect_seconds=detect, redo_steps=crash + 1 - resume,
                steps_lost=lost, from_scratch=st.restart < 0,
                info={"iterations_lost": lost,
                      "torn_flagged": st.tested > 1}))
        return out

    def _exact_ok(self, st: _CGScan, j: int) -> bool:
        invs = InvariantSet([
            OrthogonalityInvariant("p_next", "q_cur", tol=_CG_ORTH_TOL),
            ResidualInvariant("r_next", "z_next", b=st.b,
                              matvec=lambda x: _sym_matvec(self._A, x),
                              tol=_CG_RES_TOL),
        ])
        return invs.holds({"p_next": st.p[j + 1], "q_cur": st.q[j],
                           "r_next": st.r[j + 1], "z_next": st.z[j + 1]})


class _MMAdccEvaluator:
    """adcc + MM: checksum-classify every examined loop-1 chunk with one
    device batch over all cells (exact host ABFT only where the screen is
    not certain), then the cheap exact loop-2 block classification."""

    def __init__(self, wl: MMWorkload):
        impl = wl._impl
        self._n = int(impl.n)
        self._m = self._n + 1
        self._nchunks = int(impl.nchunks)
        self._row_blocks = list(impl.row_blocks)
        self._read_bw = impl.emu.cfg.read_bw

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        m = self._m
        prepared = []
        views: List[np.ndarray] = []
        for c in cells:
            ci = c.crash_image()
            upper = ci.scalar("mm_iter")
            # a loop-2 crash still scans ALL chunks (the persisted
            # counter is past nchunks), and loop-2 cells need the scan's
            # corrected_elements even though chunks don't set their lost
            examined = min(upper + 1, self._nchunks)
            base = len(views)
            chunk_views = [np.asarray(ci.region(f"C_s{s}")).reshape(m, m)
                           for s in range(examined)]
            views.extend(chunk_views)
            prepared.append((c, ci, examined, base, chunk_views))
        if views:
            nonzero, absmax, rowmax, colmax = device.mm_chunk_stats(
                np.stack(views))
        out = []
        for c, ci, examined, base, chunk_views in prepared:
            crash = c.point.step
            bad: List[int] = []
            corrected = 0
            nbytes = 0
            for s in range(examined):
                view = chunk_views[s]
                nbytes += view.nbytes
                i = base + s
                tol = _MM_ATOL + _MM_RTOL * max(float(absmax[i]), 1.0)
                if (bool(nonzero[i]) and float(rowmax[i]) <= tol / _BAND
                        and float(colmax[i]) <= tol / _BAND):
                    continue        # certainly verifies: chunk is good
                # not certain — run the exact host loop body
                if np.any(view != 0) and abft.verify(view, rtol=_MM_RTOL,
                                                     atol=_MM_ATOL):
                    continue
                fixed, nfix = abft.correct_single_error(view, rtol=_MM_RTOL,
                                                        atol=_MM_ATOL)
                if fixed is not None:
                    corrected += nfix
                else:
                    bad.append(s)
            detect = nbytes / self._read_bw
            if crash < self._nchunks:
                lost, crashed_in = len(bad), "loop1"
            else:
                blocks_done = crash - self._nchunks + 1
                ct = np.asarray(ci.region("C_temp")).reshape(m, m)
                row_resid = ct[:, self._n] - ct[:, :self._n].sum(axis=1)
                scale = max(float(np.max(np.abs(ct))), 1.0)
                tol2 = _MM_ATOL + _MM_RTOL * scale
                bad_blocks = [
                    bi for bi, (lo, hi)
                    in enumerate(self._row_blocks[:blocks_done])
                    if np.any(np.abs(row_resid[lo:hi]) > tol2)
                    or not np.any(ct[lo:hi, :] != 0)]
                detect = detect + ct.nbytes / self._read_bw
                lost, crashed_in = len(bad_blocks), "loop2"
            out.append(RecoveryResult(
                resume_step=crash + 1, restart_point=crash,
                detect_seconds=detect, redo_steps=lost, steps_lost=lost,
                info={"crashed_in": crashed_in, "chunks_lost": lost,
                      "corrected_elements": corrected,
                      "torn_flagged": lost > 0 or corrected > 0}))
        return out


class _XSBenchEvaluator:
    """adcc + XSBench: pure counter arithmetic on the post-crash view —
    no device work needed, and the dominant cell population of dense
    torn sweeps (every cell is O(1) here vs a restore + recover)."""

    def __init__(self, wl: XSBenchWorkload):
        self._ntypes = len(wl._impl._counters)

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        out = []
        for c in cells:
            ci = c.crash_image()
            crash = c.point.step
            crashed_lookups = crash + 1
            resume_i = ci.scalar("lookup_index")
            counted = sum(ci.scalar(f"type_counter_{t}")
                          for t in range(self._ntypes))
            lost = max(0, resume_i - counted) + (crashed_lookups - resume_i)
            out.append(RecoveryResult(
                resume_step=resume_i, restart_point=resume_i - 1,
                redo_steps=crashed_lookups - resume_i, steps_lost=lost,
                from_scratch=resume_i == 0,
                info={"iterations_lost": lost,
                      "torn_flagged": counted != resume_i,
                      "state_corrupt": counted > resume_i}))
        return out


# ---------------------------------------------------------------------------
# KV-family evaluators
# ---------------------------------------------------------------------------

class _KVStateEvaluator:
    """Wrap a state-restoring evaluator (scratch / checkpoint / shadow /
    undo log) with the KV durability/atomicity audit, computed from the
    host request oracle instead of the live recovered store.

    Every strategy on this route restores a wholesale committed state,
    so the store the audit would inspect is byte-for-byte the clean
    end-of-step state of ``resume_step - 1``: its semantic map is the
    oracle's live map at that prefix with every integrity verdict True,
    no reader-visible corrupt rows, and an intact meta root. The audit
    therefore reduces to dictionary arithmetic on the oracle maps — and
    ``resume_step <= acked_requests`` always holds (strategy persistence
    runs in ``after_step``, torn snapshots are captured before it), so
    the in-flight atomicity scan range is empty and atomicity is 0."""

    def __init__(self, wl: KVWorkload, base):
        self._maps = wl._oracle()[0]
        self._base = base

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        recs = self._base.recover_batch(cells)
        for c, rec in zip(cells, recs):
            acked_n = c.point.step + (0 if c.point.torn else 1)
            acked = self._maps[acked_n]
            vis = self._maps[rec.resume_step]
            dur = sum(1 for key, (seq_o, _nw) in acked.items()
                      if key not in vis or vis[key][0] < seq_o)
            dur += sum(1 for key in vis if key not in acked)
            rec.info["acked_requests"] = acked_n
            rec.info["durability_violations"] = dur
            rec.info["atomicity_violations"] = 0
        return recs


class _KVAdccEvaluator:
    """adcc + KV: replay root/commit-record validation and the
    durability/atomicity audit from each cell's crash image (post-crash
    truth is reloaded from the image, so the image serves reads of
    either side). The dominant cost — per-row SplitMix64 checksum
    chains and value-word recomputation, O(rows x words) integer
    hashing — runs as one stacked device launch over every claimed row
    of the whole cell batch
    (:func:`~repro.core.backends.batched.kv_row_checksums` /
    :func:`~repro.core.backends.batched.kv_value_match`). The device
    pipeline computes the same 63-bit integer function exactly, so
    there is no certainty band; per the established discipline any row
    the device flags bad is still re-confirmed by the exact host
    ``_row_ok`` port before it can reject a root or count a violation.
    The kernels' host fallbacks keep this route available without jax,
    just slower."""

    def __init__(self, wl: KVWorkload):
        self._wl = wl
        self._maps = wl._oracle()[0]
        self._read_bw = wl.emu.cfg.read_bw

    def _host_row_ok(self, row: np.ndarray,
                     vlogs: List[np.ndarray]) -> bool:
        """Exact image-side port of ``KVWorkload._row_ok``."""
        wl = self._wl
        if int(row[7]) != _kv_mix_words(row[:7]):
            return False
        nw = int(row[3])
        if nw <= 0:
            return True
        key, seq, goff = int(row[0]) - 1, int(row[1]), int(row[2])
        e, off = divmod(goff, wl.extent_words)
        if not (0 <= e < wl.n_extents and 0 <= off
                and off + nw <= wl.extent_words):
            return False
        got = vlogs[e][off:off + nw]
        return bool(np.array_equal(got, _kv_value_words(key, seq, nw)))

    def _audit(self, rec: RecoveryResult, acked_n: int, idx: np.ndarray,
               rows_ok: Dict[int, bool], meta: np.ndarray,
               meta_ok: Sequence[bool]) -> None:
        """``KVWorkload.audit_recovery`` on an image-side store view:
        ``rows_ok`` maps reader-visible claimed row -> integrity verdict
        (rows a validate recovery dropped are simply absent, matching
        the zeroed live rows the real audit walks)."""
        wl = self._wl
        visible: Dict[int, Tuple[int, bool]] = {}   # key -> (seq, ok)
        corrupt = 0
        for s in range(wl.n_slots):
            best = None
            for v in (0, 1):
                r = 2 * s + v
                if r not in rows_ok:
                    continue
                if best is None or int(idx[r, 1]) > int(idx[best, 1]):
                    best = r
            if best is None:
                continue
            if not rows_ok[best]:
                corrupt += 1
            if int(idx[best, 3]) > 0:
                visible[int(idx[best, 0]) - 1] = (int(idx[best, 1]),
                                                  rows_ok[best])
        atom = corrupt
        if not any(int(meta[v, 1]) == rec.resume_step and meta_ok[v]
                   for v in (0, 1)):
            atom += 1
        for j in range(acked_n, rec.resume_step):
            op, key, _nw = wl._request(j)
            if op == "get":
                continue
            ent = visible.get(key)
            if op == "put":
                if ent is None or ent[0] != j + 1 or not ent[1]:
                    atom += 1
            elif ent is not None and ent[0] < j + 1:
                atom += 1
        acked = self._maps[acked_n]
        dur = 0
        for key, (seq_o, _nw) in acked.items():
            ent = visible.get(key)
            if (ent is None or ent[0] < seq_o
                    or (ent[0] == seq_o and not ent[1])):
                dur += 1
        for key, ent in visible.items():
            if key not in acked and ent[1] and ent[0] <= acked_n:
                dur += 1
        rec.info["acked_requests"] = acked_n
        rec.info["durability_violations"] = dur
        rec.info["atomicity_violations"] = atom

    def recover_batch(self, cells: List[_BatchedCell]) -> List[RecoveryResult]:
        wl = self._wl
        n_rows = 2 * wl.n_slots
        ew = wl.extent_words
        prepared = []
        idx_blocks: List[np.ndarray] = []
        meta_blocks: List[np.ndarray] = []
        bounds_bad: List[np.ndarray] = []
        val_pos: List[int] = []     # flat claimed-row position of each item
        val_keys: List[int] = []
        val_seqs: List[int] = []
        val_nws: List[int] = []
        val_spans: List[np.ndarray] = []
        base = 0
        for c in cells:
            ci = c.crash_image()
            meta = np.asarray(ci.region("kv.meta")).reshape(2, _KV_META_W)
            idx = np.asarray(ci.region("kv.index")).reshape(n_rows, 8)
            vlogs = [np.asarray(ci.region(f"kv.vlog{e}"))
                     for e in range(wl.n_extents)]
            claimed = np.flatnonzero(idx[:, 0] != 0)
            rows = idx[claimed]
            bad = np.zeros(len(claimed), dtype=bool)
            for p in range(len(claimed)):
                nw = int(rows[p, 3])
                if nw <= 0:
                    continue
                e, off = divmod(int(rows[p, 2]), ew)
                if not (0 <= e < wl.n_extents and off + nw <= ew):
                    bad[p] = True       # torn (goff, nwords): row invalid
                    continue
                val_pos.append(base + p)
                val_keys.append(int(rows[p, 0]) - 1)
                val_seqs.append(int(rows[p, 1]))
                val_nws.append(nw)
                val_spans.append(vlogs[e][off:off + nw])
            idx_blocks.append(rows)
            meta_blocks.append(meta)
            bounds_bad.append(bad)
            prepared.append((c, meta, idx, vlogs, claimed, base))
            base += len(claimed)

        # one stacked launch per verification kind across the whole batch
        if base:
            all_rows = np.vstack(idx_blocks)
            row_ok_flat = (device.kv_row_checksums(all_rows[:, :7])
                           == all_rows[:, 7])
            row_ok_flat &= ~np.concatenate(bounds_bad)
        else:
            row_ok_flat = np.empty(0, dtype=bool)
        all_meta = np.vstack(meta_blocks)
        meta_ck = (device.kv_row_checksums(all_meta[:, :_KV_META_W - 1])
                   == all_meta[:, _KV_META_W - 1])
        if val_pos:
            wmax = max(val_nws)
            got = np.zeros((len(val_pos), wmax), dtype=np.int64)
            for i, span in enumerate(val_spans):
                got[i, :len(span)] = span
            vok = device.kv_value_match(
                np.asarray(val_keys, dtype=np.int64),
                np.asarray(val_seqs, dtype=np.int64), got,
                np.asarray(val_nws, dtype=np.int64))
            row_ok_flat[np.asarray(val_pos)] &= vok

        out = []
        for i, (c, meta, idx, vlogs, claimed, b) in enumerate(prepared):
            # host re-confirmation of every device-flagged-bad row/root
            rows_ok: Dict[int, bool] = {}
            for j, r in enumerate(claimed):
                ok = bool(row_ok_flat[b + j])
                if not ok:
                    ok = self._host_row_ok(idx[r], vlogs)
                rows_ok[int(r)] = ok
            meta_ok = []
            for v in (0, 1):
                ok = bool(meta_ck[2 * i + v])
                if not ok:
                    ok = (int(meta[v, -1]) == _kv_mix_words(meta[v, :-1]))
                meta_ok.append(ok)
            out.append(self._eval_cell(c, meta, meta_ok, idx, rows_ok))
        return out

    def _eval_cell(self, c: _BatchedCell, meta: np.ndarray,
                   meta_ok: Sequence[bool], idx: np.ndarray,
                   rows_ok: Dict[int, bool]) -> RecoveryResult:
        """Exact replay of ``KVWorkload.adcc_recover`` + the audit on the
        resulting store view."""
        wl = self._wl
        crash = c.point.step
        acked_n = crash + (0 if c.point.torn else 1)
        raw = max(int(meta[v, 1]) for v in (0, 1))
        if wl.policy == "blind":
            rec = RecoveryResult(
                resume_step=raw, restart_point=raw - 1,
                detect_seconds=meta.nbytes / self._read_bw,
                redo_steps=crash + 1 - raw, from_scratch=raw == 0,
                info={"policy": "blind", "torn_flagged": False})
            self._audit(rec, acked_n, idx, rows_ok, meta, meta_ok)
            return rec
        read_bytes = meta.nbytes + idx.nbytes
        for r in rows_ok:
            read_bytes += 8 * max(0, int(idx[r, 3]))
        detect = read_bytes / self._read_bw
        valid = [v for v in (0, 1) if meta_ok[v]]
        resume = None
        for cc, v in sorted(((int(meta[v, 1]), v) for v in valid),
                            reverse=True):
            ok_c = all(ok or int(idx[r, 1]) != cc
                       for r, ok in rows_ok.items())
            fp = int(meta[v, 7])
            if ok_c and fp:
                r = fp - 1
                ok_c = (0 <= r < 2 * wl.n_slots
                        and rows_ok.get(r, False)
                        and int(idx[r, 1]) == cc
                        and int(idx[r, 7]) == int(meta[v, 8]))
            if ok_c:
                resume = cc
                break
        if resume is None:
            rec = RecoveryResult(
                resume_step=0, restart_point=-1, detect_seconds=detect,
                redo_steps=crash + 1, steps_lost=crash + 1,
                from_scratch=True,
                info={"policy": "validate", "torn_flagged": True,
                      "slots_dropped": 0})
            # the real path resets the store before the audit: empty
            # semantic map, intact committed=0 root => every acked live
            # key is a durability violation and nothing else counts
            rec.info["acked_requests"] = acked_n
            rec.info["durability_violations"] = len(self._maps[acked_n])
            rec.info["atomicity_violations"] = 0
            return rec
        dropped = 0
        kept: Dict[int, bool] = {}
        for r, ok in rows_ok.items():
            if not ok or int(idx[r, 1]) > resume:
                dropped += 1
            else:
                kept[r] = True
        rec = RecoveryResult(
            resume_step=resume, restart_point=resume - 1,
            detect_seconds=detect, redo_steps=crash + 1 - resume,
            from_scratch=resume == 0,
            info={"policy": "validate",
                  "torn_flagged": dropped > 0 or resume < raw,
                  "slots_dropped": dropped})
        self._audit(rec, acked_n, idx, kept, meta, meta_ok)
        return rec


_SCRATCH_TYPES = (ConsistencyStrategy, NativeStrategy)
_CKPT_TYPES = (CheckpointStrategy, CheckpointHddStrategy,
               CheckpointNvmDramStrategy)


def _make_evaluator(wl: Workload, strat: ConsistencyStrategy):
    """``(evaluator, fallback_reason)`` for this (workload, strategy)
    pair: an analytic batch evaluator with ``reason=None``, or
    ``(None, reason)`` to fall back to per-cell measure evaluation. The
    reason string is machine-readable and lands in fallback cells'
    ``info["batched_fallback"]`` so sweep gates can assert zero
    fallbacks for covered workloads. Dispatch is on EXACT types: a
    subclass may override ``recover()``, and guessing wrong would
    silently break the batched==measure identity."""
    t = type(strat)
    if type(wl) is KVWorkload:
        # the KV audit inspects the recovered store; the evaluators
        # reproduce it from the request oracle (state-restoring
        # strategies) or from the crash image (adcc)
        if t in _SCRATCH_TYPES:
            return _KVStateEvaluator(wl, _ScratchEvaluator()), None
        if t in _CKPT_TYPES:
            return _KVStateEvaluator(wl, _CheckpointEvaluator()), None
        if t is ShadowSnapshotStrategy:
            return _KVStateEvaluator(wl, _ShadowSnapshotEvaluator()), None
        if t is UndoLogStrategy:
            return _KVStateEvaluator(wl, _UndoLogEvaluator()), None
        if t is AdccStrategy:
            return _KVAdccEvaluator(wl), None
        return None, f"unsupported-strategy:{t.__name__}"
    if type(wl).audit_recovery is not Workload.audit_recovery:
        # an unknown auditing workload inspects the live recovered
        # state; analytic evaluators never run recovery, so its info
        # fields would diverge from measure cells
        return None, f"audit-override:{type(wl).__name__}"
    if t in _SCRATCH_TYPES:
        return _ScratchEvaluator(), None
    if t in _CKPT_TYPES:
        return _CheckpointEvaluator(), None
    if t is ShadowSnapshotStrategy:
        return _ShadowSnapshotEvaluator(), None
    if t is UndoLogStrategy:
        return _UndoLogEvaluator(), None
    if t is AdccStrategy:
        if type(wl) is XSBenchWorkload:
            return _XSBenchEvaluator(wl), None
        if not device.have_jax():
            return None, "no-jax"
        if type(wl) is CGWorkload:
            # only the dense (TPU/Pallas GEMM) route densifies the
            # operator; the sparse route scales with nnz and is ungated
            if (device.cg_route() == "dense"
                    and wl._impl.A.n > device.GEMM_MAX_N):
                return None, "cg-too-large"
            return _CGAdccEvaluator(wl), None
        if type(wl) is MMWorkload:
            return _MMAdccEvaluator(wl), None
    return None, f"unsupported:{type(wl).__name__}/{t.__name__}"


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class _AvgStepCache:
    """O(1) crash-phase mean step seconds from prefix sums — the
    quantity ``_crash_avg_step`` computes from the sliced duration
    lists, without building an O(crash_step) list per cell. avg_step
    feeds only wall-clock fields (``avg_step_seconds``,
    ``resume_seconds``), which cell comparisons exclude, so the
    reassociated summation is safe."""

    def __init__(self, wl: Workload, wall: List[float],
                 modeled: List[float]):
        self._phases = list(wl.phases().values())
        self._n = wl.n_steps
        self._cw = np.concatenate(([0.0], np.cumsum(wall)))
        self._cm = np.concatenate(([0.0], np.cumsum(modeled)))

    def at(self, crash_step: int, wall_last: float,
           modeled_last: float) -> float:
        rng = next((r for r in self._phases if crash_step in r),
                   range(self._n))
        lo = rng.start
        hi = min(rng.stop, crash_step + 1)  # durs list has crash_step+1
        cnt = max(1, hi - lo)
        if hi == crash_step + 1:            # crash step is in the phase:
            w = self._cw[crash_step] - self._cw[lo] + wall_last
            m = self._cm[crash_step] - self._cm[lo] + modeled_last
        else:
            w = self._cw[hi] - self._cw[lo]
            m = self._cm[hi] - self._cm[lo]
        if w / cnt >= AVG_STEP_JITTER_FLOOR:
            return float(w / cnt)
        return float(m / cnt)


def _assemble(wl: Workload, strat: ConsistencyStrategy, cell: _BatchedCell,
              avg_cache: _AvgStepCache, t0: float) -> ScenarioResult:
    """Build the ScenarioResult for one analytically evaluated cell —
    field-for-field the ``driver._measure`` construction, with the
    RecoveryResult coming from the batch evaluator instead of a live
    ``strat.recover()`` and ``torn_bytes_persisted`` from the host-side
    survivor replay instead of the emulator's stats delta."""
    point = cell.point
    crash_step = point.step
    snap = cell.snap
    n = wl.n_steps
    avg_step = avg_cache.at(crash_step, snap.wall_last, snap.modeled_last)
    rec = cell.rec
    lost, redo = _recovery_bookkeeping(rec, crash_step)
    overhead = strat.modeled_overhead_seconds(wl.step_cost_profile(),
                                              wl.emu.cfg, crash_step + 1)
    info = dict(rec.info)
    if point.survival is not None:
        info["torn_bytes_persisted"] = cell.torn_bytes
    return ScenarioResult(
        workload=wl.name, workload_params=wl.params(),
        strategy=strat.name, plan=cell.plan_desc,
        crash_step=crash_step, torn=point.torn,
        torn_survival=(point.survival.describe()
                       if point.survival is not None else None),
        fault=None,  # fault-carrying points route to per-cell fallback
        steps_total=n, steps_done=n,
        restart_point=rec.restart_point, resume_step=rec.resume_step,
        steps_lost=lost, steps_recomputed=redo,
        detect_seconds=rec.detect_seconds, resume_seconds=avg_step * redo,
        avg_step_seconds=avg_step,
        overhead_seconds=overhead,
        modeled_total_seconds=None,
        wall_seconds=time.perf_counter() - t0,
        correct=None,
        correctness_class=classify_recovery(True, crash_step, rec,
                                            point.survival),
        state_certified=None,
        metrics=None,
        traffic=None,
        info=info,
    )


def run_pair_batched(wl: Workload, strat: ConsistencyStrategy,
                     grounded: Sequence[Tuple[CrashPlan, List[CrashPoint]]],
                     progress=None,
                     snapshot_budget_bytes: Optional[int] = None,
                     snapshot_policy: str = "spill") -> List[ScenarioResult]:
    """Evaluate every cell of one set-up (workload, strategy) pair in
    batched mode. Same contract as ``run_pair_forked(mode="measure")``
    minus ``state_certified``: ScenarioResults in plan-major,
    point-minor order, deterministic fields identical cell-for-cell.

    ``snapshot_budget_bytes``/``snapshot_policy`` run the snapshot set
    under the same :class:`~repro.scenarios.sweep_engine.SnapshotTier`
    as the fork engine; batched cells hold tier *handles*, so a
    snapshot evicted between capture and analytic evaluation is
    reloaded (or recomputed from the golden prefix) on access."""
    strat.attach(wl)
    emu = wl.emu
    n = wl.n_steps

    want = set()
    for _plan, points in grounded:
        for p in points:
            want.add((p.step, p.torn) if p.step is not None
                     else (None, False))

    # -- golden forward pass (mirrors run_pair_forked, no certify ladder);
    #    additionally captures the crash context — dirty replacement
    #    queue + region geometry — each survivor replay needs
    need_full = (None, False) in want
    last_point = max((s for s, _ in want if s is not None), default=-1)
    snaps: Dict[Tuple[Optional[int], bool], _CellSnapshot] = {}
    tier: Optional[SnapshotTier] = None
    if snapshot_budget_bytes is not None:
        tier = SnapshotTier(snapshot_budget_bytes, snapshot_policy)

    def snap_put(key, snap: _CellSnapshot, pin: bool = False) -> None:
        if tier is None:
            snaps[key] = snap
        else:
            tier.put(key, snap, pin=pin)

    def snap_get(key) -> Optional[_CellSnapshot]:
        if tier is None:
            return snaps.get(key)
        return tier.get(key)

    ctxs: Dict[Tuple[int, bool], tuple] = {}
    wall: List[float] = []
    modeled: List[float] = []

    def capture_ctx(key):
        order = emu.backend.dirty_eviction_order()
        geometry = {name: emu.backend.entry_geometry(name)
                    for name in {nm for nm, _ in order}}
        ctxs[key] = (order, geometry)

    if tier is not None:
        # pinned tier-0 root every recompute-on-miss can replay from
        snap_put((-1, False), _CellSnapshot(wl, strat, 0.0, 0.0), pin=True)
    for i in range(n):
        ts = time.perf_counter()
        m0 = emu.modeled_seconds()
        strat.before_step(i)
        wl.step(i)
        if (i, True) in want:   # torn: before the persistence hook
            torn_wall = time.perf_counter() - ts
            snap_put((i, True), _CellSnapshot(
                wl, strat, torn_wall, emu.modeled_seconds() - m0))
            capture_ctx((i, True))
            # keep capture cost out of the step's recorded duration
            ts = time.perf_counter() - torn_wall
        strat.after_step(i)
        wall.append(time.perf_counter() - ts)
        modeled.append(emu.modeled_seconds() - m0)
        if (i, False) in want:
            snap_put((i, False), _CellSnapshot(wl, strat, wall[-1],
                                               modeled[-1]))
            capture_ctx((i, False))
        if not need_full and i == last_point:
            break
    if need_full:
        snap_put((None, False), _CellSnapshot(wl, strat, 0.0, 0.0),
                 pin=True)
    if tier is not None:
        tier.set_regen(_make_regen(tier, wl, strat))

    # -- split cells: analytic batch vs full/fallback ---------------------
    evaluator, fallback_reason = _make_evaluator(wl, strat)
    if evaluator is None:
        key = (type(wl).__name__, type(strat).__name__, fallback_reason)
        # INFO once per uncovered pair per process (a dense sweep visits
        # the same pair for every plan), DEBUG after
        level = logging.DEBUG if key in _FALLBACK_LOGGED else logging.INFO
        _FALLBACK_LOGGED.add(key)
        _log.log(level,
                 "batched sweep: no analytic evaluator for (%s, %s) "
                 "[%s]; crashed cells fall back to per-cell measure",
                 type(wl).__name__, type(strat).__name__, fallback_reason)
    pending: List[_BatchedCell] = []
    emit: List[tuple] = []      # (kind, plan_desc, point, cell|None)
    for plan, points in grounded:
        desc = plan.describe()
        for point in points:
            if point.step is None:
                emit.append(("full", desc, point, None))
            elif evaluator is None or point.fault is not None:
                # fault cells need the live golden-compare recovery
                # harness (nested-crash retry, media-fault injection) —
                # always the per-cell measure path
                emit.append(("fallback", desc, point, None))
            else:
                key = (point.step, point.torn)
                order, geometry = ctxs[key]
                cell = _BatchedCell(desc, point,
                                    lambda k=key: snap_get(k),
                                    order, geometry)
                pending.append(cell)
                emit.append(("batched", desc, point, cell))

    if pending:
        for cell, rec in zip(pending, evaluator.recover_batch(pending)):
            cell.rec = rec

    # -- emit in plan-major, point-minor order ----------------------------
    avg_cache = _AvgStepCache(wl, wall, modeled)
    results: List[ScenarioResult] = []
    for kind, desc, point, cell in emit:
        t0 = time.perf_counter()
        if kind == "full":
            snap = snap_get((None, False))
            snap.restore(wl, strat)
            res = _finish(wl, strat, point, desc, recover=True,
                          crashed=False, wall_durs=wall,
                          modeled_durs=modeled, t0=t0)
        elif kind == "fallback":
            snap = snap_get((point.step, point.torn))
            snap.restore(wl, strat)
            s = point.step
            res = _measure(wl, strat, point, desc,
                           wall[:s] + [snap.wall_last],
                           modeled[:s] + [snap.modeled_last], t0)
            res.info["batched_fallback"] = (
                "fault-cell" if point.fault is not None
                else fallback_reason)
        else:
            res = _assemble(wl, strat, cell, avg_cache, t0)
        results.append(res)
        if progress is not None:
            progress(res)
    if tier is not None:
        tier_info = tier.stats.to_dict()
        for res in results:
            res.info["snapshot_tier"] = tier_info
        tier.close()
    return results
