"""repro.scenarios — the unified scenario layer: every crash-consistence
experiment is one point in Workload × ConsistencyStrategy × CrashPlan.

The paper's comparison matrix (3 algorithms × 7 mechanisms × many crash
points) used to be hand-wired into each algorithm driver and each
benchmark figure; this package factors the three axes apart so a new
mechanism, workload, or crash scenario is one registry entry, not six
file edits.

Module map:

  workloads   Workload protocol + adapters for the paper's algorithms
              (CGWorkload, MMWorkload, XSBenchWorkload) and the
              WORKLOADS registry. Workloads run in "adcc" mode (the
              paper's extended algorithm) or "plain" mode (the
              unmodified baseline the traditional mechanisms protect).
  strategies  ConsistencyStrategy protocol + STRATEGIES registry:
              none / adcc / undo_log / checkpoint_{hdd,nvm,nvm_dram},
              with "@interval" variants; wraps the core TxManager and
              CheckpointBaseline machinery.
  crashplan   Declarative CrashPlan: no_crash / at_step / at_phase /
              at_fraction / seeded random batches; ``torn=True`` crashes
              inside the step boundary (exercises rollback paths), and
              ``torn=TornSpec(fraction, seed, mode, samples)`` makes the
              torn crash a parameterized *line-survival* image: a seeded
              subset of the dirty cache lines persisted before power
              loss (the WITCHER/EasyCrash crash-state space), one cell
              per sample. ``fault=FaultSpec(...)`` arms a fault
              campaign on every crash point: nested crashes that
              re-crash *during recovery* (re-entrancy certification
              against the single-crash golden cell) and/or seeded
              media faults that silently poison the post-crash image
              (detection-coverage certification).
  kv          KVWorkload — the beyond-paper persistent KV-serving
              family: an NVM-backed store (A/B-versioned hash index +
              append-only value-log extents) driven by seeded zipfian
              get/put/delete streams (ETC/UDB profiles), with
              algorithm-directed per-request persistence, durability /
              atomicity auditing against the acknowledged prefix, and
              the shadow_snapshot strategy as its natural baseline.
  costmodel   StepCostProfile + mechanism_step_seconds(): the single
              source for the paper's Figs. 4/8/13 modeled mechanism
              costs, and mechanism_cases() — the canonical 7-mechanism
              comparison axis.
  driver      run_scenario() -> ScenarioResult (uniform overhead /
              recompute / correctness / traffic fields) and sweep(),
              the batched matrix runner that emits BENCH_scenarios.json.
              sweep(engine="fork"|"rerun") selects execution: "fork"
              (default) shares one prefix run per (workload, strategy)
              pair via snapshots, "rerun" re-executes every cell from
              step 0 (the oracle both must match cell-for-cell).
              sweep(mode="measure") computes each crashed cell's
              recompute/restart fields from the recovered state instead
              of executing the tail (O(restore + recover) per cell);
              sweep(workers=N) shards the independent (workload,
              strategy) pairs across N processes with a deterministic
              pair-major merge.
  sweep_engine the prefix-sharing fork engine: snapshot/restore on
              MemoryBackend + Workload + ConsistencyStrategy makes a
              crash-point batch O(tail) instead of O(full re-run),
              so dense plans (CrashPlan.at_every_step()) are tractable.

Ten-line tour::

    from repro.scenarios import CrashPlan, run_scenario, sweep

    res = run_scenario(("cg", {"n": 8192, "iters": 16}), "adcc",
                       CrashPlan.at_step(14))
    print(res.restart_point, res.steps_lost, res.correct)

    cells = sweep(workloads=("cg", "mm", "xsbench"),
                  strategies=("none", "adcc", "undo_log",
                              "checkpoint_nvm"),
                  plans=(CrashPlan.no_crash(), CrashPlan.at_fraction(0.5)),
                  out_json="BENCH_scenarios.json")
"""

from ..core.backends import LineSurvival, MediaFault
from .crashplan import CrashPlan, CrashPoint, FaultSpec, TornSpec
from .costmodel import (
    MECHANISM_CASES,
    MechanismCase,
    StepCostProfile,
    cg_step_profile,
    mechanism_cases,
    mechanism_step_seconds,
    mm_step_profile,
    kv_step_profile,
    xsbench_step_profile,
)
from .workloads import (
    WORKLOADS,
    CGWorkload,
    FinalReport,
    MMWorkload,
    RecoveryResult,
    Workload,
    XSBenchWorkload,
    make_workload,
    register_workload,
)
from .strategies import (
    STRATEGIES,
    AdccStrategy,
    CheckpointStrategy,
    ConsistencyStrategy,
    NativeStrategy,
    ShadowSnapshotStrategy,
    UndoLogStrategy,
    make_strategy,
    register_strategy,
    strategy_names,
)
from .kv import KV_PROFILES, KVProfile, KVWorkload  # registers "kv"
from .driver import (
    AVG_STEP_JITTER_FLOOR,
    DEFAULT_SWEEP_PLANS,
    FORK_ONLY_FIELDS,
    FULL_RUN_FIELDS,
    SWEEP_ENGINES,
    SWEEP_MODES,
    WALL_CLOCK_FIELDS,
    ScenarioResult,
    classify_recovery,
    deterministic_cell_dict,
    measure_divergence_fields,
    run_scenario,
    sweep,
    write_scenarios_json,
)

__all__ = [
    "CrashPlan", "CrashPoint", "TornSpec", "LineSurvival",
    "FaultSpec", "MediaFault",
    "MECHANISM_CASES", "MechanismCase", "StepCostProfile",
    "mechanism_cases", "mechanism_step_seconds",
    "cg_step_profile", "mm_step_profile", "kv_step_profile",
    "xsbench_step_profile",
    "WORKLOADS", "Workload", "CGWorkload", "MMWorkload", "XSBenchWorkload",
    "KVWorkload", "KVProfile", "KV_PROFILES",
    "RecoveryResult", "FinalReport", "make_workload", "register_workload",
    "STRATEGIES", "ConsistencyStrategy", "NativeStrategy", "AdccStrategy",
    "UndoLogStrategy", "CheckpointStrategy", "ShadowSnapshotStrategy",
    "make_strategy", "register_strategy", "strategy_names",
    "AVG_STEP_JITTER_FLOOR", "DEFAULT_SWEEP_PLANS", "SWEEP_ENGINES",
    "SWEEP_MODES", "WALL_CLOCK_FIELDS", "FULL_RUN_FIELDS",
    "FORK_ONLY_FIELDS",
    "ScenarioResult", "classify_recovery", "deterministic_cell_dict",
    "measure_divergence_fields", "run_scenario", "sweep",
    "write_scenarios_json",
]
