"""Paper §III.B end to end: CG with algorithm-directed crash consistence.

Solves a sparse SPD system under the crash emulator, kills the run at
iteration 14, then recovers by backward-scanning the NVM image with the
two algorithm invariants (orthogonality p·q=0 and residual r=b-Az) and
resumes — comparing the large-problem case (loses ~1 iteration) against
the small-problem case (cache holds everything: restart from scratch).

    PYTHONPATH=src python examples/cg_crash_recovery.py
"""

import numpy as np

from repro.algorithms.cg import ADCC_CG, make_spd_system, plain_cg
from repro.core.nvm import NVMConfig


def demo(n: int, label: str) -> None:
    print(f"\n== {label}: n={n} "
          f"(working set ≈ {(4 * n * 8 * 16) / 1e6:.1f} MB vs 2 MB cache)")
    A, b = make_spd_system(n, nnz_per_row=8, seed=n)
    cg = ADCC_CG(A, b, iters=16, cfg=NVMConfig(cache_bytes=2 * 1024 * 1024))
    res = cg.run(crash_at_iter=14)
    z_ref = plain_cg(A, b, 16)
    print(f"   crash @ iter {res.crashed_at}; invariant scan accepted "
          f"iteration {res.restart_iter} "
          f"({res.iterations_lost} iteration(s) lost)")
    if res.recovery is not None:
        for j, reports in zip(range(res.crashed_at, -2, -1),
                              res.recovery.reports[:3]):
            line = ", ".join(f"{r.name}: {'OK' if r.ok else 'BAD'} "
                             f"({r.detail})" for r in reports)
            print(f"   iter {j}: {line}")
    err = float(np.max(np.abs(res.z - z_ref)))
    print(f"   resumed to completion; |z - z_ref|_max = {err:.2e} "
          f"({'CORRECT' if err < 1e-8 else 'WRONG'})")


def main() -> None:
    demo(65536, "large problem (paper: lose <= 1 iteration)")
    demo(1024, "small problem (paper: everything was cached -> restart)")


if __name__ == "__main__":
    main()
