"""Optimizers + distributed-optimization tricks (grad compression)."""

from .adamw import (AdamWState, adafactor_init, adafactor_update, adamw_init,
                    adamw_update, lr_schedule, make_optimizer)
from .compression import compress_decompress, init_error_state

__all__ = ["AdamWState", "adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "lr_schedule", "make_optimizer",
           "compress_decompress", "init_error_state"]
