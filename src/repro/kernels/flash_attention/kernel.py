"""Pallas TPU kernel: blockwise causal attention (flash-attention fwd).

Grid (B*H, S/bq, S/bk) with the KV dim innermost; VMEM scratch carries
the online-softmax state (f32 accumulator (bq, hd), running max m and
normalizer l) across KV blocks, so the (S, S) score matrix never touches
HBM — the structural fix for the memory-bound prefill cells in the
roofline table (llama3 prefill_32k: 17 GB of f32 logits per layer with
naive attention).

GQA without materializing the KV repeat: the K/V BlockSpec index maps
divide the batch*head grid coordinate by the group size G, so each
query-head block reads its KV head's block directly.

Fully-masked blocks contribute exactly zero via masked exp (m is clamped
to a finite floor so empty blocks cannot produce NaN through
exp(-inf - -inf)).

Forward-only: serving/prefill path. The training path keeps the jnp
attention (XLA autodiff); a custom-vjp flash backward is future work
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        logits = jnp.where(mask, logits, NEG_INF)
    else:
        mask = jnp.ones((bq, bk), jnp.bool_)

    m_prev = m_ref[...]                            # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # masked positions must contribute exactly 0 even when the whole
    # block is masked (m_new == NEG_INF would give exp(0) = 1 otherwise)
    p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           groups: int = 1, causal: bool = True,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, S, hd); k/v: (BH // groups, S, hd). S % bq == S % bk == 0.
    Returns (BH, S, hd) in q.dtype."""
    BH, S, hd = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    assert k.shape[0] * groups == BH, (q.shape, k.shape, groups)
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // groups, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
