"""ADCC trainer + launcher (``python -m repro.launch.train --arch ...``).

Per step the trainer:
  1. pulls batch t from the counter-based pipeline (pure function of t),
  2. runs the jitted train_step (params/opt sharded per partition rules),
  3. synchronously appends the few-KB checksum ledger record — the
     paper's "flush one cache line per iteration",
  4. every ``slot_every`` steps enqueues the heavy state to the async,
     fence-free slot writer (torn on crash, like cache-eviction residue).

On start it attempts ADCC recovery: ledger linearity-chain validation,
then newest-first slot scan with per-tensor checksum verification
(core/acc_state.py). Restores the data cursor + RNG with the accepted
step, making recovery bitwise-reproducible — asserted by the
crash/restart integration test.

Also includes the step-time straggler monitor (flags slow hosts for the
controller to replace — simulated single-host here, interface real).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, TrainConfig
from ..core.acc_state import (ChecksumLedger, LedgerRecord, flatten_checksums,
                              verify_state_against_record)
from ..core.slots import (AsyncSlotWriter, SlotStore, flatten_state,
                          unflatten_state)
from ..data.pipeline import SyntheticPipeline
from ..models.registry import build_model, get_config
from ..optim import init_error_state
from ..sharding.partition import make_rules
from .mesh import make_mesh, single_device_mesh
from .steps import build_train_step

__all__ = ["ADCCTrainer", "StragglerMonitor", "main"]


class StragglerMonitor:
    """Step-time outlier detection. At fleet scale each host reports its
    step wall-time; hosts persistently above ``threshold`` x median get
    flagged for hot-spare replacement. Single-host here, interface real."""

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: List[float] = []
        self.flagged_steps: List[int] = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        recent = self.times[-self.window:]
        if len(recent) >= 8:
            med = float(np.median(recent))
            if seconds > self.threshold * med:
                self.flagged_steps.append(step)
                return True
        return False


@dataclasses.dataclass
class TrainerResult:
    final_step: int
    losses: List[float]
    resumed_from: Optional[int]
    recovery_report: str
    step_seconds: List[float]


class ADCCTrainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, workdir: str, *,
                 batch: int = 8, seq: int = 64, mesh=None,
                 slot_every: int = 8, n_slots: int = 3,
                 mode: str = "adcc"):
        """mode: 'adcc' (paper technique) | 'sync' (traditional blocking
        checkpoint baseline) | 'none' (no fault tolerance)."""
        assert mode in ("adcc", "sync", "none")
        self.cfg, self.tcfg = cfg, tcfg
        self.workdir = workdir
        self.batch, self.seq = batch, seq
        self.slot_every, self.mode = slot_every, mode
        os.makedirs(workdir, exist_ok=True)

        self.mesh = mesh if mesh is not None else single_device_mesh()
        self.rules = make_rules(self.mesh, fsdp=tcfg.fsdp)
        self.api = build_model(cfg)
        self.pipeline = SyntheticPipeline(cfg, batch, seq, seed=tcfg.seed)
        sample = {k: jnp.asarray(v)
                  for k, v in self.pipeline.batch_at(0).items()}
        self.step_fn, self.shardings, self.opt_init = build_train_step(
            self.api, tcfg, self.rules, donate=False,
            batch_template=sample)
        self.ledger = ChecksumLedger(os.path.join(workdir, "ledger.jsonl"))
        self.store = SlotStore(os.path.join(workdir, "slots"), n_slots)
        self.writer = AsyncSlotWriter(self.store) if mode == "adcc" else None
        self.monitor = StragglerMonitor()
        self._crashed = False

    # -- recovery ---------------------------------------------------------------
    def _try_recover(self):
        """-> (params, opt_state, resume_step, report) or Nones."""
        recs = {r.step: r for r in self.ledger.validated_records()}
        if not recs:
            return None, None, 0, "no ledger"
        template_p, _ = self.api.abstract_init(jax.random.PRNGKey(0))
        for slot, step in self.store.slots_by_recency():
            rec = recs.get(step)
            if rec is None:
                continue
            flat = self.store.read_slot(slot)
            if flat is None:
                continue
            try:
                state = unflatten_state(
                    {"params": template_p,
                     "opt": jax.eval_shape(self.opt_init, template_p)}, flat)
            except (KeyError, ValueError):
                continue  # torn slot: missing/short leaves
            ok, bad = verify_state_against_record(
                state["params"], state["opt"], rec)
            if ok:
                return (state["params"], state["opt"], step + 1,
                        f"slot {slot} @ step {step} verified")
        newest = max(recs)
        return None, None, 0, (f"no slot verified (ledger reaches step "
                               f"{newest}); restart from scratch")

    # -- main loop ------------------------------------------------------------------
    def run(self, steps: int, crash_at_step: Optional[int] = None,
            log_every: int = 10) -> TrainerResult:
        params, opt_state, start, report = self._try_recover()
        resumed_from = start - 1 if start > 0 else None
        if params is None:
            params, _ = self.api.init(jax.random.PRNGKey(self.tcfg.seed))
            opt_state = self.opt_init(params)
        else:
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
        err_state = init_error_state(params)

        losses: List[float] = []
        times: List[float] = []
        t = start
        while t < steps:
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch_at(t).items()}
            rng = jax.random.fold_in(jax.random.PRNGKey(self.tcfg.seed), t)
            params, opt_state, err_state, metrics, cks = self.step_fn(
                params, opt_state, err_state, batch, rng)
            loss = float(metrics["loss"])
            losses.append(loss)

            # (3) synchronous tiny ledger write — the "one cache line"
            if self.mode == "adcc":
                self.ledger.append(LedgerRecord(
                    step=t, rng_seed=self.tcfg.seed,
                    cursor=[self.tcfg.seed, t + 1, 0],
                    cks_params=flatten_checksums(cks["params"]),
                    cks_opt=flatten_checksums(cks["opt"]),
                    cks_updates=flatten_checksums(cks["updates"]),
                    loss=loss))
                # (4) async fence-free heavy-state write
                if (t + 1) % self.slot_every == 0:
                    self.writer.submit(t, flatten_state(
                        {"params": params, "opt": opt_state}))
            elif self.mode == "sync" and (t + 1) % self.slot_every == 0:
                # traditional checkpoint: blocking full copy + ledger
                self.ledger.append(LedgerRecord(
                    step=t, rng_seed=self.tcfg.seed,
                    cursor=[self.tcfg.seed, t + 1, 0],
                    cks_params=flatten_checksums(cks["params"]),
                    cks_opt=flatten_checksums(cks["opt"]),
                    cks_updates=flatten_checksums(cks["updates"]),
                    loss=loss))
                self.store.write_slot(
                    self.store.slot_for_step((t + 1) // self.slot_every),
                    t, flatten_state({"params": params, "opt": opt_state}))

            dt_step = time.perf_counter() - t0
            times.append(dt_step)
            self.monitor.record(t, dt_step)
            if log_every and t % log_every == 0:
                print(f"step {t:5d} loss {loss:.4f} "
                      f"({dt_step*1e3:.0f} ms)", flush=True)

            if crash_at_step is not None and t == crash_at_step:
                self.crash()
                return TrainerResult(t, losses, resumed_from, report, times)
            t += 1

        if self.writer is not None:
            self.writer.drain()
        self.ledger.close()
        self._final_params = params  # for tests
        self._final_opt = opt_state
        return TrainerResult(steps - 1, losses, resumed_from, report, times)

    def crash(self) -> None:
        """Simulated node failure: in-flight async writes torn, process
        state dropped. (Real deployment: the job simply dies.)"""
        if self.writer is not None:
            self.writer.crash()
        self.ledger.close()
        self._crashed = True


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="ADCC trainer")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-scale config (CPU)")
    ap.add_argument("--mode", default="adcc",
                    choices=["adcc", "sync", "none"])
    ap.add_argument("--slot-every", type=int, default=8)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(optimizer=args.optimizer, remat=args.remat,
                       grad_compression=args.grad_compression)
    trainer = ADCCTrainer(cfg, tcfg, args.workdir, batch=args.batch,
                          seq=args.seq, slot_every=args.slot_every,
                          mode=args.mode)
    res = trainer.run(args.steps, crash_at_step=args.crash_at)
    print(f"done: final step {res.final_step}, resumed_from="
          f"{res.resumed_from}, recovery: {res.recovery_report}")
    if res.losses:
        print(f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
