"""The paper's three case studies, runnable on the crash emulator:

  cg        — Conjugate Gradient with versioned arrays + invariant recovery (§III.B)
  mm_abft   — ABFT matrix multiplication, two-loop decomposition (§III.C)
  xsbench   — Monte-Carlo cross-section lookup with selective flushing (§III.D)
"""

from . import cg, mm_abft, xsbench  # noqa: F401
