"""jit'd wrapper: checksum verification via the tile-sums Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..abft_matmul.ops import on_tpu
from .kernel import tile_sums_pallas

__all__ = ["verify_checksums", "tile_sums", "tile_sums_batch"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pick_block(dim: int, default: int = 128) -> int:
    for cand in (default, 64, 32, 16, 8):
        if dim >= cand:
            return cand
    return 8


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_sums(x: jax.Array, *, interpret: bool):
    """(row_sums (m,), col_sums (n,)) of x via one Pallas HBM pass."""
    m, n = x.shape
    bm, bn = _pick_block(m), _pick_block(n)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    x_p = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    rowp, colp = tile_sums_pallas(x_p, bm=bm, bn=bn, interpret=interpret)
    return jnp.sum(rowp, axis=1)[:m], jnp.sum(colp, axis=0)[:n]


@functools.partial(jax.jit,
                   static_argnames=("acc_dtype", "use_pallas", "interpret"))
def _tile_sums_batch_impl(x, *, acc_dtype, use_pallas, interpret):
    B, m, n = x.shape
    if not use_pallas:
        xa = x.astype(acc_dtype)
        return jnp.sum(xa, axis=2), jnp.sum(xa, axis=1)
    bm, bn = _pick_block(m), _pick_block(n)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    x_p = jnp.pad(x, ((0, 0), (0, mp - m), (0, np_ - n)))

    def one(xi):
        rowp, colp = tile_sums_pallas(
            xi, bm=bm, bn=bn, acc_dtype=acc_dtype, interpret=interpret)
        return jnp.sum(rowp, axis=1)[:m], jnp.sum(colp, axis=0)[:n]

    return jax.vmap(one)(x_p)


def tile_sums_batch(x: jax.Array, *, acc_dtype=jnp.float32,
                    use_pallas: bool | None = None,
                    interpret: bool = False):
    """Batched row/col sums of a stack of matrices x (B, m, n).

    Returns (row_sums (B, m), col_sums (B, n)) accumulated in
    ``acc_dtype``. The batched sweep engine's ABFT chunk screen calls
    this once over every examined chunk image of a whole sweep matrix.

    ``use_pallas=None`` routes through the Pallas kernel on TPU and
    plain XLA reductions elsewhere (Pallas interpret mode is far too
    slow for the CPU hot path; equivalence of the two routes is pinned
    by tests at small shapes with ``use_pallas=True, interpret=True``).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    return _tile_sums_batch_impl(
        x, acc_dtype=jnp.dtype(acc_dtype), use_pallas=bool(use_pallas),
        interpret=bool(interpret))


def verify_checksums(cf: jax.Array, rtol: float = 1e-6, atol: float = 1e-4,
                     *, interpret: bool | None = None):
    """Kernel-backed verdict for a full-checksum matrix cf (m+1, n+1).
    Returns (ok, row_resid (m,), col_resid (n,)) like ref.verify_ref."""
    if interpret is None:
        interpret = not on_tpu()
    data = cf[:-1, :-1]
    row_sums, col_sums = tile_sums(data, interpret=interpret)
    row_resid = cf[:-1, -1].astype(jnp.float32) - row_sums
    col_resid = cf[-1, :-1].astype(jnp.float32) - col_sums
    scale = jnp.maximum(jnp.max(jnp.abs(cf)).astype(jnp.float32), 1.0)
    tol = atol + rtol * scale
    ok = (jnp.max(jnp.abs(row_resid)) <= tol) & (jnp.max(jnp.abs(col_resid)) <= tol)
    return ok, row_resid, col_resid
