"""Device-resident forward pass: jax-jit cache transitions over the
vectorized backend's state.

``DeviceBackend`` lifts the NVM-emulation *forward pass* — the write
coalescing, dirty bitmaps/stamps, and traffic accounting that every
golden prefix pays per step — onto jit-compiled kernels
(:func:`repro.core.backends.batched.cache_op_update` /
:func:`queue_validity`). It subclasses :class:`VectorizedBackend` and
overrides exactly two inner loops:

* ``_op``: a span operation whose entry range is large and provably
  eviction-free (the streaming regime — CSR matvec rows, MC grids, KV
  value-log extents under an adequate cache) is computed as one fused
  device launch producing the new bitmaps/stamps, the miss mask, and
  the miss count; the host then commits the results, queue-appends in
  the reference order, and charges traffic once. The launch is
  *speculative*: nothing is mutated until the no-eviction precondition
  (``occupancy + misses * weight <= capacity``) is confirmed, so any op
  that could evict falls back to the parent's host path untouched —
  byte/stat-identity with :class:`VectorizedBackend` is by
  construction, not by reimplementation.
* ``_validity``: queue-slot validation for large single-region blocks
  (the eviction/compaction/crash-order scan) as one gather launch.

Everything else — batched eviction, flush, drain, ``crash(survival)``
line- and word-granularity torn paths, ``snapshot()/restore()``, media
faults via ``corrupt_image_words`` — is inherited unchanged, so the
fork ladder, snapshot tiering, and fault injection run on top of it
with the established cross-backend byte-identity contracts intact
(gated by tests/test_backend_equivalence.py).

The kernels are plain jnp under ``enable_x64``: per the accelerator
guide, these transitions are memory-bound elementwise/gather ops that
XLA already fuses into single kernels — a hand-written Pallas grid
would add block-spec bookkeeping for no arithmetic win (unlike the
ABFT/CG launches in ``repro.kernels``, which are MXU-shaped). Shapes
are padded to powers of two so jit compiles log-many variants.

Without jax (or below :data:`DeviceBackend.MIN_DEVICE_ENTRIES`, where
dispatch overhead dominates) every path falls back to the parent, so
``REPRO_NVM_BACKEND=device`` is always safe to select.

Worker-pool caveat: the first device op instantiates an XLA backend in
this process; forking after that deadlocks children's device math. The
sweep driver switches its pool to spawn-start whenever
``jax_runtime_live()`` reports a live runtime (see
``repro.scenarios.driver.sweep``).
"""

from __future__ import annotations

import numpy as np

from . import batched as _dev
from .vectorized import VectorizedBackend

__all__ = ["DeviceBackend"]


class DeviceBackend(VectorizedBackend):
    """Vectorized cache emulation with jit-compiled bulk transitions."""

    kind = "device"

    # smallest entry count routed to the device: below this the jit
    # dispatch overhead exceeds the fused-transition win (tests lower it
    # to force every span op through the device kernels)
    MIN_DEVICE_ENTRIES = 2048

    def _op(self, name: str, lo: int, hi: int, is_write: bool) -> None:
        r = self._regions[name]
        if hi <= lo:
            return
        e_lo = lo // r.epe
        e_hi = (hi - 1) // r.epe + 1
        m = e_hi - e_lo
        if m < self.MIN_DEVICE_ENTRIES or not _dev.have_jax():
            super()._op(name, lo, hi, is_write)
            return
        sl = slice(e_lo, e_hi)
        t0 = self._clock
        fifo = self.cfg.replacement == "fifo"
        new_p, new_d, new_s, miss, n_miss = _dev.cache_op_update(
            r.present[sl], r.dirty[sl], r.stamp[sl], t0, is_write, fifo)
        if self._weight_used + n_miss * r.w > self.capacity_lines:
            # eviction pressure: nothing mutated yet — the parent's
            # hit/miss-run walk with interleaved queue pops is the
            # reference-exact path
            super()._op(name, lo, hi, is_write)
            return
        self._clock = t0 + m
        r.present[sl] = new_p
        r.dirty[sl] = new_d
        r.stamp[sl] = new_s
        ents = np.arange(e_lo, e_hi, dtype=np.int64)
        stamps = t0 + np.arange(m, dtype=np.int64)
        if fifo:
            # FIFO hits keep their queue slot; only misses enqueue
            self._q_append(r.rid, ents[miss], stamps[miss])
        else:
            self._q_append(r.rid, ents, stamps)
        self._weight_used += n_miss * r.w
        self.store.stats.charge_batch(
            self.cfg, write_bytes=0,
            read_bytes=0 if is_write else n_miss * r.epe * r.itemsize,
            evict_lines=0)

    def _validity(self, rids: np.ndarray, ents: np.ndarray,
                  stamps: np.ndarray):
        n = rids.shape[0]
        if n < self.MIN_DEVICE_ENTRIES or not _dev.have_jax():
            return super()._validity(rids, ents, stamps)
        rid0 = int(rids[0])
        if not np.all(rids == rid0):
            return super()._validity(rids, ents, stamps)
        r = self._by_rid.get(rid0)
        if r is None:  # dropped region: every slot is stale
            return (np.zeros(n, dtype=bool), np.zeros(n, dtype=np.int64))
        return _dev.queue_validity(r.present, r.stamp, ents, stamps, r.w)
