"""Minimal, deterministic stand-in for the ``hypothesis`` package.

The test suite uses a small slice of hypothesis (``given``/``settings``
plus the ``integers``/``floats``/``booleans``/``lists``/``tuples``
strategies). When the real package is unavailable, :func:`install`
registers drop-in modules under ``sys.modules`` so
``from hypothesis import given, settings, strategies as st`` keeps
working. Examples are drawn from a numpy Generator seeded by the test's
qualified name, so runs are reproducible and failures are replayable.

This is *not* hypothesis: there is no shrinking and no coverage-guided
search — just ``max_examples`` random examples per test.
"""

from __future__ import annotations

import sys
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, allow_nan: bool = False,
           allow_infinity: bool = False) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]
    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))


def sampled_from(options) -> _Strategy:
    seq = list(options)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def given(**strategies):
    def decorate(fn):
        def runner(*args):
            n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.adler32(fn.__qualname__.encode())
            for example in range(n):
                rng = np.random.default_rng((seed, example))
                kwargs = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example #{example} for "
                        f"{fn.__qualname__}: {kwargs!r}") from exc

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register the shim as ``hypothesis`` if the real one is missing."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "lists", "tuples",
                 "sampled_from"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
