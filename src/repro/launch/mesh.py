"""Production mesh builders (assignment-mandated signatures).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "single_device_mesh", "axis_type_kwargs"]

# jax >= 0.5 exposes jax.sharding.AxisType and expects axis_types=;
# 0.4.x has neither, and jax.make_mesh rejects the kwarg there.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwargs for ``jax.make_mesh``, or ``{}`` on jax 0.4.x."""
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_type_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes, **axis_type_kwargs(len(axes)))


def single_device_mesh(axes: Tuple[str, ...] = ("data", "model")):
    """1-device mesh with the production axis names — lets CPU tests run
    the exact production code path (shard_map, constraints) unchanged."""
    return make_mesh((1,) * len(axes), axes)
