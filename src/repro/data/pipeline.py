"""Deterministic, resumable synthetic data pipeline.

The pipeline is a pure function of (seed, step): batch t is generated
counter-based, so persisting just the *cursor* (one integer — the
paper's "flush the cache line containing i") makes data delivery exactly
resumable after a crash: a restarted run replays the identical token
stream with no out-of-band state. This is the data-side half of the
bitwise-reproducible-recovery guarantee the integration tests assert.

Content: Zipf-distributed token ids with injected copy/repeat structure
so small models actually have something learnable (loss visibly drops
in examples/train_e2e.py), labels = next-token shift.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..configs.base import ModelConfig

__all__ = ["PipelineState", "SyntheticPipeline"]


@dataclasses.dataclass
class PipelineState:
    """The entire pipeline state — 3 integers. Tiny by construction."""

    seed: int
    step: int
    epoch: int = 0

    def as_array(self) -> np.ndarray:
        return np.array([self.seed, self.step, self.epoch], np.int64)

    @classmethod
    def from_array(cls, arr) -> "PipelineState":
        return cls(seed=int(arr[0]), step=int(arr[1]), epoch=int(arr[2]))


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.state = PipelineState(seed=seed, step=0)
        self.host_id = host_id
        self.n_hosts = n_hosts
        # Zipf-ish unigram distribution over the vocab
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    # -- counter-based batch generation ---------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        # independent stream per (seed, step, host): SeedSequence spawning
        ss = np.random.SeedSequence(
            entropy=self.state.seed,
            spawn_key=(step, self.host_id))
        return np.random.default_rng(ss)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step — the resumability property."""
        rng = self._rng_for(step)
        B = self.batch // self.n_hosts
        S = self.seq
        tokens = rng.choice(self.cfg.vocab_size, size=(B, S + 1),
                            p=self._probs).astype(np.int32)
        # inject copy structure: second half repeats the first half for a
        # random subset of rows (learnable signal)
        copy_rows = rng.random(B) < 0.5
        half = (S + 1) // 2
        tokens[copy_rows, half:2 * half] = tokens[copy_rows, :half]
        return {"tokens": tokens[:, :-1],
                "labels": tokens[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # -- checkpoint integration --------------------------------------------------
    def cursor(self) -> np.ndarray:
        return self.state.as_array()

    def restore(self, arr) -> None:
        self.state = PipelineState.from_array(arr)
