"""Modeled per-step mechanism costs — the single source for the paper's
seven-mechanism runtime comparisons (Figs. 4/8/13).

Each workload reduces its persistence behaviour to a
:class:`StepCostProfile` (bytes checkpointed / logged / ADCC-flushed per
persist event); :func:`mechanism_step_seconds` turns (strategy, profile,
config) into modeled seconds per persist event using the paper's §III.A
bandwidth model. The runtime figures are then pure matrices:
``for case in mechanism_cases(): (native + case.step_seconds(p)) / native``.

Cost formulas (per persist event; ``line`` = ``cfg.line_bytes``):

  none                0
  checkpoint_hdd      hdd_latency + ckpt_bytes / hdd_bw
  checkpoint_nvm      ckpt_bytes / write_bw + ckpt_lines * flush_latency
  checkpoint_nvm_dram ... + dram_cache / dram_bw + dram_cache / write_bw
  undo_log            2 * (log_bytes / write_bw + log_lines * flush_latency)
                      (old-value copy + fence, then commit writeback + fence)
  adcc                adcc_bytes / write_bw + adcc_lines * flush_latency
  shadow_snapshot     shadow_bytes / write_bw + shadow_lines * flush_latency
                      + 8 / write_bw + flush_latency
                      (copy-on-write copies only regions dirtied since the
                      previous snapshot, then one persisted 8-byte
                      root-pointer flip; shadow_bytes defaults to
                      ckpt_bytes when a workload provides no COW estimate)
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from ..core.nvm import NVMConfig

__all__ = [
    "StepCostProfile",
    "MechanismCase",
    "MECHANISM_CASES",
    "mechanism_cases",
    "mechanism_step_seconds",
    "persist_events",
    "survivor_writeback_seconds",
    "cg_step_profile",
    "mm_step_profile",
    "xsbench_step_profile",
    "kv_step_profile",
]


def survivor_writeback_seconds(nbytes: int, cfg: NVMConfig) -> float:
    """Modeled NVM-write time of the dirty-line writebacks a torn crash
    completed before power loss (``traffic.torn_bytes_persisted``).

    Never charged to a run's ``modeled_seconds`` — the program did not
    wait for in-flight evictions — but it bounds the plausibility of a
    survival fraction: persisting those bytes must fit the power-fail
    hold-up window, and fig_torn reports this as per-cell context.
    """
    return nbytes / cfg.write_bw


@dataclasses.dataclass(frozen=True)
class StepCostProfile:
    """Per-persist-event byte/line counts of one workload."""

    ckpt_bytes: int                  # bytes a checkpoint copies
    log_bytes: int                   # bytes an undo-log tx copies (dirtied)
    adcc_bytes: int                  # bytes ADCC flushes
    adcc_lines: Optional[int] = None   # CLFLUSH issues (default bytes/line)
    ckpt_lines: Optional[int] = None
    log_lines: Optional[int] = None
    interval_steps: int = 1          # steps between persist events
    hdd_latency_s: float = 0.0       # per-checkpoint seek cost (tiny payloads)
    # bytes a shadow snapshot copies per persist event (regions dirtied
    # since the previous snapshot — the copy-on-write saving over
    # ckpt_bytes). None => no estimate, fall back to ckpt_bytes.
    shadow_bytes: Optional[int] = None
    shadow_lines: Optional[int] = None


def _lines(bytes_: int, explicit: Optional[int], line: int) -> int:
    return explicit if explicit is not None else max(1, math.ceil(bytes_ / line))


def mechanism_step_seconds(strategy: str, profile: StepCostProfile,
                           cfg: NVMConfig) -> float:
    """Modeled mechanism seconds per persist event."""
    line = cfg.line_bytes
    if strategy in ("none", "native"):
        return 0.0
    if strategy == "checkpoint_hdd":
        return profile.hdd_latency_s + profile.ckpt_bytes / cfg.hdd_bw
    if strategy in ("checkpoint_nvm", "checkpoint_nvm_dram"):
        t = (profile.ckpt_bytes / cfg.write_bw
             + _lines(profile.ckpt_bytes, profile.ckpt_lines, line)
             * cfg.flush_latency)
        if strategy == "checkpoint_nvm_dram":
            t += cfg.dram_cache_bytes / cfg.dram_bw
            t += cfg.dram_cache_bytes / cfg.write_bw
        return t
    if strategy == "undo_log":
        nlines = _lines(profile.log_bytes, profile.log_lines, line)
        return 2 * (profile.log_bytes / cfg.write_bw
                    + nlines * cfg.flush_latency)
    if strategy == "adcc":
        nlines = _lines(profile.adcc_bytes, profile.adcc_lines, line)
        return profile.adcc_bytes / cfg.write_bw + nlines * cfg.flush_latency
    if strategy == "shadow_snapshot":
        nb = (profile.shadow_bytes if profile.shadow_bytes is not None
              else profile.ckpt_bytes)
        nl = _lines(nb, profile.shadow_lines, line)
        # COW copy of the dirtied regions + one persisted root-pointer flip
        return (nb / cfg.write_bw + nl * cfg.flush_latency
                + 8 / cfg.write_bw + cfg.flush_latency)
    raise ValueError(f"unknown strategy {strategy!r}")


def persist_events(steps_run: int, strategy_interval: int,
                   profile: StepCostProfile, wants_adcc: bool) -> int:
    """How many persist events ``steps_run`` executed steps triggered.

    Traditional mechanisms persist every ``strategy_interval`` steps;
    ADCC's cadence is algorithm-directed, carried by the profile's
    ``interval_steps`` (e.g. XSBench's selective flush interval). The
    single source for a cell's modeled mechanism overhead — both the
    full-execution path and mode="measure" (which never runs the tail,
    so its overhead must come from this model, not from execution)
    charge ``events * mechanism_step_seconds(...)``.
    """
    interval = strategy_interval * (profile.interval_steps
                                    if wants_adcc else 1)
    return steps_run // max(1, interval)


@dataclasses.dataclass(frozen=True)
class MechanismCase:
    """One column of the paper's 7-mechanism comparison."""

    name: str          # figure row label, e.g. "adcc_nvm_dram"
    strategy: str      # registry key, e.g. "adcc"
    nvm_dram: bool     # heterogeneous NVM/DRAM system vs NVM-only

    def config(self, **overrides) -> NVMConfig:
        return NVMConfig(nvm_same_as_dram=not self.nvm_dram, **overrides)

    def step_seconds(self, profile: StepCostProfile,
                     cfg: Optional[NVMConfig] = None) -> float:
        return mechanism_step_seconds(self.strategy, profile,
                                      cfg or self.config())


MECHANISM_CASES: List[MechanismCase] = [
    MechanismCase("native", "none", nvm_dram=False),
    MechanismCase("ckpt_hdd", "checkpoint_hdd", nvm_dram=False),
    MechanismCase("ckpt_nvm_only", "checkpoint_nvm", nvm_dram=False),
    MechanismCase("ckpt_nvm_dram", "checkpoint_nvm_dram", nvm_dram=True),
    MechanismCase("pmem_undo", "undo_log", nvm_dram=False),
    MechanismCase("adcc_nvm_only", "adcc", nvm_dram=False),
    MechanismCase("adcc_nvm_dram", "adcc", nvm_dram=True),
]


def mechanism_cases() -> List[MechanismCase]:
    """The paper's seven crash-consistence mechanisms (§III.A cases 1-7)."""
    return list(MECHANISM_CASES)


# -- per-workload profiles -----------------------------------------------------

def cg_step_profile(n: int, line_bytes: int = 64) -> StepCostProfile:
    """Per CG iteration: checkpoint copies p/q/r/z, undo-log dirties
    p/r/z, ADCC flushes the one cache line holding the counter."""
    vec = n * 8
    return StepCostProfile(ckpt_bytes=4 * vec, log_bytes=3 * vec,
                           adcc_bytes=line_bytes, adcc_lines=1)


def mm_step_profile(n: int, line_bytes: int = 64) -> StepCostProfile:
    """Per submatrix multiplication: checkpoint/undo-log move the whole
    (n+1)^2 C_f; ADCC flushes one checksum row + one checksum column."""
    cf = (n + 1) * (n + 1) * 8
    cs = 2 * (n + 1) * 8
    return StepCostProfile(ckpt_bytes=cf, log_bytes=cf, adcc_bytes=cs,
                           adcc_lines=max(1, cs // line_bytes))


def kv_step_profile(index_bytes: int, meta_bytes: int, extent_bytes: int,
                    n_extents: int, avg_value_bytes: int,
                    line_bytes: int = 64) -> StepCostProfile:
    """Per KV request: a checkpoint copies the whole store (index + meta
    + every value extent); the undo log dirties the touched slot pair,
    the appended value span, and the meta pair; ADCC-style selective
    persistence flushes exactly the request's value span + slot line +
    meta line; a shadow snapshot copies only the regions dirtied since
    the previous snapshot — in steady state the index, the meta pair,
    and the one extent the append head sits in (COW shares the rest)."""
    footprint = index_bytes + meta_bytes + n_extents * extent_bytes
    touched = 2 * line_bytes + avg_value_bytes + meta_bytes
    adcc = avg_value_bytes + 2 * line_bytes
    shadow = index_bytes + meta_bytes + extent_bytes
    return StepCostProfile(
        ckpt_bytes=footprint, log_bytes=touched, adcc_bytes=adcc,
        adcc_lines=max(1, math.ceil(avg_value_bytes / line_bytes)) + 2,
        shadow_bytes=shadow,
        hdd_latency_s=5e-3)


def xsbench_step_profile(line_bytes: int = 64, interval_steps: int = 1,
                         hdd_latency_s: float = 5e-3) -> StepCostProfile:
    """Per flush interval: the persisted state is macro_xs_vector + five
    counters + the loop index (~13 distinct cache lines; paper Fig. 13)."""
    state_bytes = (5 + 5 + 1) * 8
    nlines = max(1, state_bytes // line_bytes) + 10   # distinct lines
    return StepCostProfile(
        ckpt_bytes=state_bytes, ckpt_lines=nlines,
        log_bytes=nlines * line_bytes, log_lines=nlines,
        adcc_bytes=nlines * line_bytes, adcc_lines=nlines,
        interval_steps=interval_steps, hdd_latency_s=hdd_latency_s)
