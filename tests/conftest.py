"""Shared test setup.

* Makes ``src/`` importable so a bare ``pytest`` works without
  PYTHONPATH gymnastics.
* Installs the deterministic hypothesis stand-in when the real
  ``hypothesis`` package is not installed in the image (the property
  tests only use a small strategy subset — see repro._compat).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro._compat.hypothesis_shim import install as _install_hypothesis_shim

_install_hypothesis_shim()
