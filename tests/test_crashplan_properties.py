"""Property-based tests for the CrashPlan resolution contract.

Uses ``hypothesis`` when installed; otherwise the deterministic
stand-in from ``repro._compat`` (installed by tests/conftest.py) draws
seeded random examples with the same API — either way the properties
are replayable.

The contract under test (see CrashPlan.resolve):

  * every resolved crash step lies in ``[0, n_steps)``;
  * resolved steps are strictly increasing — sorted, deduplicated —
    for every plan kind, including seeded ``random`` batches and the
    dense ``at_every_step`` plan;
  * resolution is pure: the same plan against the same step/phase
    layout yields the same points, every time;
  * seeded random batches are engine- and mode-invariant end to end:
    ``sweep`` produces the same deterministic cells under
    engine="fork", engine="rerun", and mode="measure" (on the fields a
    measured cell defines).
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.nvm import NVMConfig
from repro.scenarios import (CrashPlan, TornSpec, deterministic_cell_dict,
                             measure_divergence_fields, sweep)

SMALL = NVMConfig(cache_bytes=256 * 1024)


class _StubWorkload:
    """The minimal surface ``CrashPlan.resolve`` grounds against: a
    step count, a phase layout, and a name. Keeps the plan-contract
    properties decoupled from (and much faster than) real workloads."""

    name = "stub"

    def __init__(self, n_steps: int, phases=None):
        self._n = int(n_steps)
        self._phases = phases if phases is not None \
            else {"main": range(self._n)}

    @property
    def n_steps(self) -> int:
        return self._n

    def phases(self):
        return dict(self._phases)


def _split_phases(n):
    head = range(0, (n + 1) // 2)
    return {"head": head, "tail": range(len(head), n)}


def _build_plan(kind, n, raw_step, frac, count, seed, torn):
    if kind == "none":
        return CrashPlan.no_crash()
    if kind == "step":
        return CrashPlan.at_step(raw_step % n, torn)
    if kind == "phase":
        head = _split_phases(n)["head"]
        return CrashPlan.at_phase("head", raw_step % len(head), torn)
    if kind == "fraction":
        return CrashPlan.at_fraction(frac, torn)
    if kind == "random":
        return CrashPlan.random(count=min(count, n), seed=seed, torn=torn)
    return CrashPlan.at_every_step(torn)


@given(kind=st.sampled_from(["none", "step", "phase", "fraction",
                             "random", "every"]),
       n=st.integers(1, 48), raw_step=st.integers(0, 1000),
       frac=st.floats(0.0, 1.0), count=st.integers(1, 9),
       seed=st.integers(0, 2**16), torn=st.booleans())
@settings(max_examples=60, deadline=None)
def test_resolved_points_sorted_dedup_in_range(kind, n, raw_step, frac,
                                               count, seed, torn):
    wl = _StubWorkload(n, _split_phases(n))
    plan = _build_plan(kind, n, raw_step, frac, count, seed, torn)
    points = plan.resolve(wl)
    if kind == "none":
        assert [p.step for p in points] == [None]
        return
    steps = [p.step for p in points]
    assert all(0 <= s < n for s in steps)
    assert steps == sorted(set(steps)), (kind, steps)
    assert all(p.torn == torn for p in points)
    # purity: resolving again — or against another workload with the
    # same layout — yields identical points
    again = plan.resolve(_StubWorkload(n, _split_phases(n)))
    assert [(p.step, p.torn) for p in again] == \
        [(p.step, p.torn) for p in points]


@given(n=st.integers(1, 64), frac=st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_at_fraction_stays_in_step_range(n, frac):
    (pt,) = CrashPlan.at_fraction(frac).resolve(_StubWorkload(n))
    assert 0 <= pt.step < n
    # endpoints pin to the first/last step
    assert CrashPlan.at_fraction(0.0).resolve(_StubWorkload(n))[0].step == 0
    assert CrashPlan.at_fraction(1.0).resolve(
        _StubWorkload(n))[0].step == n - 1


@given(count=st.integers(1, 10), seed=st.integers(0, 2**16),
       n=st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_random_batches_are_reproducible(count, seed, n):
    wl = _StubWorkload(n)
    plan = CrashPlan.random(count=min(count, n), seed=seed)
    a = [p.step for p in plan.resolve(wl)]
    b = [p.step for p in plan.resolve(wl)]
    assert a == b
    assert len(a) == len(set(a)) == min(count, n)


@given(count=st.integers(1, 3), seed=st.integers(0, 64),
       torn=st.booleans())
@settings(max_examples=4, deadline=None)
def test_random_batches_engine_and_mode_invariant(count, seed, torn):
    """fork == rerun == measure (where fields overlap) for seeded
    random crash batches on a real workload."""
    plan = CrashPlan.random(count=count, seed=seed, torn=torn)
    kw = dict(workloads=(("cg", {"n": 128, "iters": 6, "seed": 0}),),
              strategies=("checkpoint_nvm@2",), plans=(plan,), cfg=SMALL)
    fork = sweep(engine="fork", **kw)
    rerun = sweep(engine="rerun", **kw)
    measure = sweep(engine="fork", mode="measure", **kw)
    assert [deterministic_cell_dict(c) for c in fork] == \
        [deterministic_cell_dict(c) for c in rerun]
    assert len(measure) == len(fork) == count
    for m, f in zip(measure, fork):
        assert measure_divergence_fields(m, f) == []
    steps = [c.crash_step for c in fork]
    assert steps == sorted(set(steps))


@given(kind=st.sampled_from(["step", "phase", "fraction", "random",
                             "every"]),
       n=st.integers(1, 32), raw_step=st.integers(0, 1000),
       frac=st.floats(0.0, 1.0), count=st.integers(1, 6),
       seed=st.integers(0, 2**16),
       t_frac=st.floats(0.0, 1.0), t_seed=st.integers(0, 2**16),
       t_mode=st.sampled_from(["random", "eviction"]),
       samples=st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_tornspec_resolution_is_reproducible_and_sample_expanded(
        kind, n, raw_step, frac, count, seed, t_frac, t_seed, t_mode,
        samples):
    """The TornSpec extension of the resolution contract: every base
    step expands into exactly ``samples`` points with derived seeds
    t_seed..t_seed+samples-1, steps stay sorted (non-decreasing, each
    repeated ``samples`` times), every point carries torn=True and its
    own LineSurvival, and resolution remains pure."""
    spec = TornSpec(fraction=t_frac, seed=t_seed, mode=t_mode,
                    samples=samples)
    wl = _StubWorkload(n, _split_phases(n))
    plan = _build_plan(kind, n, raw_step, frac, count, seed, spec)
    points = plan.resolve(wl)
    base_steps = sorted(set(p.step for p in points))
    assert all(0 <= s < n for s in base_steps)
    assert [p.step for p in points] == \
        [s for s in base_steps for _ in range(samples)]
    for p in points:
        assert p.torn and p.survival is not None
        assert p.survival.fraction == t_frac and p.survival.mode == t_mode
    for s in base_steps:
        seeds = [p.survival.seed for p in points if p.step == s]
        assert seeds == list(range(t_seed, t_seed + samples))
    again = plan.resolve(_StubWorkload(n, _split_phases(n)))
    assert [(p.step, p.survival) for p in again] == \
        [(p.step, p.survival) for p in points]
    # the plan key embeds the spec; per-point keys embed derived seeds
    assert f":torn[{spec.describe()}]" in plan.describe()
    assert len({p.describe() for p in points}) == len(base_steps) * samples


@given(t_frac=st.floats(0.0, 1.0), t_seed=st.integers(0, 256),
       t_mode=st.sampled_from(["random", "eviction"]))
@settings(max_examples=3, deadline=None)
def test_torn_survival_cells_engine_and_mode_invariant(t_frac, t_seed,
                                                       t_mode):
    """fork == rerun == measure (where fields overlap) for seeded
    line-survival torn crashes on a real workload."""
    spec = TornSpec(fraction=t_frac, seed=t_seed, mode=t_mode, samples=2)
    plan = CrashPlan.random(count=2, seed=5, torn=spec)
    kw = dict(workloads=(("cg", {"n": 128, "iters": 6, "seed": 0}),),
              strategies=("undo_log@2",), plans=(plan,), cfg=SMALL)
    fork = sweep(engine="fork", **kw)
    rerun = sweep(engine="rerun", **kw)
    measure = sweep(engine="fork", mode="measure", **kw)
    assert [deterministic_cell_dict(c) for c in fork] == \
        [deterministic_cell_dict(c) for c in rerun]
    assert len(measure) == len(fork) == 4   # 2 steps x 2 samples
    for m, f in zip(measure, fork):
        assert measure_divergence_fields(m, f) == []
    assert len({(c.crash_step, c.torn_survival) for c in fork}) == 4


def test_invalid_plan_parameters_raise():
    with pytest.raises(ValueError):
        CrashPlan.at_step(-1)
    with pytest.raises(ValueError):
        CrashPlan.at_fraction(1.5)
    with pytest.raises(ValueError):
        CrashPlan.random(count=0)


def test_ungroundable_plans_raise_not_clamp():
    wl = _StubWorkload(4)
    with pytest.raises(ValueError):
        CrashPlan.at_step(4).resolve(wl)
    with pytest.raises(ValueError):
        CrashPlan.random(count=5, seed=0).resolve(wl)
    with pytest.raises(ValueError):
        CrashPlan.at_phase("loop2", 0).resolve(wl)


# ---------------------------------------------------------------------------
# KV serving-class properties (durability/atomicity audit contract)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**16), t_seed=st.integers(0, 2**8),
       profile=st.sampled_from(["etc", "udb"]))
@settings(max_examples=8, deadline=None)
def test_kv_class_coherence(seed, t_seed, profile):
    """A ``durability_violation`` cell is never correct, and ``no_crash``
    KV cells are always ``complete`` — for any stream seed, survival
    seed, and profile."""
    plan = CrashPlan.random(count=2, seed=seed % 97,
                            torn=TornSpec(fraction=0.5, seed=t_seed))
    cells = sweep(workloads=(("kv", {"n_steps": 14, "seed": seed,
                                     "profile": profile}),),
                  strategies=("none", "shadow_snapshot",
                              "checkpoint_nvm@5"),
                  plans=(CrashPlan.no_crash(), plan), cfg=SMALL)
    for c in cells:
        if c.correctness_class == "durability_violation":
            assert c.correct is False, (c.strategy, c.crash_step)
        if c.crash_step is None:
            assert c.correctness_class == "complete"
            assert c.correct, (c.strategy,)


@pytest.mark.parametrize("backend", ["reference", "vectorized"])
def test_kv_engine_invariance_both_backends(backend):
    """fork == rerun == measure (where fields overlap), cell for cell,
    for the KV workload on both emulator backends."""
    cfg = NVMConfig(backend=backend, cache_bytes=256 * 1024)
    kw = dict(workloads=(("kv", {"n_steps": 12, "profile": "udb"}),),
              strategies=("none", "adcc", "shadow_snapshot"),
              plans=(CrashPlan.no_crash(),
                     CrashPlan.at_every_step(
                         torn=TornSpec(fraction=0.5, seed=3))),
              cfg=cfg)
    fork = sweep(engine="fork", **kw)
    rerun = sweep(engine="rerun", **kw)
    measure = sweep(engine="fork", mode="measure", **kw)
    assert [deterministic_cell_dict(c) for c in fork] == \
        [deterministic_cell_dict(c) for c in rerun]
    assert len(measure) == len(fork)
    for m, f in zip(measure, fork):
        assert measure_divergence_fields(m, f) == []
