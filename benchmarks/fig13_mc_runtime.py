"""Paper Fig. 13: XSBench runtime with the seven mechanisms.

The persisted objects are tiny (macro_xs_vector + 5 counters + index =
~13 cache lines), flushed/checkpointed every 0.01% of lookups. The
NVM/DRAM checkpoint still pays a whole-DRAM-cache flush per checkpoint —
the paper's 13% outlier; ADCC flushes ~13 lines: <=0.05% overhead.
Runtime measured as wall-clock lookup loop (numpy, no emulator) with
mechanism costs charged per flush interval.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.nvm import NVMConfig

from .common import Row, emit

LOOKUPS = 200_000
# paper-matched ABSOLUTE interval: 0.01% of the paper's 1.5e7 lookups
# (tying it to our scaled-down total would shrink intervals 75x and
# exaggerate every mechanism's overhead equally)
FLUSH_EVERY = 1_500
GRID = 40_000
NUCLIDES = 34
STATE_BYTES = (5 + 5 + 1) * 8          # macro_xs + counters + index


def _native_lookup_seconds() -> float:
    """Vectorized XSBench-like lookup kernel (compute only)."""
    rng = np.random.default_rng(0)
    egrid = np.sort(rng.uniform(0, 20, GRID))
    nuc = rng.uniform(0.1, 10, (GRID, NUCLIDES, 5))
    t0 = time.perf_counter()
    B = 2000
    for i in range(0, LOOKUPS, B):
        e = rng.uniform(0, 20, B)
        idx = np.clip(np.searchsorted(egrid, e) - 1, 0, GRID - 2)
        sel = rng.integers(0, NUCLIDES, (B, 6))
        x0 = nuc[idx[:, None], sel]
        x1 = nuc[idx[:, None] + 1, sel]
        t = ((e - egrid[idx]) / np.maximum(egrid[idx + 1] - egrid[idx],
                                           1e-30))[:, None, None]
        macro = (x0 * (1 - t) + x1 * t).sum(axis=1)
        cdf = np.cumsum(macro, axis=1)
        cdf /= cdf[:, -1:]
        _ = (rng.uniform(0, 1, (B, 1)) < cdf).argmax(axis=1)
    return time.perf_counter() - t0


def _mech_total(case: str, cfg: NVMConfig) -> float:
    n_flushes = LOOKUPS // FLUSH_EVERY
    lines = max(1, STATE_BYTES // cfg.line_bytes) + 10  # distinct lines
    if case == "native":
        return 0.0
    if case == "ckpt_hdd":
        # per checkpoint: seek latency dominates tiny payloads
        return n_flushes * (5e-3 + STATE_BYTES / cfg.hdd_bw)
    if case == "ckpt_nvm_only":
        return n_flushes * (STATE_BYTES / cfg.write_bw
                            + lines * cfg.flush_latency)
    if case == "ckpt_nvm_dram":
        return n_flushes * (STATE_BYTES / cfg.write_bw
                            + lines * cfg.flush_latency
                            + cfg.dram_cache_bytes / cfg.dram_bw
                            + cfg.dram_cache_bytes / cfg.write_bw)
    if case == "pmem_undo":
        # tx per interval: log old lines + commit fences
        return n_flushes * 2 * (lines * 64 / cfg.write_bw
                                + lines * cfg.flush_latency)
    if case == "adcc":
        return n_flushes * (lines * 64 / cfg.write_bw
                            + lines * cfg.flush_latency)
    raise ValueError(case)


def run() -> List[Row]:
    native = _native_lookup_seconds()
    rows = [Row("fig13/mc_runtime/native_seconds", native,
                f"{LOOKUPS} lookups")]
    nvm_only = NVMConfig(nvm_same_as_dram=True)
    nvm_dram = NVMConfig()
    for case, cfg in [("native", nvm_only), ("ckpt_hdd", nvm_only),
                      ("ckpt_nvm_only", nvm_only),
                      ("ckpt_nvm_dram", nvm_dram), ("pmem_undo", nvm_only),
                      ("adcc_nvm_only", nvm_only),
                      ("adcc_nvm_dram", nvm_dram)]:
        base = "adcc" if case.startswith("adcc") else case
        mech = _mech_total(base, cfg)
        rows.append(Row(f"fig13/mc_runtime/{case}/normalized",
                        (native + mech) / native, f"mech={mech*1e3:.2f}ms"))
    return rows


def main() -> None:
    emit(run(), save_as="fig13_mc_runtime.json")


if __name__ == "__main__":
    main()
