"""BENCH_sweep.json trend tracker — the dense-sweep artifact diff.

The ``sweep`` suite's hard divergence gates catch *correctness*
regressions; this tool catches *performance* regressions the gates
cannot see: a change that keeps fork==rerun cell-for-cell but quietly
makes the fork engine re-copy every snapshot would sail through CI
while the speedups collapse. Compare the current artifact's speedup
columns against the previous one and fail when any drops by more than
``--max-regression`` (default 2x — generous enough for shared-runner
noise, tight enough that an O(tail) -> O(full-run) slip cannot hide).

    python -m benchmarks.sweep_trend PREV.json NEW.json

Exit codes: 0 = ok (including "no previous artifact yet" — a missing,
empty, or corrupt baseline degrades to seeding, optionally written in
place with ``--seed-baseline``), 1 = regression or unreadable CURRENT
artifact. CI wires this behind an actions/cache-restored copy of the
last successful run's BENCH_sweep.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

# the trend columns BENCH_sweep.json has carried since schema v2;
# batched_speedup, kv_cells_per_second, fault_cells_per_second, and
# pointshard_speedup arrived later, so compare_speedups tolerates
# baselines that predate any one metric (prev-missing is skipped,
# new-missing is a schema-drift failure). The *_cells_per_second
# columns are absolute throughputs rather than ratios, but the
# baseline comes from the same runner class and the 2x window absorbs
# host noise — what they catch is the KV restore/recover/audit path
# (kv_) or the fault harness's golden + retried-recovery path (fault_)
# slipping from O(touched lines) to O(store footprint).
# pointshard_speedup is a ratio like the others but additionally
# depends on the runner's core count; same-runner-class baselines keep
# it comparable, and the 2x window absorbs scheduler noise.
# kv_batched_speedup guards the analytic KV evaluators' reason to
# exist (batched over measure on the timed KV matrix), and
# device_prefix_speedup guards the device backend's streaming forward
# pass — on a CPU-only jax it sits below 1x, which is fine: the trend
# gate compares against a baseline from the same runner class, so what
# it catches is the ratio collapsing, not its absolute value.
TREND_METRICS = ("speedup", "measure_speedup", "total_speedup",
                 "batched_speedup", "kv_cells_per_second",
                 "fault_cells_per_second", "pointshard_speedup",
                 "kv_batched_speedup", "device_prefix_speedup")


def load_artifact(path: str):
    """Parse a BENCH_sweep.json, returning None for a missing, empty,
    or corrupt file instead of raising — a half-written artifact from a
    cancelled CI run must degrade to 'no baseline yet', not break the
    gate forever (the cache would re-serve the corrupt file on every
    subsequent run)."""
    try:
        with open(path) as fh:
            text = fh.read()
    except OSError:
        return None
    if not text.strip():
        return None
    try:
        payload = json.loads(text)
    except ValueError:
        return None
    return payload if isinstance(payload, dict) else None


def compare_speedups(prev: Dict, new: Dict,
                     max_regression: float = 2.0) -> List[str]:
    """Regression messages ([] = trend ok). Raw per-stage seconds are
    never compared — they shift with host load — only the speedup
    ratios (self-normalizing on the same host) and the KV cell
    throughput (noisy, but bounded by the 2x window)."""
    failures = []
    for metric in TREND_METRICS:
        if metric not in prev:
            continue  # older-schema baseline: nothing to compare yet
        if metric not in new:
            # a metric the baseline carried has vanished from the new
            # artifact — a schema drift that would otherwise silently
            # disable this gate forever
            failures.append(
                f"{metric}: present in previous artifact but missing "
                f"from the new one (schema drift disables the gate)")
            continue
        old_v, new_v = float(prev[metric]), float(new[metric])
        if old_v <= 0:
            continue
        if new_v < old_v / max_regression:
            unit = "x" if metric.endswith("speedup") else "/s"
            failures.append(
                f"{metric}: {new_v:.2f}{unit} vs previous "
                f"{old_v:.2f}{unit} (> {max_regression:g}x regression)")
    return failures


def seed_baseline(new_path: str, prev_path: str) -> None:
    """Copy the current artifact over the baseline slot so the very
    first run of a fresh cache (or a run after a corrupt baseline)
    leaves a usable baseline behind even if later steps fail."""
    os.makedirs(os.path.dirname(os.path.abspath(prev_path)), exist_ok=True)
    with open(new_path) as src, open(prev_path, "w") as dst:
        dst.write(src.read())
    print(f"sweep_trend: seeded baseline {prev_path}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev", help="previous BENCH_sweep.json (baseline)")
    ap.add_argument("new", help="current BENCH_sweep.json")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when a speedup drops by more than this "
                         "factor (default: 2.0)")
    ap.add_argument("--seed-baseline", action="store_true",
                    help="when the baseline is missing/empty/corrupt, "
                         "copy the current artifact into its place")
    args = ap.parse_args(argv)

    new = load_artifact(args.new)
    if new is None:
        print(f"sweep_trend: current artifact {args.new} missing or "
              f"unreadable", flush=True)
        return 1
    prev = load_artifact(args.prev)
    if prev is None:
        # cold start is an explicit PASS, not an ambiguous warning: the
        # gate has nothing to compare against, so say exactly what
        # happened to the baseline slot and whether the next run will
        # have one.
        state = "corrupt/empty" if os.path.exists(args.prev) else "missing"
        if args.seed_baseline:
            seed_baseline(args.new, args.prev)
            print(f"sweep_trend: PASS (cold start) — baseline at "
                  f"{args.prev} was {state}; current artifact seeded as "
                  f"the baseline for the next run", flush=True)
        else:
            print(f"sweep_trend: PASS (cold start) — baseline at "
                  f"{args.prev} is {state} and --seed-baseline was not "
                  f"given, so the trend gate stays cold until one is "
                  f"seeded", flush=True)
        return 0
    if prev.get("smoke") != new.get("smoke"):
        print("sweep_trend: smoke/full mismatch between artifacts; "
              "skipping (not comparable)", flush=True)
        return 0

    failures = compare_speedups(prev, new, args.max_regression)
    for metric in TREND_METRICS:
        if metric in new:
            unit = "x" if metric.endswith("speedup") else "/s"
            prev_s = (f"{float(prev[metric]):.2f}{unit}"
                      if metric in prev else "-")
            print(f"sweep_trend: {metric} {float(new[metric]):.2f}{unit} "
                  f"(previous {prev_s})", flush=True)
    if failures:
        print("sweep_trend: FAIL\n  " + "\n  ".join(failures), flush=True)
        return 1
    print("sweep_trend: ok", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
