"""Benchmark driver: one module per paper table/figure + framework
tables. Prints ``name,value,derived`` CSV; ``--json PATH`` additionally
writes every suite's rows as one machine-readable artifact.

    python -m benchmarks.run                      # every suite
    python -m benchmarks.run fig4 fig8 fig13      # just these
    python -m benchmarks.run --backend reference scenarios

  fig3      CG recomputation, every crash step        (paper Fig. 3)
  fig4      CG runtime, 7 mechanisms                  (paper Fig. 4)
  fig7      ABFT-MM recomputation, every crash step   (paper Fig. 7)
  fig8      ABFT-MM runtime vs rank, 7 mechanisms     (paper Fig. 8)
  fig10_12  MC correctness basic vs selective restart (paper Figs. 10+12)
  fig13     MC runtime, 7 mechanisms                  (paper Fig. 13)
  fig_torn  torn-write detection coverage vs survival (BENCH_torn.json)
  fig_faults nested-crash + media-fault campaigns     (BENCH_faults.json)
  fig_kv    KV serving durability vs overhead matrix  (BENCH_kv.json)
  scenarios workload x strategy x crash-point sweep   (BENCH_scenarios.json)
  sweep     rerun/fork/measure sweep timing + gates   (BENCH_sweep.json)
  train     training-loop ADCC vs sync checkpoint     (beyond-paper)
  kernel    ABFT matmul fused-checksum overhead       (kernel-level)

Suites construct their NVMConfigs lazily (inside ``run()``), so
``--backend`` / ``REPRO_NVM_BACKEND`` can never be snapshotted at import
time and silently ignored. ``--smoke`` / ``--workers`` export
``REPRO_SCENARIOS_SMOKE`` / ``REPRO_SWEEP_WORKERS`` the same way, for
the suites that sweep scenario matrices (fig3, fig7, fig_torn,
fig_faults, fig_kv, scenarios, sweep). ``fig_faults --chaos`` (direct
invocation) additionally gates the self-healing pool against injected
worker kills and hangs.

Roofline (reads dry-run artifacts): ``python -m benchmarks.roofline``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

from . import (fig3_cg_recompute, fig4_cg_runtime, fig7_mm_recompute,
               fig8_mm_runtime, fig10_12_mc_correctness, fig13_mc_runtime,
               fig_faults, fig_kv, fig_torn, kernel_bench, scenarios_sweep,
               sweep_timing, train_overhead)
from .common import emit, rows_to_records, write_json

SUITES = {
    "fig3": fig3_cg_recompute,
    "fig4": fig4_cg_runtime,
    "fig7": fig7_mm_recompute,
    "fig8": fig8_mm_runtime,
    "fig10_12": fig10_12_mc_correctness,
    "fig13": fig13_mc_runtime,
    "fig_torn": fig_torn,
    "fig_faults": fig_faults,
    "fig_kv": fig_kv,
    "scenarios": scenarios_sweep,
    "sweep": sweep_timing,
    "train": train_overhead,
    "kernel": kernel_bench,
}
SUITE_NAMES = tuple(SUITES)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*", metavar="SUITE",
                    help=f"suites to run (default: all; one of {SUITE_NAMES})")
    ap.add_argument("--only", default=None, choices=list(SUITE_NAMES),
                    help="(legacy) run a single suite")
    ap.add_argument("--backend", default=None,
                    choices=["reference", "vectorized"],
                    help="NVM emulation backend for every suite "
                         "(default: NVMConfig's default, i.e. vectorized)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all executed suites' rows to PATH as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized scenario matrices "
                         "(exports REPRO_SCENARIOS_SMOKE=1)")
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="processes for scenario sweeps "
                         "(exports REPRO_SWEEP_WORKERS)")
    args = ap.parse_args()
    if args.backend:
        os.environ["REPRO_NVM_BACKEND"] = args.backend
    if args.smoke:
        os.environ["REPRO_SCENARIOS_SMOKE"] = "1"
    if args.workers is not None:
        os.environ["REPRO_SWEEP_WORKERS"] = str(args.workers)
    unknown = [s for s in args.suites if s not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from {SUITE_NAMES}")
    names = list(args.suites) or ([args.only] if args.only
                                  else list(SUITE_NAMES))
    print("name,value,derived")
    t0 = time.time()
    by_suite = {}
    for name in names:
        print(f"# --- {name} ---", flush=True)
        mod = SUITES[name]
        rows = mod.run()
        emit(rows, save_as=getattr(mod, "ARTIFACT", None))
        by_suite[name] = rows_to_records(rows)
    if args.json:
        write_json(args.json, {"schema": "benchmarks.run/v1",
                               "backend": args.backend or "default",
                               "suites": by_suite})
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
