"""Iteration-versioned persistent arrays — the paper's CG extension.

The paper adds an iteration dimension to CG's four hot vectors so that
each iteration's values land in distinct cache lines / NVM locations and
are never overwritten (Fig. 2). :class:`VersionedArray` wraps a
``(versions, n)`` PersistentRegion with iteration-indexed access, and
:class:`FlushedCounter` is the "flush the cache line containing i"
primitive used by all three algorithms.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .nvm import CrashEmulator
from .regions import PersistentRegion

__all__ = ["VersionedArray", "FlushedCounter"]


class VersionedArray:
    """A vector with an added iteration dimension, stored in NVM."""

    def __init__(self, emu: CrashEmulator, name: str, versions: int, n: int,
                 dtype=np.float64, sector_lines: int = 1):
        self.region: PersistentRegion = emu.alloc(
            name, (versions, n), dtype, sector_lines=sector_lines)
        self.versions = versions
        self.n = n

    def set(self, i: int, value: np.ndarray) -> None:
        self.region[i, :] = value

    def get(self, i: int) -> np.ndarray:
        return self.region[i, :]

    def nvm_version(self, i: int) -> np.ndarray:
        """Post-crash NVM view of version i (no cache interaction)."""
        return self.region.nvm[i]

    def flush_version(self, i: int) -> None:
        self.region.flush((i, slice(None)))


class FlushedCounter:
    """A persistent scalar counter whose cache line is flushed on every
    update — the paper's single-cache-line-per-iteration overhead."""

    def __init__(self, emu: CrashEmulator, name: str):
        self.region = emu.alloc(name, (1,), np.int64)

    def set(self, value: int) -> None:
        self.region[0] = value
        self.region.flush()

    def nvm_value(self) -> int:
        return int(self.region.nvm[0])
