"""qwen2-vl-2b — VLM backbone with M-RoPE (sections 16/24/24), GQA kv=2.
The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings merged into the sequence.
[arXiv:2409.12191; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151_936, head_dim=128,
    mrope_sections=(16, 24, 24), n_patches=1024,
    rope_theta=1_000_000.0, tie_embeddings=True,
)
