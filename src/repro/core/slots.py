"""Multi-slot asynchronous state store with verified recovery.

The heavy training state (params + optimizer state) is written
round-robin into K slots with **no synchronous barrier** — the TPU
analogue of the paper's reliance on hardware cache eviction: writes
drain opportunistically; a crash mid-write tears the slot. Recovery
backward-scans slots newest-first (paper §III.B) and accepts the first
slot whose every tensor verifies against the synchronously-persisted
checksum ledger (core/acc_state.py).

Format per slot directory:
    meta.json            {"step": int, "complete": bool}
    <flat-key>.npy       one file per pytree leaf (numpy, host layout)

``complete`` is written LAST — but recovery must not trust it (a torn
filesystem can persist meta before data); it is only a fast-path hint.
Verification is always checksum-based.

``AsyncSlotWriter`` runs writes on a daemon thread; ``crash()`` abandons
the queue mid-flight exactly like a real power loss would.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SlotStore", "AsyncSlotWriter", "flatten_state", "unflatten_state"]


def flatten_state(tree) -> Dict[str, np.ndarray]:
    """pytree -> {path: ndarray} with deterministic '/'-joined keys."""
    import jax
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def unflatten_state(template, flat: Dict[str, np.ndarray]):
    """Rebuild a pytree shaped like ``template`` from the flat dict."""
    import jax
    paths = [("/".join(_path_str(p) for p in path))
             for path, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
    leaves = [flat[k] for k in paths]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class SlotStore:
    def __init__(self, root: str, n_slots: int = 3):
        self.root = root
        self.n_slots = n_slots
        os.makedirs(root, exist_ok=True)

    def slot_dir(self, k: int) -> str:
        return os.path.join(self.root, f"slot_{k}")

    def slot_for_step(self, step: int) -> int:
        return (step // 1) % self.n_slots  # round-robin by write index

    # -- write (synchronous core; async wrapper below) -------------------------
    def write_slot(self, k: int, step: int, state_flat: Dict[str, np.ndarray],
                   tear_after: Optional[int] = None) -> None:
        """Write slot k. ``tear_after`` (tests only) aborts after N leaves,
        emulating a crash mid-write."""
        d = self.slot_dir(k)
        tmp_meta = {"step": step, "complete": False}
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "meta.json"), "w") as fh:
            json.dump(tmp_meta, fh)
        for i, (key, arr) in enumerate(sorted(state_flat.items())):
            if tear_after is not None and i >= tear_after:
                return  # torn: remaining leaves keep their old bytes
            np.save(os.path.join(d, key.replace("/", "__") + ".npy"), arr)
        with open(os.path.join(d, "meta.json"), "w") as fh:
            json.dump({"step": step, "complete": True}, fh)

    # -- read -------------------------------------------------------------------
    def read_meta(self, k: int) -> Optional[Dict]:
        try:
            with open(os.path.join(self.slot_dir(k), "meta.json")) as fh:
                return json.load(fh)
        except (OSError, json.JSONDecodeError):
            return None

    def read_slot(self, k: int) -> Optional[Dict[str, np.ndarray]]:
        d = self.slot_dir(k)
        if not os.path.isdir(d):
            return None
        out = {}
        for fn in os.listdir(d):
            if fn.endswith(".npy"):
                try:
                    out[fn[:-4].replace("__", "/")] = np.load(
                        os.path.join(d, fn))
                except (OSError, ValueError):
                    return None  # torn file
        return out or None

    def slots_by_recency(self) -> List[Tuple[int, int]]:
        """[(slot, step)] sorted newest first."""
        metas = []
        for k in range(self.n_slots):
            m = self.read_meta(k)
            if m is not None and "step" in m:
                metas.append((k, int(m["step"])))
        return sorted(metas, key=lambda t: -t[1])

    def wipe(self) -> None:
        shutil.rmtree(self.root, ignore_errors=True)
        os.makedirs(self.root, exist_ok=True)


class AsyncSlotWriter:
    """Daemon-thread writer: enqueue state snapshots; crash() drops the
    queue and kills the in-flight write at the next leaf boundary."""

    def __init__(self, store: SlotStore):
        self.store = store
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._crashed = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._write_idx = 0

    def submit(self, step: int, state_flat: Dict[str, np.ndarray]) -> None:
        slot = self._write_idx % self.store.n_slots
        self._write_idx += 1
        self._idle.clear()
        self._q.put((slot, step, state_flat))

    def _run(self) -> None:
        while True:
            slot, step, flat = self._q.get()
            if self._crashed.is_set():
                continue
            d = self.store.slot_dir(slot)
            os.makedirs(d, exist_ok=True)
            with open(os.path.join(d, "meta.json"), "w") as fh:
                json.dump({"step": step, "complete": False}, fh)
            for i, (key, arr) in enumerate(sorted(flat.items())):
                if self._crashed.is_set():
                    break  # power loss mid-write: slot is torn
                np.save(os.path.join(d, key.replace("/", "__") + ".npy"), arr)
            else:
                if not self._crashed.is_set():
                    with open(os.path.join(d, "meta.json"), "w") as fh:
                        json.dump({"step": step, "complete": True}, fh)
            if self._q.empty():
                self._idle.set()

    def drain(self, timeout: float = 60.0) -> None:
        self._idle.wait(timeout)

    def crash(self) -> None:
        """Simulated power loss: abandon queued + in-flight writes."""
        self._crashed.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
