"""Device-math layer for the batched sweep engine (``sweep(mode="batched")``).

The batched engine (repro.scenarios.batched_engine) evaluates every
crash cell of a (workload, strategy) pair from host-side snapshots; the
only per-cell work that is numerically heavy is integrity checking —
CG's invariant backward-scan (orthogonality + residual per candidate
iteration) and ABFT's per-chunk checksum verification. This module
lifts exactly that math onto jax: the engine stacks every (cell,
candidate) / (cell, chunk) crash-image row of a whole sweep matrix and
gets the error magnitudes back from a handful of jit launches, routed
through the Pallas kernels (`repro.kernels`) on TPU and plain XLA
elsewhere.

Device results are used as a *screen*, not a verdict: accumulation
order on device differs from the host reference by a few ulps, so the
engine accepts a device verdict only outside a safety band around the
tolerance (certainly-ok / certainly-fail) and recomputes the borderline
sliver with the exact host code (`repro.core.invariants`,
`repro.core.abft`). That keeps batched cells bit-identical to
measure-mode cells while the overwhelming majority of checks never
touch the host path.

Everything is gated on jax being importable (``have_jax``): without it
the batched engine falls back to per-cell measure evaluation and this
module is never exercised.

Shapes are padded to a few fixed sizes (powers of two up to the
``CHUNK_ELEMS`` budget) so jit compiles a handful of kernels per
problem size instead of one per batch.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

try:  # soft: the engine falls back to host evaluation without jax
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    _JAX_IMPORT_ERROR: Optional[BaseException] = None
except Exception as exc:  # pragma: no cover - env without jax
    jax = None
    jnp = None
    enable_x64 = None
    _JAX_IMPORT_ERROR = exc

__all__ = ["have_jax", "jax_runtime_live", "cg_route",
           "cg_invariant_errors", "mm_chunk_stats",
           "kv_row_checksums", "kv_value_match",
           "cache_op_update", "queue_validity",
           "CHUNK_ELEMS", "GEMM_MAX_N", "SPARSE_BLOCK_ROWS"]

# per-launch element budget: bounds device/host transfer buffers and
# keeps padded launch shapes to a handful of compiled variants
CHUNK_ELEMS = 1 << 25

# largest CG system routed through the dense symmetrized-operator GEMM
# (the TPU/Pallas route — densifying the CSR operator would dominate
# memory beyond this); bigger systems take the engine's per-cell
# fallback there. The sparse route has no such cliff and is ungated.
GEMM_MAX_N = 4096


def have_jax() -> bool:
    """Whether the jax device path is available in this process."""
    return jax is not None


def jax_runtime_live() -> bool:
    """Whether this process has already instantiated an XLA backend
    (device buffers, compilation threads, locks). Forking a process in
    that state deadlocks the children's device math — the sweep driver
    switches its worker pool to spawn-start when this is true."""
    if jax is None:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:  # pragma: no cover - private-API drift
        return True  # conservative: assume live, pay the spawn cost


def _require_jax() -> None:
    if jax is None:  # pragma: no cover - env without jax
        raise RuntimeError(
            f"jax unavailable for batched device math: {_JAX_IMPORT_ERROR}")


def _chunk_rows(total: int, elems_per_row: int) -> int:
    """Fixed launch row-count: the CHUNK_ELEMS budget, or the next power
    of two when the whole batch is smaller (so small batches reuse a
    log-many set of compiled shapes instead of one per batch size)."""
    cap = max(1, CHUNK_ELEMS // max(1, elems_per_row))
    if total >= cap:
        return cap
    c = 1
    while c < total:
        c <<= 1
    return c


# ---------------------------------------------------------------------------
# CG invariant errors (Eq. 1 orthogonality, Eq. 2 residual)
# ---------------------------------------------------------------------------

if jax is not None:

    def _cg_errors_from_Sz(P, Q, R, Z, b, Sz):
        pq = jnp.sum(P * Q, axis=1)
        denom = jnp.linalg.norm(P, axis=1) * jnp.linalg.norm(Q, axis=1) + 1e-300
        orth = jnp.abs(pq) / denom
        resid = jnp.linalg.norm(R - (b[None, :] - Sz), axis=1)
        rel = resid / (jnp.linalg.norm(b) + 1e-300)
        return orth, rel

    @functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
    def _cg_errors_dense_jit(P, Q, R, Z, b, S, *, use_pallas, interpret):
        from ...kernels.abft_matmul.ops import gemm_batch

        # S is the dense symmetrized operator 0.5*(A + A^T); stacking all
        # candidate z rows makes the residual matvecs one GEMM launch
        # through the Pallas fused-epilogue matmul (MXU route)
        Sz = gemm_batch(Z, S, acc_dtype=jnp.float64,
                        use_pallas=use_pallas, interpret=interpret)
        return _cg_errors_from_Sz(P, Q, R, Z, b, Sz)

    @jax.jit
    def _cg_errors_sparse_jit(P, Q, R, Z, b, vals, cols):
        # batched sparse matvec over the padded equal-width symmetrized
        # operator (vals/cols are (n, K) row slabs, zero-padded): pure
        # gather + multiply + reduce — O(nnz) work per candidate row
        # where the dense GEMM route does O(n^2), and no device scatter
        # (scatter serializes badly on CPU XLA). The MXU makes the dense
        # route the right call on TPU; sparse wins everywhere else by
        # the fill factor.
        Sz = jnp.sum(Z[:, cols] * vals[None, :, :], axis=-1)
        return _cg_errors_from_Sz(P, Q, R, Z, b, Sz)

    @functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
    def _mm_stats_jit(V, *, use_pallas, interpret):
        from ...kernels.checksum_verify.ops import tile_sums_batch

        data = V[:, :-1, :-1]
        row_sums, col_sums = tile_sums_batch(
            data, acc_dtype=jnp.float64,
            use_pallas=use_pallas, interpret=interpret)
        rowmax = jnp.max(jnp.abs(V[:, :-1, -1] - row_sums), axis=1)
        colmax = jnp.max(jnp.abs(V[:, -1, :-1] - col_sums), axis=1)
        absmax = jnp.max(jnp.abs(V), axis=(1, 2))
        nonzero = jnp.any(V != 0, axis=(1, 2))
        return nonzero, absmax, rowmax, colmax


def _pad_rows(block: np.ndarray, rows: int) -> np.ndarray:
    if block.shape[0] >= rows:
        return block
    # np.zeros + slice assign: np.pad's generic path is several times
    # slower and this sits on the per-launch hot path
    out = np.zeros((rows,) + block.shape[1:], dtype=block.dtype)
    out[:block.shape[0]] = block
    return out


# fixed sparse-route launch width: every chunk is padded to this many
# rows so jit compiles exactly one shape per (n, nnz), however the
# caller's batch/wave sizes vary
SPARSE_BLOCK_ROWS = 256


def cg_route(use_pallas: Optional[bool] = None) -> str:
    """Which residual-matvec route ``cg_invariant_errors`` will take:
    ``"dense"`` (Pallas fused-epilogue GEMM over the densified
    symmetrized operator — the MXU-native TPU route, subject to
    :data:`GEMM_MAX_N`) or ``"sparse"`` (batched CSR gather/scatter —
    O(nnz) per row, the right call on CPU/GPU XLA hosts)."""
    if use_pallas is None:
        from ...kernels.abft_matmul.ops import on_tpu
        use_pallas = on_tpu()
    return "dense" if use_pallas else "sparse"


def cg_invariant_errors(P: np.ndarray, Q: np.ndarray, R: np.ndarray,
                        Z: np.ndarray, b: np.ndarray, operator, *,
                        use_pallas: Optional[bool] = None,
                        interpret: bool = False
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched CG invariant error magnitudes over candidate rows.

    P/Q/R/Z are (T, n) stacks of post-crash overlay rows — one row per
    (cell, candidate iteration) pair. ``operator`` is the symmetrized
    system matrix S = 0.5*(A + A^T) in the representation matching
    :func:`cg_route`: ``("dense", S)`` densified, or
    ``("sparse", vals, cols)`` — (n, K) equal-width row slabs of S,
    rows zero-padded to the widest row (see
    :func:`~repro.scenarios.batched_engine._CGAdccEvaluator._operator`).
    Returns (orth_err (T,), resid_rel (T,)) as float64 numpy arrays:

      orth_err[t]  = |p.q| / (|p||q| + 1e-300)       (vs tol 1e-7)
      resid_rel[t] = ||r - (b - S z)|| / (||b|| + 1e-300)  (vs tol 1e-6)

    the exact quantities OrthogonalityInvariant / ResidualInvariant
    compare — up to device accumulation order, which is why callers
    apply a certainty band before trusting a verdict.
    """
    _require_jax()
    kind, *op = operator
    T, n = P.shape
    rows = (_chunk_rows(T, 4 * n) if kind == "dense"
            else min(SPARSE_BLOCK_ROWS, _chunk_rows(T, 4 * n)))
    orth = np.empty(T, dtype=np.float64)
    rel = np.empty(T, dtype=np.float64)
    with enable_x64():
        bj = jnp.asarray(np.asarray(b, dtype=np.float64))
        if kind == "dense":
            if use_pallas is None:
                from ...kernels.abft_matmul.ops import on_tpu
                use_pallas = on_tpu()
            opj = (jnp.asarray(np.asarray(op[0], dtype=np.float64)),)
        elif kind == "sparse":
            vals, cols = op
            opj = (jnp.asarray(np.asarray(vals, dtype=np.float64)),
                   jnp.asarray(np.asarray(cols, dtype=np.int32)))
        else:
            raise ValueError(f"unknown CG operator representation {kind!r}")
        for lo in range(0, T, rows):
            hi = min(lo + rows, T)
            blocks = (jnp.asarray(_pad_rows(P[lo:hi], rows)),
                      jnp.asarray(_pad_rows(Q[lo:hi], rows)),
                      jnp.asarray(_pad_rows(R[lo:hi], rows)),
                      jnp.asarray(_pad_rows(Z[lo:hi], rows)))
            if kind == "dense":
                o, r = _cg_errors_dense_jit(
                    *blocks, bj, *opj, use_pallas=bool(use_pallas),
                    interpret=bool(interpret))
            else:
                o, r = _cg_errors_sparse_jit(*blocks, bj, *opj)
            orth[lo:hi] = np.asarray(o)[:hi - lo]
            rel[lo:hi] = np.asarray(r)[:hi - lo]
    return orth, rel


# ---------------------------------------------------------------------------
# KV integrity math (SplitMix64 mix-chain checksums, value-word verify)
# ---------------------------------------------------------------------------
#
# Unlike the float CG/ABFT screens above, everything here is uint64
# integer arithmetic with wraparound semantics — bit-exact on every XLA
# backend and in the numpy fallback — so no certainty band is needed:
# a device verdict IS the host verdict. The batched KV evaluator still
# re-confirms device-flagged-bad rows with the exact host code
# (repro.scenarios.kv), because those rare verdicts are the ones that
# drive visible behavior (row drops, violation counts) and the
# re-check costs nothing.

_SM64_GAMMA = 0x9E3779B97F4A7C15
_SM64_MIX1 = 0xBF58476D1CE4E5B9
_SM64_MIX2 = 0x94D049BB133111EB
_KV_MIX_INIT = 0x243F6A8885A308D3
_KV_VALUE_SALT = 21  # key << 21 ^ seq, matching kv._value_words
_MASK63 = (1 << 63) - 1


def _np_splitmix(z: np.ndarray) -> np.ndarray:
    """Vectorized SplitMix64 over uint64 arrays — bit-identical to the
    scalar ``repro.scenarios.kv._splitmix`` (wraparound multiplies)."""
    with np.errstate(over="ignore"):
        z = z + np.uint64(_SM64_GAMMA)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(_SM64_MIX1)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(_SM64_MIX2)
        return z ^ (z >> np.uint64(31))


if jax is not None:

    def _j_splitmix(z):
        z = z + jnp.uint64(_SM64_GAMMA)
        z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(_SM64_MIX1)
        z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(_SM64_MIX2)
        return z ^ (z >> jnp.uint64(31))

    @functools.partial(jax.jit, static_argnames=("width",))
    def _kv_row_ck_jit(words, *, width):
        # order-sensitive chain: acc_{j+1} = splitmix(acc_j ^ w_j); the
        # width is static (7 for index rows, 15 for meta rows) so the
        # chain unrolls into a fixed op sequence per compiled shape
        acc = jnp.full(words.shape[0], _KV_MIX_INIT, dtype=jnp.uint64)
        for j in range(width):
            acc = _j_splitmix(acc ^ words[:, j])
        return acc & jnp.uint64(_MASK63)

    @jax.jit
    def _kv_value_match_jit(keys, seqs, got, nwords):
        base = _j_splitmix((keys << jnp.uint64(_KV_VALUE_SALT)) ^ seqs)
        offs = jnp.arange(got.shape[1], dtype=jnp.uint64)
        expect = _j_splitmix(base[:, None] + offs[None, :]) \
            & jnp.uint64(_MASK63)
        live = offs[None, :] < nwords[:, None]
        return jnp.all(jnp.where(live, got == expect, True), axis=1)

    @functools.partial(jax.jit, static_argnames=("is_write", "fifo"))
    def _cache_op_jit(present, dirty, stamp, t0, *, is_write, fifo):
        # bulk no-eviction cache-op transition (see cache_op_update)
        pos = jnp.arange(present.shape[0], dtype=jnp.int64)
        miss = ~present
        new_stamp = t0 + pos if not fifo else jnp.where(miss, t0 + pos, stamp)
        new_dirty = (jnp.ones_like(dirty) if is_write
                     else jnp.logical_and(dirty, present))
        return (jnp.ones_like(present), new_dirty, new_stamp, miss,
                jnp.sum(miss, dtype=jnp.int64))

    @jax.jit
    def _queue_validity_jit(present, stamp, entries, stamps, weight):
        valid = jnp.logical_and(present[entries], stamp[entries] == stamps)
        return valid, jnp.where(valid, weight, 0).astype(jnp.int64)


def _pow2_rows(n: int) -> int:
    c = 1
    while c < n:
        c <<= 1
    return c


def _as_u64(a: np.ndarray) -> np.ndarray:
    # int64 -> uint64 by two's-complement reinterpretation (== & MASK64),
    # matching the scalar host code's `w & _MASK64` on python ints
    return np.ascontiguousarray(np.asarray(a)).astype(np.uint64)


def kv_row_checksums(words: np.ndarray) -> np.ndarray:
    """Batched order-sensitive 63-bit mix-chain checksum per row.

    ``words`` is an (N, K) int64/uint64 stack of row prefixes (K = 7 for
    KV index rows, 15 for meta rows). Returns the (N,) int64 checksums —
    the device counterpart of ``repro.scenarios.kv._mix_words``, exact
    (integer wraparound is bit-identical on device and host).
    Falls back to vectorized numpy when jax is unavailable.
    """
    if len(words) == 0:
        return np.empty(0, dtype=np.int64)
    w = _as_u64(words).reshape(len(words), -1)
    N, K = w.shape
    if jax is None:
        acc = np.full(N, _KV_MIX_INIT, dtype=np.uint64)
        for j in range(K):
            acc = _np_splitmix(acc ^ w[:, j])
        return (acc & np.uint64(_MASK63)).astype(np.int64)
    rows = _pow2_rows(max(1, N))
    with enable_x64():
        out = _kv_row_ck_jit(jnp.asarray(_pad_rows(w, rows)), width=K)
        return np.asarray(out)[:N].astype(np.int64)


def kv_value_match(keys: np.ndarray, seqs: np.ndarray, got: np.ndarray,
                   nwords: np.ndarray) -> np.ndarray:
    """Batched value-word verification for KV index rows.

    Row i matches when ``got[i, :nwords[i]]`` equals the deterministic
    value words of (key, seq) — the device counterpart of comparing
    against ``repro.scenarios.kv._value_words``. ``got`` is (N, W)
    zero-padded beyond each row's width; returns an (N,) bool array.
    Exact (pure uint64 math); numpy fallback without jax.
    """
    if len(keys) == 0:
        return np.empty(0, dtype=bool)
    k = _as_u64(keys)
    s = _as_u64(seqs)
    g = _as_u64(got).reshape(len(k), -1)
    nw = np.asarray(nwords, dtype=np.int64)
    N, W = g.shape
    if jax is None:
        base = _np_splitmix((k << np.uint64(_KV_VALUE_SALT)) ^ s)
        offs = np.arange(W, dtype=np.uint64)
        with np.errstate(over="ignore"):
            expect = _np_splitmix(base[:, None] + offs[None, :]) \
                & np.uint64(_MASK63)
        live = offs[None, :].astype(np.int64) < nw[:, None]
        return np.all(np.where(live, g == expect, True), axis=1)
    rows = _pow2_rows(max(1, N))
    with enable_x64():
        out = _kv_value_match_jit(
            jnp.asarray(_pad_rows(k, rows)), jnp.asarray(_pad_rows(s, rows)),
            jnp.asarray(_pad_rows(g, rows)),
            jnp.asarray(_pad_rows(nw, rows)))
        return np.asarray(out)[:N]


# ---------------------------------------------------------------------------
# DeviceBackend step kernels (forward-pass cache transitions)
# ---------------------------------------------------------------------------

def cache_op_update(present: np.ndarray, dirty: np.ndarray,
                    stamp: np.ndarray, t0: int, is_write: bool, fifo: bool
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray, int]:
    """Bulk cache-state transition for one span op touching entries
    ``[e_lo, e_hi)`` when no eviction is needed (the streaming regime).

    Inputs are the per-entry slices of a region's present/dirty bitmaps
    and LRU stamps; ``t0`` is the op's base clock tick. Returns
    ``(new_present, new_dirty, new_stamp, miss, n_miss)`` — exactly the
    state `VectorizedBackend._op` produces for a no-eviction op:

      * every touched entry ends resident;
      * a write dirties all touched entries, a read preserves dirt on
        hits and leaves misses clean;
      * LRU restamps every entry with ``t0 + position``; FIFO restamps
        misses only (hits keep their insertion stamp);
      * ``n_miss`` misses were fetched (the caller charges read traffic
        and queue-appends accordingly).

    The caller must pre-check capacity and fall back to the host path
    when the op could evict. Shapes are padded to powers of two
    (pad lanes: present=True, dirty=False — hits that never miss) so
    jit compiles log-many variants. Numpy fallback without jax.
    """
    m = len(present)
    if jax is None:
        pos = np.arange(m, dtype=np.int64)
        miss = ~present
        new_stamp = (t0 + pos if not fifo
                     else np.where(miss, t0 + pos, stamp))
        new_dirty = (np.ones(m, dtype=bool) if is_write
                     else np.logical_and(dirty, present))
        return (np.ones(m, dtype=bool), new_dirty, new_stamp, miss,
                int(miss.sum()))
    rows = _pow2_rows(max(1, m))
    pad = rows - m
    p = np.concatenate([present, np.ones(pad, dtype=bool)]) if pad else present
    d = _pad_rows(np.ascontiguousarray(dirty), rows)
    st = _pad_rows(np.ascontiguousarray(stamp), rows)
    with enable_x64():
        np_, nd, ns, miss, n_miss = _cache_op_jit(
            jnp.asarray(p), jnp.asarray(d), jnp.asarray(st),
            jnp.int64(t0), is_write=bool(is_write), fifo=bool(fifo))
        return (np.asarray(np_)[:m], np.asarray(nd)[:m],
                np.asarray(ns)[:m], np.asarray(miss)[:m], int(n_miss))


def queue_validity(present: np.ndarray, stamp: np.ndarray,
                   entries: np.ndarray, stamps: np.ndarray,
                   weight: int) -> Tuple[np.ndarray, np.ndarray]:
    """Eviction-queue slot validation for a single-region window.

    A queue slot is live when its entry is still resident and its
    recorded stamp matches the entry's current stamp (stale LRU
    re-touch duplicates fail the stamp check). Returns ``(valid, wts)``
    with ``wts[i] = weight`` (the region's sector-line weight) on valid
    slots and 0 elsewhere — the single-rid core of
    ``VectorizedBackend._validity``. Pad lanes (entry 0 / stamp 0) are
    never valid: a resident entry always carries a stamp >= 1.
    Numpy fallback without jax.
    """
    n = len(entries)
    if jax is None:
        valid = np.logical_and(present[entries], stamp[entries] == stamps)
        return valid, np.where(valid, weight, 0).astype(np.int64)
    rows = _pow2_rows(max(1, n))
    with enable_x64():
        valid, wts = _queue_validity_jit(
            jnp.asarray(np.ascontiguousarray(present)),
            jnp.asarray(np.ascontiguousarray(stamp)),
            jnp.asarray(_pad_rows(np.ascontiguousarray(entries), rows)),
            jnp.asarray(_pad_rows(np.ascontiguousarray(stamps), rows)),
            jnp.int64(weight))
        return np.asarray(valid)[:n], np.asarray(wts)[:n]


def mm_chunk_stats(V: np.ndarray, *, use_pallas: Optional[bool] = None,
                   interpret: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched ABFT checksum statistics over full-checksum matrices.

    V is a (B, m, m) stack of post-crash chunk images (m = n+1 with the
    checksum row/column in place) — one slab per (cell, examined chunk)
    pair. Returns per-slab

      nonzero  any element != 0 (exact on device)
      absmax   max |V| (exact on device — no accumulation)
      rowmax   max row-checksum residual |V[:-1,-1] - sum(data, axis=1)|
      colmax   max col-checksum residual |V[-1,:-1] - sum(data, axis=0)|

    matching ``repro.core.abft.residuals``/``verify`` up to device
    summation order (callers apply a certainty band on rowmax/colmax;
    nonzero and the tolerance derived from absmax are exact).
    """
    _require_jax()
    if use_pallas is None:
        from ...kernels.abft_matmul.ops import on_tpu
        use_pallas = on_tpu()
    B, m, _ = V.shape
    rows = _chunk_rows(B, m * m)
    nonzero = np.empty(B, dtype=bool)
    absmax = np.empty(B, dtype=np.float64)
    rowmax = np.empty(B, dtype=np.float64)
    colmax = np.empty(B, dtype=np.float64)
    with enable_x64():
        for lo in range(0, B, rows):
            hi = min(lo + rows, B)
            nz, am, rm, cm = _mm_stats_jit(
                jnp.asarray(_pad_rows(V[lo:hi], rows)),
                use_pallas=bool(use_pallas), interpret=bool(interpret))
            nonzero[lo:hi] = np.asarray(nz)[:hi - lo]
            absmax[lo:hi] = np.asarray(am)[:hi - lo]
            rowmax[lo:hi] = np.asarray(rm)[:hi - lo]
            colmax[lo:hi] = np.asarray(cm)[:hi - lo]
    return nonzero, absmax, rowmax, colmax
