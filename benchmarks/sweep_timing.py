"""Fork-vs-rerun sweep-engine comparison — the ``sweep`` suite.

Times a dense one-crash-point-per-step matrix (3 workloads × 3
strategies × (no_crash + at_every_step)) under both sweep engines,
writes ``BENCH_sweep.json`` with per-engine seconds + speedup, and
fails if any cell's deterministic payload differs between engines.

    PYTHONPATH=src python -m benchmarks.sweep_timing            # full
    PYTHONPATH=src python -m benchmarks.sweep_timing --smoke    # CI

The matrix definitions and comparison logic live in
benchmarks/scenarios_sweep.py (``fork_vs_rerun_timing`` /
``run_timing``); this module is the registered suite entry point.
"""

from __future__ import annotations

from typing import List

from .common import Row, emit
from .scenarios_sweep import BENCH_SWEEP_JSON, run_timing  # noqa: F401

ARTIFACT = "sweep_timing.json"


def run(smoke: bool = None) -> List[Row]:
    return run_timing(smoke)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized dense matrix")
    args = ap.parse_args()
    emit(run(smoke=args.smoke or None), save_as=ARTIFACT)
