"""Emulated NVM + volatile cache + crash semantics (paper §III.A).

The paper studies crash consistence with a PIN-based emulator: program
loads/stores go through a configurable LRU cache sitting in front of
NVM-based main memory; on a crash, cache contents are lost and only the
NVM image survives. This module reproduces that machinery in pure
numpy at cache-line granularity, plus a bandwidth/latency *cost model*
(Quartz-style: NVM bandwidth = DRAM/8 by default) so mechanism overheads
can be charged in modeled seconds independent of host speed.

Three layers:

  NVMStore        persistent image (survives ``crash()``) + traffic stats
  VolatileCache   fully-associative LRU write-back cache over the store
  CrashEmulator   couples program "truth" arrays with cache+store; provides
                  ``crash()`` / ``recover()`` and region allocation

Granularity: a *line* is ``line_bytes`` of a region's flattened buffer.
Program views ("truth") always hold the latest values — the cache tracks
*which lines would still be dirty in a volatile cache*, i.e. which bytes
have NOT yet reached NVM. ``crash()`` discards exactly those bytes.

Cost model notes (paper §II): flushing a clean or absent line costs the
same order as flushing a dirty one, so ``flush`` charges per-line cost
unconditionally. CLFLUSH also invalidates, so flushed lines leave the
cache.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = [
    "NVMConfig",
    "TrafficStats",
    "NVMStore",
    "VolatileCache",
    "CrashEmulator",
]


@dataclasses.dataclass(frozen=True)
class NVMConfig:
    """Cache geometry + bandwidth cost model.

    Defaults mirror the paper's setup: 32 MB cache (their DRAM cache size;
    we use it as the volatile-cache capacity for crash experiments can be
    overridden per-test), 64 B lines, NVM bandwidth = DRAM/8 (Quartz
    configuration), DRAM ~25.6 GB/s (2×DDR3-1600 as on their Xeon E5606
    box), local HDD ~120 MB/s for checkpoint baselines.
    """

    cache_bytes: int = 32 * 1024 * 1024
    dram_cache_bytes: int = 32 * 1024 * 1024  # NVM/DRAM system's DRAM cache
    line_bytes: int = 64
    dram_bw: float = 25.6e9          # B/s
    nvm_read_bw: float = 25.6e9 / 8  # B/s (paper: up to 8x lower bandwidth)
    nvm_write_bw: float = 25.6e9 / 8
    hdd_bw: float = 120e6            # B/s, local hard drive baseline
    flush_latency: float = 100e-9    # s per CLFLUSH instruction issue
    nvm_same_as_dram: bool = False   # the paper's optimistic "NVM-only" config
    # "lru": fully-associative LRU (paper's emulator default).
    # "fifo": insertion-order replacement — models the conflict evictions a
    # real set-associative cache inflicts on *hot* lines, which is what
    # leaves XSBench's counters stale-by-different-amounts in NVM (Fig. 10).
    replacement: str = "lru"

    @property
    def read_bw(self) -> float:
        return self.dram_bw if self.nvm_same_as_dram else self.nvm_read_bw

    @property
    def write_bw(self) -> float:
        return self.dram_bw if self.nvm_same_as_dram else self.nvm_write_bw


@dataclasses.dataclass
class TrafficStats:
    """Byte-accurate traffic + modeled-time accounting."""

    nvm_bytes_written: int = 0
    nvm_bytes_read: int = 0
    lines_flushed: int = 0
    lines_evicted: int = 0
    modeled_seconds: float = 0.0

    def charge_write(self, nbytes: int, cfg: NVMConfig) -> None:
        self.nvm_bytes_written += nbytes
        self.modeled_seconds += nbytes / cfg.write_bw

    def charge_read(self, nbytes: int, cfg: NVMConfig) -> None:
        self.nvm_bytes_read += nbytes
        self.modeled_seconds += nbytes / cfg.read_bw

    def charge_flush_issue(self, nlines: int, cfg: NVMConfig) -> None:
        self.lines_flushed += nlines
        self.modeled_seconds += nlines * cfg.flush_latency

    def snapshot(self) -> "TrafficStats":
        return dataclasses.replace(self)

    def delta_since(self, prev: "TrafficStats") -> "TrafficStats":
        return TrafficStats(
            nvm_bytes_written=self.nvm_bytes_written - prev.nvm_bytes_written,
            nvm_bytes_read=self.nvm_bytes_read - prev.nvm_bytes_read,
            lines_flushed=self.lines_flushed - prev.lines_flushed,
            lines_evicted=self.lines_evicted - prev.lines_evicted,
            modeled_seconds=self.modeled_seconds - prev.modeled_seconds,
        )


class NVMStore:
    """The persistent image: named flat byte-addressable regions.

    ``image[name]`` is the array of bytes that would survive a crash.
    All writes into the image are charged to ``stats`` at NVM bandwidth.
    """

    def __init__(self, cfg: NVMConfig):
        self.cfg = cfg
        self.image: Dict[str, np.ndarray] = {}
        self.meta: Dict[str, Tuple[Tuple[int, ...], np.dtype]] = {}
        self.stats = TrafficStats()

    def alloc(self, name: str, shape: Tuple[int, ...], dtype) -> None:
        if name in self.image:
            raise KeyError(f"region {name!r} already allocated")
        dt = np.dtype(dtype)
        self.image[name] = np.zeros(int(np.prod(shape)), dtype=dt)
        self.meta[name] = (tuple(shape), dt)

    def free(self, name: str) -> None:
        self.image.pop(name, None)
        self.meta.pop(name, None)

    def writeback(self, name: str, lo: int, hi: int, src: np.ndarray) -> None:
        """Persist src[lo:hi) (flat element indices) into the image."""
        self.image[name][lo:hi] = src[lo:hi]
        self.stats.charge_write((hi - lo) * src.itemsize, self.cfg)

    def read_view(self, name: str) -> np.ndarray:
        """The surviving (post-crash) contents, shaped. No cost charged:
        recovery-time reads are charged by the recovery code itself."""
        shape, _ = self.meta[name]
        return self.image[name].reshape(shape)


class VolatileCache:
    """Fully-associative LRU write-back cache.

    Keys are ``(region, entry_index)`` where an *entry* covers
    ``sector_lines`` consecutive cache lines of that region (sector_lines=1
    reproduces exact per-line behavior; large read-mostly regions register
    with coarser sectors so emulation stays fast while capacity pressure —
    the thing that drives the paper's eviction behavior — is preserved:
    entries are *weighted* by their line count against the capacity).

    Only occupancy and dirtiness are tracked — the newest data lives in
    the emulator's truth arrays; the store's image holds whatever has been
    written back.
    """

    def __init__(self, store: NVMStore, cfg: NVMConfig):
        self.store = store
        self.cfg = cfg
        self.capacity_lines = max(1, cfg.cache_bytes // cfg.line_bytes)
        # value = dirty flag; weight per entry is a per-region constant
        self._lru: "OrderedDict[Tuple[str, int], bool]" = OrderedDict()
        self._weight_used = 0
        self._truth: Dict[str, np.ndarray] = {}
        self._sector_lines: Dict[str, int] = {}

    # -- registration ------------------------------------------------------
    def register(self, name: str, truth_flat: np.ndarray, sector_lines: int = 1) -> None:
        self._truth[name] = truth_flat
        self._sector_lines[name] = max(1, int(sector_lines))

    def unregister(self, name: str) -> None:
        self._truth.pop(name, None)
        stale = [k for k in self._lru if k[0] == name]
        w = self._sector_lines.get(name, 1)
        for k in stale:
            del self._lru[k]
            self._weight_used -= w
        self._sector_lines.pop(name, None)

    # -- geometry ----------------------------------------------------------
    def _elems_per_entry(self, name: str) -> int:
        epl = max(1, self.cfg.line_bytes // self._truth[name].itemsize)
        return epl * self._sector_lines[name]

    def _entry_range(self, name: str, lo: int, hi: int) -> range:
        epe = self._elems_per_entry(name)
        return range(lo // epe, (hi - 1) // epe + 1) if hi > lo else range(0)

    # -- internals ----------------------------------------------------------
    def _evict_one(self) -> None:
        (name, entry), dirty = self._lru.popitem(last=False)
        self._weight_used -= self._sector_lines[name]
        if dirty:
            self._writeback_entry(name, entry)
        self.store.stats.lines_evicted += self._sector_lines[name]

    def _writeback_entry(self, name: str, entry: int) -> None:
        truth = self._truth[name]
        epe = self._elems_per_entry(name)
        lo = entry * epe
        hi = min(lo + epe, truth.shape[0])
        if hi > lo:
            self.store.writeback(name, lo, hi, truth)

    def _touch(self, name: str, entry: int, dirty: bool) -> None:
        key = (name, entry)
        if self.cfg.replacement == "fifo":
            # FIFO: hits update dirtiness in place (no reordering), so hot
            # lines age out periodically like victims of set conflicts
            prev = self._lru.get(key)
            if prev is not None:
                if dirty and not prev:
                    self._lru[key] = True
                return
            w = self._sector_lines[name]
            while self._weight_used + w > self.capacity_lines and self._lru:
                self._evict_one()
            self._weight_used += w
            self._lru[key] = dirty
            return
        prev = self._lru.pop(key, None)
        if prev is None:
            w = self._sector_lines[name]
            while self._weight_used + w > self.capacity_lines and self._lru:
                self._evict_one()
            self._weight_used += w
        self._lru[key] = dirty or bool(prev)

    # -- program-visible operations ------------------------------------------
    def write(self, name: str, lo: int, hi: int) -> None:
        """Program stored truth[lo:hi): allocate entries, mark dirty."""
        for entry in self._entry_range(name, lo, hi):
            self._touch(name, entry, dirty=True)

    def read(self, name: str, lo: int, hi: int) -> None:
        """Program loaded truth[lo:hi): allocate entries (miss => charge
        NVM read), do not dirty."""
        itemsize = self._truth[name].itemsize
        epe = self._elems_per_entry(name)
        for entry in self._entry_range(name, lo, hi):
            if (name, entry) not in self._lru:
                self.store.stats.charge_read(epe * itemsize, self.cfg)
            self._touch(name, entry, dirty=False)

    def flush(self, name: str, lo: int = 0, hi: Optional[int] = None) -> None:
        """CLFLUSH truth[lo:hi): write back dirty entries, invalidate,
        charge per-line cost unconditionally (paper §II: flushing clean or
        absent lines costs the same order as dirty ones)."""
        if hi is None:
            hi = self._truth[name].shape[0]
        entries = self._entry_range(name, lo, hi)
        sector = self._sector_lines[name]
        self.store.stats.charge_flush_issue(len(entries) * sector, self.cfg)
        itemsize = self._truth[name].itemsize
        epe = self._elems_per_entry(name)
        for entry in entries:
            key = (name, entry)
            dirty = self._lru.pop(key, None)
            if dirty is not None:
                self._weight_used -= sector
            if dirty:
                self._writeback_entry(name, entry)
            else:
                # clean/absent flush still occupies the memory pipeline
                self.store.stats.modeled_seconds += (
                    epe * itemsize / self.store.cfg.write_bw
                )

    def drain(self) -> None:
        """Write back everything (normal program termination)."""
        while self._lru:
            (name, entry), dirty = self._lru.popitem(last=False)
            self._weight_used -= self._sector_lines[name]
            if dirty:
                self._writeback_entry(name, entry)

    def crash(self) -> int:
        """Power loss: volatile contents vanish. Returns #dirty entries lost."""
        lost = sum(1 for d in self._lru.values() if d)
        self._lru.clear()
        self._weight_used = 0
        return lost

    @property
    def occupancy_lines(self) -> int:
        return self._weight_used

    def dirty_entries(self, name: str) -> Iterator[int]:
        for (n, entry), dirty in self._lru.items():
            if n == name and dirty:
                yield entry


class CrashEmulator:
    """Couples program arrays with the cache+NVM pair (paper's crash
    emulator). Allocate regions, compute on their ``.view`` arrays through
    :class:`PersistentRegion` (see regions.py), then ``crash()`` to lose
    volatile state and ``post_crash_view()`` to inspect what survived.
    """

    def __init__(self, cfg: Optional[NVMConfig] = None):
        self.cfg = cfg or NVMConfig()
        self.store = NVMStore(self.cfg)
        self.cache = VolatileCache(self.store, self.cfg)
        self._truth: Dict[str, np.ndarray] = {}
        self.crashed = False

    # region management ------------------------------------------------------
    def alloc(self, name: str, shape, dtype=np.float64,
              init: Optional[np.ndarray] = None, sector_lines: int = 1):
        from .regions import PersistentRegion  # local to avoid cycle

        shape = tuple(int(s) for s in shape)
        self.store.alloc(name, shape, dtype)
        truth = np.zeros(int(np.prod(shape)), dtype=np.dtype(dtype))
        self._truth[name] = truth
        self.cache.register(name, truth, sector_lines=sector_lines)
        region = PersistentRegion(self, name, shape, np.dtype(dtype))
        if init is not None:
            region[...] = np.asarray(init, dtype=dtype).reshape(shape)
        return region

    def free(self, name: str) -> None:
        self.cache.unregister(name)
        self.store.free(name)
        self._truth.pop(name, None)

    # crash / recovery ---------------------------------------------------------
    def crash(self) -> int:
        """Drop the volatile cache; reload every truth array from the NVM
        image (the program must now see only what survived)."""
        lost = self.cache.crash()
        for name, truth in self._truth.items():
            truth[:] = self.store.image[name]
        self.crashed = True
        return lost

    def post_crash_view(self, name: str) -> np.ndarray:
        return self.store.read_view(name)

    def truth_flat(self, name: str) -> np.ndarray:
        return self._truth[name]

    # stats -------------------------------------------------------------------
    @property
    def stats(self) -> TrafficStats:
        return self.store.stats

    def modeled_seconds(self) -> float:
        return self.store.stats.modeled_seconds
