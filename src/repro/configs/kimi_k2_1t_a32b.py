"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config):
61L, d_model 7168, GQA kv=8, 384 routed experts top-8 (+1 shared),
expert d_ff=2048. [arXiv:2501.kimi2; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=0, vocab_size=163_840, head_dim=128,
    n_experts=384, experts_per_token=8, n_shared_experts=1, moe_d_ff=2048,
    rope_theta=50_000.0,
)
