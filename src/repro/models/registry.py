"""Architecture registry: ``--arch <id>`` -> (ModelConfig, ModelApi)."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

from ..configs.base import ModelConfig

__all__ = ["ModelApi", "build_model", "get_config", "list_archs", "ARCHS"]

# arch id -> config module (each exposes CONFIG: ModelConfig)
ARCHS = {
    "granite-8b": "repro.configs.granite_8b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "llama3-8b": "repro.configs.llama3_8b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "mamba2-130m": "repro.configs.mamba2_130m",
}


@dataclasses.dataclass
class ModelApi:
    """Uniform functional interface over every architecture family."""

    cfg: ModelConfig
    init: Callable                 # key -> (params, axes)
    abstract_init: Callable        # key -> (ShapeDtypeStruct params, axes)
    forward: Callable              # (params, batch, mesh=None, remat=...) -> logits
    loss_fn: Callable              # (params, batch, mesh=None, remat=...) -> loss
    init_cache: Optional[Callable]  # (batch, max_len) -> (cache, axes)
    decode_step: Optional[Callable]  # (params, cache, tokens, pos, mesh) -> ...


def _lm_api(cfg: ModelConfig) -> ModelApi:
    from . import lm
    return ModelApi(
        cfg=cfg,
        init=lambda key: lm.init(cfg, key),
        abstract_init=lambda key: lm.abstract_init(cfg, key),
        forward=lambda p, b, mesh=None, remat="none", flash=False:
        lm.forward(cfg, p, b, mesh, remat=remat, flash=flash),
        loss_fn=lambda p, b, mesh=None, remat="none": lm.loss_fn(
            cfg, p, b, mesh, remat=remat),
        init_cache=(None if not cfg.is_decoder else
                    (lambda batch, max_len: lm.init_cache(cfg, batch, max_len))),
        decode_step=(None if not cfg.is_decoder else
                     (lambda p, c, t, pos, mesh=None: lm.decode_step(
                         cfg, p, c, t, pos, mesh))),
    )


def _ssm_api(cfg: ModelConfig) -> ModelApi:
    from . import ssm_lm
    return ModelApi(
        cfg=cfg,
        init=lambda key: ssm_lm.init(cfg, key),
        abstract_init=lambda key: ssm_lm.abstract_init(cfg, key),
        forward=lambda p, b, mesh=None, remat="none": ssm_lm.forward(
            cfg, p, b, mesh, remat=remat),
        loss_fn=lambda p, b, mesh=None, remat="none": ssm_lm.loss_fn(
            cfg, p, b, mesh, remat=remat),
        init_cache=lambda batch, max_len: ssm_lm.init_cache(cfg, batch, max_len),
        decode_step=lambda p, c, t, pos, mesh=None: ssm_lm.decode_step(
            cfg, p, c, t, pos, mesh),
    )


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    from . import hybrid
    return ModelApi(
        cfg=cfg,
        init=lambda key: hybrid.init(cfg, key),
        abstract_init=lambda key: hybrid.abstract_init(cfg, key),
        forward=lambda p, b, mesh=None, remat="none": hybrid.forward(
            cfg, p, b, mesh, remat=remat),
        loss_fn=lambda p, b, mesh=None, remat="none": hybrid.loss_fn(
            cfg, p, b, mesh, remat=remat),
        init_cache=lambda batch, max_len: hybrid.init_cache(cfg, batch, max_len),
        decode_step=lambda p, c, t, pos, mesh=None: hybrid.decode_step(
            cfg, p, c, t, pos, mesh),
    )


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch]).CONFIG


def build_model(cfg_or_arch) -> ModelApi:
    cfg = (get_config(cfg_or_arch) if isinstance(cfg_or_arch, str)
           else cfg_or_arch)
    if cfg.family == "ssm":
        return _ssm_api(cfg)
    if cfg.family == "hybrid":
        return _hybrid_api(cfg)
    return _lm_api(cfg)


def list_archs():
    return sorted(ARCHS)
