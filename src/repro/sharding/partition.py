"""Logical-axis sharding rules -> NamedSharding (t5x-style).

Every model init returns an ``axes`` tree mirroring the params with
tuples of logical dim names; this module maps those names onto mesh axes
and builds the in/out shardings consumed by jit. Rules compose DP /
FSDP(ZeRO) / TP / EP / SP (see DESIGN.md §5):

  batch       -> ("pod", "data")   DP over pods x data
  embed       -> "data" iff fsdp   ZeRO parameter sharding
  qheads/mlp/vocab/experts/ssm_inner -> "model"   TP / EP
  kvheads     -> replicated        (KV heads < TP degree in all archs)
  seq         -> "data" iff sp     sequence parallelism for long prefill

KV-cache activations shard batch over ("pod","data") and heads over
"model" where divisible.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["PartitionRules", "make_rules", "spec_for_axes", "params_shardings",
           "batch_shardings", "cache_shardings", "logical_to_spec"]


@dataclasses.dataclass(frozen=True)
class PartitionRules:
    """logical dim name -> mesh axis (or None = replicate)."""

    table: Dict[str, Optional[object]]
    mesh: Mesh

    def spec(self, axes: Tuple[str, ...]) -> P:
        entries = []
        used = set()
        for name in axes:
            ax = self.table.get(name)
            # a mesh axis may appear only once per spec (e.g. experts and
            # mlp_e both map to "model": the first wins, rest replicate)
            if ax is None or ax in used or (isinstance(ax, tuple) and
                                            any(a in used for a in ax)):
                entries.append(None)
                continue
            if isinstance(ax, tuple):
                for a in ax:
                    used.add(a)
            else:
                used.add(ax)
            entries.append(ax)
        return P(*entries)


def make_rules(mesh: Mesh, *, fsdp: bool = True, sp: bool = False,
               kv_cache_heads_shardable: bool = False,
               shard_cache_seq: bool = False,
               shard_ssm_heads: bool = False,
               replicate_attn_heads: bool = False) -> PartitionRules:
    """Build the logical->mesh table.

    kv_cache_heads_shardable: KV-cache head dim divisible by TP degree
        (checked by the caller per-arch) -> shard cache heads on "model".
    shard_cache_seq: shard the KV-cache *sequence* dim over "data" —
        used for long-context decode where batch < DP degree.
    shard_ssm_heads: SSM state head dim divisible by TP degree.
    """
    has_pod = "pod" in mesh.axis_names
    dp = ("pod", "data") if has_pod else ("data",)
    table = {
        # --- weights -------------------------------------------------------
        "embed": "data" if fsdp else None,   # ZeRO/FSDP param shard
        # decode replicates attention heads: per-step attention weight
        # reads are tiny, and sharded heads force cache gathers (§Perf
        # iteration 6)
        "qheads": None if replicate_attn_heads else "model",
        "kvheads": None,                     # KV heads < TP in all archs
        "mlp": "model",
        "mlp_e": None,                       # expert FFN dim: EP already on "model"
        "vocab": "model",
        "experts": "model",                  # EP
        "experts_r": None,                   # router output dim (small)
        "kv_lora": None,
        "layers": None,
        "ssm_inner": "model",                # mamba out_proj contraction dim
        "ssm_proj": None,                    # mixed z|x|B|C|dt projection dim
        "ssm_conv": None,
        "ssm_heads": "model" if shard_ssm_heads else None,
        "conv_width": None,
        "head_dim": None,
        "state": None,
        # --- activations / caches ------------------------------------------
        "batch": dp,
        "seq": "data" if sp else None,
        "seq_cache": "data" if shard_cache_seq else None,
        "kvheads_sep": "model" if kv_cache_heads_shardable else None,
        "shared_sites": None,
    }
    if shard_cache_seq:
        # long-context decode: batch (=1) cannot shard over DP — the
        # cache sequence dim carries the data axis instead
        table["batch"] = None
    return PartitionRules(table=table, mesh=mesh)


def logical_to_spec(rules: PartitionRules, axes_tree):
    is_axes = lambda t: (isinstance(t, tuple)
                         and all(isinstance(s, str) for s in t))
    return jax.tree.map(lambda t: rules.spec(t), axes_tree, is_leaf=is_axes)


def params_shardings(rules: PartitionRules, axes_tree):
    specs = logical_to_spec(rules, axes_tree)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(rules: PartitionRules, batch_tree, *,
                    shard_seq: bool = False):
    """Shard every batch leaf's leading batch dim over DP (and optionally
    the second (sequence) dim over 'data' for SP prefill). The vlm
    ``positions`` leaf is (3, B, S): batch is dim 1."""
    mesh = rules.mesh
    dp = rules.table["batch"]

    def spec_for(leaf):
        nd = len(leaf.shape)
        if nd == 3 and leaf.shape[0] == 3:         # vlm positions (3,B,S)
            return P(None, dp)
        entries = [dp] + [None] * (nd - 1)
        return P(*entries)

    return jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)),
                        batch_tree)


def cache_shardings(rules: PartitionRules, cache_axes):
    return params_shardings(rules, cache_axes)
