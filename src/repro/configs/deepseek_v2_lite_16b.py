"""deepseek-v2-lite-16b — MoE with MLA (kv_lora=512), 64 routed experts
top-6 + 2 shared, expert d_ff=1408. [arXiv:2405.04434; hf]

Note (DESIGN.md #4): the assignment sheet's primary spec says 64 routed
experts; the bracket note "160 routed" conflicts and the primary spec
wins. Every layer is MoE (the real model's first dense layer is omitted
for a uniform scanned stack; parameter deviation < 1%).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab_size=102_400,
    n_experts=64, experts_per_token=6, n_shared_experts=2, moe_d_ff=1408,
    use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
)
