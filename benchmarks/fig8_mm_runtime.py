"""Paper Fig. 8: ABFT-MM runtime across mechanisms, for three rank sizes.

Per rank k (paper: 200/400/1000 at n=8000; scaled here), mechanisms are
charged per submatrix-multiplication iteration: checkpoint copies the
whole C_f; PMEM logs every dirtied line of C_f; ADCC flushes only the
checksum row + column. Larger rank => fewer flushes => smaller ADCC
overhead (paper: 8.2% at rank 200 -> 1.3% at rank 1000)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.nvm import NVMConfig

from .common import Row, emit, timeit

N = 1024
RANKS = [128, 256, 512]


def _native_chunk_seconds(n: int, k: int) -> float:
    rng = np.random.default_rng(0)
    A = rng.uniform(-1, 1, (n + 1, k))
    B = rng.uniform(-1, 1, (k, n + 1))
    return timeit(lambda: A @ B, repeats=3)


def _mech_per_chunk(case: str, n: int, cfg: NVMConfig) -> float:
    cf_bytes = (n + 1) * (n + 1) * 8
    line = cfg.line_bytes
    if case == "native":
        return 0.0
    if case == "ckpt_hdd":
        return cf_bytes / cfg.hdd_bw
    if case == "ckpt_nvm_only":
        return cf_bytes / cfg.write_bw + (cf_bytes / line) * cfg.flush_latency
    if case == "ckpt_nvm_dram":
        return (cf_bytes / cfg.write_bw + (cf_bytes / line) * cfg.flush_latency
                + cfg.dram_cache_bytes / cfg.dram_bw
                + cfg.dram_cache_bytes / cfg.write_bw)
    if case == "pmem_undo":
        return 2 * (cf_bytes / cfg.write_bw
                    + (cf_bytes / line) * cfg.flush_latency)
    if case == "adcc":
        cs_bytes = 2 * (n + 1) * 8      # checksum row + column
        return cs_bytes / cfg.write_bw + (cs_bytes / line) * cfg.flush_latency
    raise ValueError(case)


def run() -> List[Row]:
    rows = []
    nvm_only = NVMConfig(nvm_same_as_dram=True)
    nvm_dram = NVMConfig()
    for k in RANKS:
        chunk_s = _native_chunk_seconds(N, k)
        rows.append(Row(f"fig8/mm_runtime/rank={k}/native_chunk_seconds",
                        chunk_s))
        for case, cfg in [("native", nvm_only), ("ckpt_hdd", nvm_only),
                          ("ckpt_nvm_only", nvm_only),
                          ("ckpt_nvm_dram", nvm_dram),
                          ("pmem_undo", nvm_only),
                          ("adcc_nvm_only", nvm_only),
                          ("adcc_nvm_dram", nvm_dram)]:
            base = ("adcc" if case.startswith("adcc") else case)
            mech = _mech_per_chunk(base, N, cfg)
            rows.append(Row(f"fig8/mm_runtime/rank={k}/{case}/normalized",
                            (chunk_s + mech) / chunk_s,
                            f"mech={mech*1e3:.3f}ms"))
    return rows


def main() -> None:
    emit(run(), save_as="fig8_mm_runtime.json")


if __name__ == "__main__":
    main()
