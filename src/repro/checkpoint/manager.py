"""Mesh-agnostic checkpointing with elastic restore.

Checkpoints store *global* (unsharded) arrays plus the logical-axes
metadata, never device layouts — so a run saved on an N-device mesh
restores onto an M-device mesh (elastic scaling after losing/gaining
pods): ``restore`` re-applies the partition rules of the *target* mesh
and ``jax.device_put``s each global array against its new
NamedSharding. Complements the ADCC slot store (core/slots.py), which
is the fast intra-job recovery tier; this is the durable cross-job tier.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..core.slots import flatten_state, unflatten_state
from ..sharding.partition import PartitionRules, params_shardings

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_elastic"]


def save_checkpoint(path: str, state, step: int,
                    extra_meta: Optional[Dict] = None) -> None:
    """state: any pytree of arrays (will be fetched to host as global
    numpy arrays)."""
    os.makedirs(path, exist_ok=True)
    flat = flatten_state(jax.tree.map(np.asarray, state))
    np.savez(os.path.join(path, "state.npz"),
             **{k.replace("/", "__"): v for k, v in flat.items()})
    meta = {"step": step, "n_leaves": len(flat)}
    meta.update(extra_meta or {})
    with open(os.path.join(path, "meta.json"), "w") as fh:
        json.dump(meta, fh)


def restore_checkpoint(path: str, template) -> Tuple[Any, Dict]:
    """Rebuild the pytree on host (numpy). Template supplies structure."""
    with np.load(os.path.join(path, "state.npz")) as z:
        flat = {k.replace("__", "/"): z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as fh:
        meta = json.load(fh)
    return unflatten_state(template, flat), meta


def restore_elastic(path: str, template, rules: PartitionRules,
                    axes_tree) -> Tuple[Any, Dict]:
    """Restore onto a *different* mesh: device_put every global array
    against the sharding derived from the target mesh's rules."""
    host_state, meta = restore_checkpoint(path, template)
    shardings = params_shardings(rules, axes_tree)
    placed = jax.tree.map(jax.device_put, host_state, shardings)
    return placed, meta
