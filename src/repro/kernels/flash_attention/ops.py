"""jit'd wrapper: (B, S, H, hd) GQA attention via the flash kernel.

Forward-only (prefill/serving). Pads S to the block size; GQA handled by
the kernel's index maps (no KV repeat materialization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..abft_matmul.ops import on_tpu
from .kernel import flash_attention_pallas

__all__ = ["flash_attention"]


def _pick_block(S: int, target: int = 512) -> int:
    for cand in (target, 256, 128, 64, 32, 16, 8):
        if S % cand == 0 and cand <= S:
            return cand
    return S


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def _impl(q, k, v, *, causal: bool, interpret: bool):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    groups = H // KV
    bq = bk = _pick_block(S)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    out = flash_attention_pallas(qf, kf, vf, groups=groups, causal=causal,
                                 bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal: bool = True,
                    interpret: bool | None = None):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd), H % KV == 0."""
    if interpret is None:
        interpret = not on_tpu()
    return _impl(q, k, v, causal=causal, interpret=interpret)
