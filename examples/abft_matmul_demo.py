"""Paper §III.C end to end: ABFT matrix multiplication with ADCC.

1. Runs the two-loop checksum-extended MM (Fig. 6) under the crash
   emulator, crashes mid-loop-1 and mid-loop-2, and recovers via
   checksum verification (+ recomputation of torn chunks).
2. Shows single-element error *correction* from checksums alone.
3. Runs the fused-epilogue Pallas kernel (TPU target, interpret mode on
   CPU) and verifies its checksums against the jnp oracle.

    PYTHONPATH=src python examples/abft_matmul_demo.py
"""

import numpy as np

from repro.core import abft
from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, run_scenario


def crash_demo() -> None:
    n, k = 512, 128
    for loop in ("loop1", "loop2"):
        res = run_scenario(("mm", {"n": n, "k": k, "seed": 0}), "adcc",
                           CrashPlan.at_phase(loop, 2),
                           cfg=NVMConfig(cache_bytes=2 * 1024 * 1024))
        print(f"== crash in {loop}: {res.info['chunks_lost']} chunk(s) torn, "
              f"{res.info['corrected_elements']} element(s) "
              f"checksum-corrected, "
              f"final |C - A@B|_max = {res.metrics['max_error']:.2e}")


def correction_demo() -> None:
    rng = np.random.default_rng(1)
    C = rng.uniform(-1, 1, (64, 64))
    Cf = abft.encode_full(C)
    Cf[17, 42] += 3.14159          # single corrupted element
    fixed, nfix = abft.correct_single_error(Cf)
    print(f"== single-error correction: fixed {nfix} element, "
          f"recovered exactly: {np.allclose(fixed, abft.encode_full(C))}")


def kernel_demo() -> None:
    import jax.numpy as jnp
    from repro.kernels.abft_matmul.ops import abft_matmul_full
    from repro.kernels.checksum_verify.ops import verify_checksums
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(192, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 160)), jnp.float32)
    cf = abft_matmul_full(a, b)           # Pallas fused epilogue
    ok, _, _ = verify_checksums(cf)       # Pallas detection kernel
    print(f"== Pallas fused-checksum matmul: C_f {cf.shape}, "
          f"checksums verify: {bool(ok)}")
    bad = cf.at[5, 7].add(10.0)
    ok2, rres, cres = verify_checksums(bad)
    import jax.numpy as jnp2
    print(f"== tampered element detected at row "
          f"{int(jnp2.argmax(jnp2.abs(rres)))}, col "
          f"{int(jnp2.argmax(jnp2.abs(cres)))} (truth: 5, 7)")


def main() -> None:
    crash_demo()
    correction_demo()
    kernel_demo()


if __name__ == "__main__":
    main()
