"""Sweep execution comparison — the ``sweep`` suite.

Times a dense one-crash-point-per-step matrix (3 workloads × 3
strategies × (no_crash + at_every_step)) under the rerun engine, the
fork engine, fork + mode="measure", and a pair-sharded parallel measure
run, plus the fig_torn dense torn matrix under measure vs
fork+mode="batched", plus a single-pair dense matrix point-sharded
across 4 workers and re-swept under a 1-byte snapshot budget (spill
and recompute tier policies); writes ``BENCH_sweep.json`` (and the
standalone ``BENCH_batched.json``) with per-run seconds + speedups,
and fails on any divergence gate (fork/rerun, measure/fork,
workers>1/workers=1, batched/measure, point-sharded/serial,
budgeted/unbudgeted) or an unexercised tier-eviction path.

    PYTHONPATH=src python -m benchmarks.sweep_timing            # full
    PYTHONPATH=src python -m benchmarks.sweep_timing --smoke    # CI

The matrix definitions and comparison logic live in
benchmarks/scenarios_sweep.py (``engine_timing`` / ``run_timing``);
this module is the registered suite entry point.
"""

from __future__ import annotations

from typing import List

from .common import Row, emit
from .scenarios_sweep import BENCH_SWEEP_JSON, run_timing  # noqa: F401

ARTIFACT = "sweep_timing.json"


def run(smoke: bool = None, workers: int = None) -> List[Row]:
    return run_timing(smoke, workers)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized dense matrix")
    ap.add_argument("--workers", type=int, default=None,
                    help="processes for the sharded run "
                         "(default: REPRO_SWEEP_WORKERS or 2)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke or None, workers=args.workers),
         save_as=ARTIFACT)
