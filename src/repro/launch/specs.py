"""Input builders: concrete batches (smoke/train) and ShapeDtypeStruct
stand-ins (dry-run) for every architecture family x shape cell.

The modality frontends of [audio]/[vlm] archs are STUBS per the
assignment: ``frames`` / ``patches`` arrive as precomputed embeddings.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig

__all__ = ["make_batch", "batch_specs", "decode_specs", "vlm_split"]


def vlm_split(cfg: ModelConfig, seq: int) -> Tuple[int, int]:
    """(n_patches, n_text) for a vlm sequence of total length ``seq``."""
    p = min(cfg.n_patches, seq // 2)
    return p, seq - p


def _vlm_positions(cfg: ModelConfig, batch: int, seq: int) -> np.ndarray:
    """M-RoPE position streams: patches get (t=0, h, w) grid positions,
    text continues sequentially on all three streams."""
    p, t = vlm_split(cfg, seq)
    side = max(1, int(np.sqrt(p)))
    pos = np.zeros((3, seq), np.int32)
    idx = np.arange(p)
    pos[0, :p] = 0
    pos[1, :p] = idx // side
    pos[2, :p] = idx % side
    text_pos = side + np.arange(t)
    pos[:, p:] = text_pos[None, :]
    return np.broadcast_to(pos[:, None, :], (3, batch, seq))


def make_batch(cfg: ModelConfig, batch: int, seq: int, key) -> Dict:
    """Concrete batch for training/prefill."""
    ks = jax.random.split(key, 3)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                        jnp.float32),
            "labels": jax.random.randint(ks[1], (batch, seq), 0,
                                         cfg.vocab_size, jnp.int32),
        }
    if cfg.family == "vlm":
        p, t = vlm_split(cfg, seq)
        labels = jax.random.randint(ks[1], (batch, t), 0, cfg.vocab_size,
                                    jnp.int32)
        return {
            "tokens": jax.random.randint(ks[0], (batch, t), 0,
                                         cfg.vocab_size, jnp.int32),
            "patches": jax.random.normal(ks[2], (batch, p, cfg.d_model),
                                         jnp.float32),
            "positions": jnp.asarray(_vlm_positions(cfg, batch, seq)),
            "labels": jnp.concatenate(
                [jnp.full((batch, p), -100, jnp.int32), labels], axis=1),
        }
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    labels = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    return {"tokens": tokens, "labels": labels}


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for train/prefill lowering."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if cfg.family == "audio":
        return {"frames": sds((B, S, cfg.d_model), f32),
                "labels": sds((B, S), i32)}
    if cfg.family == "vlm":
        p, t = vlm_split(cfg, S)
        return {"tokens": sds((B, t), i32),
                "patches": sds((B, p, cfg.d_model), f32),
                "positions": sds((3, B, S), i32),
                "labels": sds((B, S), i32)}
    return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 init_cache) -> Tuple[Dict, object, object]:
    """(token specs, cache specs, pos spec) for serve_step lowering.
    ``init_cache(batch, max_len)`` is the arch's cache builder; it is
    evaluated abstractly (eval_shape) so nothing is allocated."""
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: init_cache(B, S)[0])
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"tokens": tokens}, cache_shapes, pos
