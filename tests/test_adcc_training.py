"""ADCC training-state integration tests: ledger invariants, torn-slot
rejection, crash/restart bitwise recovery, elastic restore, optimizer and
compression substrates."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.acc_state import (ChecksumLedger, LedgerRecord,
                                  verify_state_against_record)
from repro.core.slots import SlotStore, flatten_state, unflatten_state
from repro.launch.train import ADCCTrainer, StragglerMonitor
from repro.models.registry import get_config


def tiny_trainer(workdir, mode="adcc", slot_every=6, optimizer="adamw"):
    cfg = get_config("llama3-8b").reduced()
    tcfg = TrainConfig(remat="none", total_steps=40, warmup_steps=5,
                       optimizer=optimizer)
    return ADCCTrainer(cfg, tcfg, workdir, batch=4, seq=32,
                       slot_every=slot_every, mode=mode)


class TestLedger:
    def test_append_and_read(self, tmp_path):
        led = ChecksumLedger(str(tmp_path / "l.jsonl"))
        for t in range(3):
            led.append(LedgerRecord(step=t, rng_seed=0, cursor=[0, t + 1, 0],
                                    cks_params=[1.0 * t], cks_opt=[2.0 * t],
                                    cks_updates=[1.0 if t else 0.0],
                                    loss=1.0))
        led.close()
        assert len(led.read_all()) == 3

    def test_torn_tail_line_discarded(self, tmp_path):
        path = str(tmp_path / "l.jsonl")
        led = ChecksumLedger(path)
        led.append(LedgerRecord(0, 0, [0, 1, 0], [1.0], [0.0], [0.0], 1.0))
        led.close()
        with open(path, "a") as fh:
            fh.write('{"step": 1, "rng_seed": 0, "cursor": [0,2,0], "cks_p')
        assert len(ChecksumLedger(path).read_all()) == 1

    def test_linearity_chain_breaks_on_corruption(self, tmp_path):
        led = ChecksumLedger(str(tmp_path / "l.jsonl"))
        cks = 10.0
        for t in range(5):
            upd = 0.5
            cks_rec = cks + upd if t != 3 else cks + 99.0  # corrupt step 3
            led.append(LedgerRecord(t, 0, [0, t + 1, 0], [cks_rec], [0.0],
                                    [upd], 1.0))
            cks = cks + upd
        led.close()
        good = led.validated_records()
        assert [r.step for r in good] == [0, 1, 2]

    def test_verify_state_against_record(self):
        params = {"w": jnp.ones((4, 4))}
        opt = {"m": jnp.zeros((4, 4))}
        rec = LedgerRecord(0, 0, [0, 1, 0], [16.0], [0.0], [0.0], 1.0)
        ok, bad = verify_state_against_record(params, opt, rec)
        assert ok and bad == 0
        rec_bad = LedgerRecord(0, 0, [0, 1, 0], [17.0], [0.0], [0.0], 1.0)
        ok, bad = verify_state_against_record(params, opt, rec_bad)
        assert not ok and bad == 1


class TestSlots:
    def _state(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"a": jax.random.normal(k, (8, 8)),
                           "b": jnp.arange(4.0) + seed}}

    def test_roundtrip(self, tmp_path):
        store = SlotStore(str(tmp_path), n_slots=2)
        state = self._state()
        store.write_slot(0, 5, flatten_state(state))
        flat = store.read_slot(0)
        rebuilt = unflatten_state(state, flat)
        assert np.allclose(rebuilt["params"]["a"], state["params"]["a"])

    def test_torn_write_detectable(self, tmp_path):
        store = SlotStore(str(tmp_path), n_slots=2)
        s1 = self._state(seed=1)
        store.write_slot(0, 5, flatten_state(s1))
        s2 = self._state(seed=2)
        store.write_slot(0, 9, flatten_state(s2), tear_after=1)  # torn!
        flat = store.read_slot(0)
        rebuilt = unflatten_state(s1, flat)
        # mixed generations: checksum verification must reject
        sums = [float(jnp.sum(x)) for x in jax.tree.leaves(rebuilt)]
        want = [float(jnp.sum(x)) for x in jax.tree.leaves(s2)]
        assert not np.allclose(sums, want)

    def test_recency_order(self, tmp_path):
        store = SlotStore(str(tmp_path), n_slots=3)
        for k, step in [(0, 3), (1, 7), (2, 5)]:
            store.write_slot(k, step, flatten_state(self._state(step)))
        assert store.slots_by_recency() == [(1, 7), (2, 5), (0, 3)]


class TestCrashRestart:
    def test_bitwise_recovery(self, tmp_path):
        ref_dir, crash_dir = str(tmp_path / "ref"), str(tmp_path / "crash")
        ref = tiny_trainer(ref_dir)
        r_ref = ref.run(24, log_every=0)

        tr1 = tiny_trainer(crash_dir)
        tr1.run(24, crash_at_step=15, log_every=0)
        tr2 = tiny_trainer(crash_dir)
        r2 = tr2.run(24, log_every=0)
        assert r2.resumed_from is not None and r2.resumed_from >= 5
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            ref._final_params, tr2._final_params)
        assert max(jax.tree.leaves(diffs)) == 0.0

    def test_recovery_skips_torn_slot(self, tmp_path):
        wd = str(tmp_path / "t")
        tr1 = tiny_trainer(wd, slot_every=4)
        tr1.run(20, crash_at_step=18, log_every=0)
        # corrupt the newest slot's first tensor (simulate torn write)
        store = tr1.store
        newest_slot, newest_step = store.slots_by_recency()[0]
        d = store.slot_dir(newest_slot)
        fn = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
        arr = np.load(os.path.join(d, fn))
        arr = arr + 1000.0
        np.save(os.path.join(d, fn), arr)

        tr2 = tiny_trainer(wd, slot_every=4)
        r2 = tr2.run(20, log_every=0)
        # must have recovered from an OLDER slot than the corrupted one
        assert r2.resumed_from is not None
        assert r2.resumed_from < newest_step

    def test_sync_mode_also_recovers(self, tmp_path):
        wd = str(tmp_path / "s")
        tr1 = tiny_trainer(wd, mode="sync", slot_every=4)
        tr1.run(16, crash_at_step=12, log_every=0)
        tr2 = tiny_trainer(wd, mode="sync", slot_every=4)
        r2 = tr2.run(16, log_every=0)
        assert r2.resumed_from is not None


class TestElasticCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        from repro.checkpoint.manager import (restore_checkpoint,
                                              save_checkpoint)
        state = {"w": jnp.ones((8, 16)), "step": jnp.int32(7)}
        save_checkpoint(str(tmp_path / "ck"), state, step=7)
        restored, meta = restore_checkpoint(str(tmp_path / "ck"), state)
        assert meta["step"] == 7
        assert np.allclose(restored["w"], 1.0)

    def test_elastic_restore_new_mesh(self, tmp_path):
        from repro.checkpoint.manager import restore_elastic, save_checkpoint
        from repro.launch.mesh import single_device_mesh
        from repro.sharding.partition import make_rules
        state = {"w": jnp.ones((8, 16))}
        axes = {"w": ("embed", "mlp")}
        save_checkpoint(str(tmp_path / "ck"), state, step=3)
        mesh = single_device_mesh()
        rules = make_rules(mesh, fsdp=True)
        placed, meta = restore_elastic(str(tmp_path / "ck"), state, rules,
                                       axes)
        assert np.allclose(np.asarray(placed["w"]), 1.0)


class TestOptim:
    def test_adafactor_trains(self, tmp_path):
        tr = tiny_trainer(str(tmp_path / "af"), optimizer="adafactor")
        res = tr.run(12, log_every=0)
        assert np.isfinite(res.losses).all()

    def test_adafactor_3d_params(self):
        """Regression: factored stats broadcasting for stacked (L, D, F)
        params (the kimi-k2 train_4k failure)."""
        from repro.optim.adamw import adafactor_init, adafactor_update
        tcfg = TrainConfig(optimizer="adafactor")
        params = {"w": jnp.ones((6, 16, 8))}
        grads = {"w": jnp.full((6, 16, 8), 0.1)}
        state = adafactor_init(params)
        upd, state = adafactor_update(tcfg, grads, state, params)
        assert upd["w"].shape == (6, 16, 8)
        assert bool(jnp.all(jnp.isfinite(upd["w"])))

    def test_int8_compression_error_feedback(self):
        from repro.optim.compression import (compress_decompress,
                                             init_error_state)
        key = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(key, (64, 64))}
        err = init_error_state(g)
        # accumulate compressed grads over many rounds: with error
        # feedback the *mean* compressed signal converges to the truth
        total_c = jnp.zeros((64, 64))
        for i in range(64):
            gc, err = compress_decompress(g, err, jax.random.fold_in(key, i))
            total_c = total_c + gc["w"]
        rel = float(jnp.linalg.norm(total_c / 64 - g["w"])
                    / jnp.linalg.norm(g["w"]))
        assert rel < 0.02, rel


class TestStraggler:
    def test_flags_outliers(self):
        mon = StragglerMonitor(window=16, threshold=2.0)
        for t in range(20):
            flagged = mon.record(t, 1.0 if t != 15 else 5.0)
            if t == 15:
                assert flagged
        assert mon.flagged_steps == [15]

    def test_no_false_positives_on_uniform(self):
        mon = StragglerMonitor()
        for t in range(50):
            assert not mon.record(t, 1.0 + 0.01 * (t % 3))
