"""Paper Fig. 7: ABFT-MM recomputation cost for crashes in loop 1
(submatrix multiplication) and loop 2 (submatrix addition), across
matrix sizes — a declarative scenario matrix (ADCC strategy ×
``CrashPlan.at_every_step()``), so BOTH loops are enumerated at every
crash step rather than sampled at one index per loop. Runs through
``sweep(mode="measure")``: each cell is restore + crash + ADCC recovery
(which itself recomputes the lost chunks/blocks — that IS the measured
cost), with no tail re-execution. Expect: large matrices lose <= 1
chunk/row-block at every crash point.

``--smoke`` shrinks the size axis for CI; every run — smoke or full —
passes the dense-matrix gates (parallel==serial, every full-execution
cell correct, measure==fork) — ``scenarios_sweep.check_dense_gates``.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, make_workload, sweep

from .common import Row

ARTIFACT = "fig7_mm_recompute.json"

SIZES = [256, 512, 768, 1024]
SMOKE_SIZES = [64, 128]

PLANS = (CrashPlan.no_crash(), CrashPlan.at_every_step())


def _workloads(sizes: Sequence[int]) -> Tuple:
    return tuple(("mm", {"n": n, "k": n // 4, "seed": n}) for n in sizes)


def _cfg() -> NVMConfig:
    return NVMConfig(cache_bytes=4 * 1024 * 1024)


def _sweep_kw(smoke: bool) -> Dict:
    sizes = SMOKE_SIZES if smoke else SIZES
    return dict(workloads=_workloads(sizes), strategies=("adcc",),
                plans=PLANS, cfg=_cfg())


def _phase_of(spec, cfg: NVMConfig) -> Dict[int, str]:
    """step index -> "loop1"/"loop2" for one adcc-mode MM workload."""
    probe = make_workload(spec)
    probe.setup(cfg, "adcc")
    return {s: name for name, rng in probe.phases().items() for s in rng}


def run(smoke: bool = None, workers: int = None,
        mode: str = "measure") -> List[Row]:
    from .scenarios_sweep import check_dense_gates, resolve_sweep_env

    smoke, workers = resolve_sweep_env(smoke, workers)
    kw = _sweep_kw(smoke)
    cells = sweep(mode=mode, workers=workers, **kw)
    # with mode="batched" the same gate stack pins the batched cells
    # against a fresh measure-mode sweep cell-for-cell.
    # all gates at every size; ABFT recovery is exact (checksum
    # correction, not approximate restart), so the strict correctness
    # assert holds at full sizes too — unlike fig3
    check_dense_gates(kw, cells, workers, strict_correct=True)

    rows = []
    for spec in kw["workloads"]:
        n = spec[1]["n"]
        phase_of = _phase_of(spec, kw["cfg"])
        mine = [c for c in cells if c.workload_params.get("n") == n]
        baseline = [c for c in mine if c.crash_step is None]
        assert baseline and all(c.correct for c in baseline), \
            (n, "no_crash baseline must finalize correct")
        crashed = [c for c in mine if c.crash_step is not None]
        assert [c.crash_step for c in crashed] == sorted(phase_of), \
            (n, "dense curve must cover every step of both loops")
        by_loop: Dict[str, List[float]] = {"loop1": [], "loop2": []}
        for c in crashed:
            loop = phase_of[c.crash_step]
            norm = ((c.detect_seconds + c.resume_seconds)
                    / max(c.avg_step_seconds, 1e-12))
            by_loop[loop].append(c.steps_lost)
            rows.append(Row(
                f"fig7/mm_recompute/n={n}/{loop}/crash={c.crash_step}"
                f"/chunks_lost",
                c.steps_lost,
                f"class={c.correctness_class} "
                f"corrected={c.info.get('corrected_elements', 0)}"))
            rows.append(Row(
                f"fig7/mm_recompute/n={n}/{loop}/crash={c.crash_step}"
                f"/normalized_recompute",
                norm, f"detect={c.detect_seconds:.4f}s"))
        for loop, lost in by_loop.items():
            rows.append(Row(f"fig7/mm_recompute/n={n}/{loop}/max_chunks_lost",
                            max(lost), f"crash_points={len(lost)}"))
    return rows


def main(argv=None) -> None:
    from .common import dense_figure_cli
    dense_figure_cli(run, ARTIFACT, argv)


if __name__ == "__main__":
    main()
