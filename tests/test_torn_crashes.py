"""Sub-step torn-write crash images: line survival from backend to sweep.

Covers the full stack the TornSpec refactor touches:

  * ``select_survivors`` — the one shared survivor-selection routine
    (count rounding, eviction-prefix vs seeded-random modes, validation);
  * backend equivalence — randomized traces with seeded survival crashes
    must leave reference and vectorized backends byte-identical (images,
    stats including the torn-persist counters, dirty sets, truth);
  * emulator semantics — fraction 1.0 persists everything, fraction 0.0
    is bit-identical to the classic all-or-nothing crash, eviction mode
    persists queue-front lines first, crashes stay free in modeled time;
  * TornSpec resolution — reproducible, sample-expanded, distinct
    derived seeds; bare ``torn=True`` cells unchanged;
  * engine/mode invariance — fork == rerun == measure cell-for-cell on
    torn line-survival plans across strategies and both workload modes;
  * torn correctness classes and recovery detection flags;
  * the undo log's torn log-tail rejection;
  * measure-mode byte-certification (``state_certified``);
  * the BENCH_sweep trend-tracker comparison rule.
"""

import numpy as np
import pytest

from repro.core.backends import LineSurvival, select_survivors
from repro.core.nvm import CrashEmulator, NVMConfig
from repro.core.transactions import TxManager
from repro.scenarios import (
    CrashPlan,
    TornSpec,
    deterministic_cell_dict,
    measure_divergence_fields,
    run_scenario,
    sweep,
)

SMALL = NVMConfig(cache_bytes=512 * 1024)

CG = ("cg", {"n": 1024, "iters": 8, "seed": 3})
XS = ("xsbench", {"lookups": 400, "grid_points": 800, "n_nuclides": 8,
                  "n_materials": 6, "max_nuclides_per_material": 4,
                  "flush_every_frac": 0.02, "seed": 7})


# ---------------------------------------------------------------------------
# survivor selection
# ---------------------------------------------------------------------------

class TestSelectSurvivors:
    ORDER = [("b", 3), ("a", 0), ("a", 2), ("b", 1), ("a", 1)]

    def test_none_and_zero_fraction_select_nothing(self):
        assert select_survivors(self.ORDER, None) == []
        assert select_survivors(self.ORDER, LineSurvival(0.0, 1)) == []
        assert select_survivors([], LineSurvival(1.0, 1)) == []

    def test_full_fraction_selects_everything(self):
        ev = select_survivors(self.ORDER, LineSurvival(1.0, 0, "eviction"))
        assert ev == self.ORDER
        rnd = select_survivors(self.ORDER, LineSurvival(1.0, 0, "random"))
        assert sorted(rnd) == sorted(self.ORDER)

    def test_eviction_mode_takes_queue_front_prefix(self):
        for k in range(1, len(self.ORDER) + 1):
            frac = k / len(self.ORDER)
            got = select_survivors(self.ORDER,
                                   LineSurvival(frac, 99, "eviction"))
            assert got == self.ORDER[:k], frac

    def test_count_is_rounded(self):
        # 5 entries * 0.5 -> round(2.5) -> 2 (banker's rounding)
        got = select_survivors(self.ORDER, LineSurvival(0.5, 0, "eviction"))
        assert len(got) == 2
        got = select_survivors(self.ORDER, LineSurvival(0.7, 0, "eviction"))
        assert len(got) == round(0.7 * 5)

    def test_random_mode_is_seeded_and_order_independent(self):
        a = select_survivors(self.ORDER, LineSurvival(0.6, 7))
        b = select_survivors(self.ORDER, LineSurvival(0.6, 7))
        assert a == b
        # replacement order must not matter in random mode
        shuffled = [self.ORDER[i] for i in (4, 2, 0, 3, 1)]
        assert select_survivors(shuffled, LineSurvival(0.6, 7)) == a
        # different seeds eventually differ
        order = [("r", i) for i in range(40)]
        draws = {tuple(select_survivors(order, LineSurvival(0.5, s)))
                 for s in range(8)}
        assert len(draws) > 1

    def test_validation(self):
        with pytest.raises(ValueError):
            LineSurvival(1.5)
        with pytest.raises(ValueError):
            LineSurvival(-0.1)
        with pytest.raises(ValueError):
            LineSurvival(0.5, mode="oldest")
        with pytest.raises(ValueError):
            TornSpec(samples=0)
        with pytest.raises(ValueError):
            TornSpec(fraction=2.0)


# ---------------------------------------------------------------------------
# backend equivalence under survival crashes
# ---------------------------------------------------------------------------

def _make_pair(rng):
    cache_lines = int(rng.integers(2, 12))
    line_bytes = int(rng.choice([32, 64]))
    cfg = dict(cache_bytes=cache_lines * line_bytes, line_bytes=line_bytes,
               replacement=str(rng.choice(["lru", "fifo"])))
    ref = CrashEmulator(NVMConfig(backend="reference", **cfg))
    vec = CrashEmulator(NVMConfig(backend="vectorized", **cfg))
    regions = []
    for i in range(int(rng.integers(2, 4))):
        n = int(rng.integers(8, 400))
        sector = int(rng.choice([1, 1, 2]))
        r_ref = ref.alloc(f"r{i}", (n,), np.float64, sector_lines=sector)
        r_vec = vec.alloc(f"r{i}", (n,), np.float64, sector_lines=sector)
        regions.append((f"r{i}", n, r_ref, r_vec))
    return ref, vec, regions


def _assert_pair_same(ref, vec, regions, ctx):
    import dataclasses
    for field in dataclasses.fields(ref.stats):
        a, b = getattr(ref.stats, field.name), getattr(vec.stats, field.name)
        assert a == b, f"{ctx}: stats.{field.name}: ref={a} vec={b}"
    for name, _n, a, b in regions:
        assert np.array_equal(ref.store.image[name], vec.store.image[name]), \
            f"{ctx}: image {name}"
        assert np.array_equal(a.view, b.view), f"{ctx}: truth {name}"
        assert np.array_equal(ref.backend.dirty_entries(name),
                              vec.backend.dirty_entries(name)), \
            f"{ctx}: dirty {name}"


@pytest.mark.parametrize("seed", range(12))
def test_randomized_traces_with_survival_crashes_are_equivalent(seed):
    rng = np.random.default_rng(1000 + seed)
    ref, vec, regions = _make_pair(rng)
    for step in range(90):
        name, n, r_ref, r_vec = regions[int(rng.integers(0, len(regions)))]
        op = rng.random()
        ctx = f"seed={seed} step={step} region={name}"
        if op < 0.55:
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            val = rng.uniform(-10, 10, size=hi - lo)
            r_ref[lo:hi] = val
            r_vec[lo:hi] = val
        elif op < 0.75:
            lo = int(rng.integers(0, n))
            hi = int(rng.integers(lo + 1, n + 1))
            assert np.array_equal(r_ref[lo:hi], r_vec[lo:hi]), ctx
        elif op < 0.85:
            r_ref.flush()
            r_vec.flush()
        else:
            survival = LineSurvival(
                fraction=float(rng.choice([0.0, 0.25, 0.5, 0.75, 1.0])),
                seed=int(rng.integers(0, 1 << 16)),
                mode=str(rng.choice(["random", "eviction"])))
            lost_ref = ref.crash(survival)
            lost_vec = vec.crash(survival)
            assert lost_ref == lost_vec, (ctx, survival)
        _assert_pair_same(ref, vec, regions, ctx)


# ---------------------------------------------------------------------------
# emulator-level torn semantics
# ---------------------------------------------------------------------------

class TestTornCrashSemantics:
    def _emu(self, backend, cache_lines=64):
        return CrashEmulator(NVMConfig(backend=backend,
                                       cache_bytes=cache_lines * 64,
                                       line_bytes=64))

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_full_survival_persists_every_dirty_line(self, backend):
        emu = self._emu(backend)
        r = emu.alloc("x", (64,), np.float64)
        vals = np.arange(64.0)
        r[...] = vals
        before = emu.modeled_seconds()
        lost = emu.crash(LineSurvival(1.0, seed=5))
        assert lost == 0
        assert np.array_equal(r.nvm, vals)
        assert np.array_equal(r.view, vals)     # truth reloaded = image
        assert emu.stats.torn_bytes_persisted == vals.nbytes
        assert emu.stats.torn_entries_persisted == 8  # 64 f64 = 8 lines
        # in-flight writebacks are free: crash charges no modeled time
        assert emu.modeled_seconds() == before

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_zero_fraction_is_bit_identical_to_classic_crash(self, backend):
        def trace(emu):
            r = emu.alloc("x", (128,), np.float64)
            r[...] = np.arange(128.0)
            r.flush(slice(0, 32))
            r[40:60] = -1.0
            return r

        a, b = self._emu(backend, 4), self._emu(backend, 4)
        ra, rb = trace(a), trace(b)
        lost_a = a.crash()
        lost_b = b.crash(LineSurvival(0.0, seed=3))
        assert lost_a == lost_b
        assert np.array_equal(ra.nvm, rb.nvm)
        assert b.stats.torn_bytes_persisted == 0
        import dataclasses
        assert dataclasses.asdict(a.stats) == dataclasses.asdict(b.stats)

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_eviction_mode_persists_lru_queue_front_first(self, backend):
        emu = self._emu(backend, cache_lines=64)  # no capacity evictions
        r = emu.alloc("x", (40,), np.float64)     # 5 lines of 8 elems
        for e in range(5):
            r[e * 8:(e + 1) * 8] = float(e + 1)
        r[0:8] = 9.0   # re-touch entry 0: moves to LRU back
        # eviction order now 1,2,3,4,0 -> k=1 survivor is entry 1
        emu.crash(LineSurvival(fraction=1 / 5, mode="eviction"))
        img = r.nvm
        assert np.all(img[8:16] == 2.0)
        assert np.all(img[0:8] == 0.0) and np.all(img[16:] == 0.0)

    def test_survivors_identical_across_backends_after_shared_trace(self):
        rng = np.random.default_rng(0)
        emus = {b: self._emu(b, 8) for b in ("reference", "vectorized")}
        regs = {b: e.alloc("x", (256,), np.float64) for b, e in emus.items()}
        writes = [(int(lo), int(lo) + int(w))
                  for lo, w in zip(rng.integers(0, 200, 30),
                                   rng.integers(1, 56, 30))]
        for lo, hi in writes:
            val = rng.uniform(size=hi - lo)
            for b in emus:
                regs[b][lo:hi] = val
        for b in emus:
            emus[b].crash(LineSurvival(0.5, seed=42))
        assert np.array_equal(regs["reference"].nvm, regs["vectorized"].nvm)


# ---------------------------------------------------------------------------
# TornSpec resolution
# ---------------------------------------------------------------------------

class TestTornSpecResolution:
    class _Stub:
        name = "stub"
        n_steps = 6

        def phases(self):
            return {"main": range(6)}

    def test_samples_expand_with_derived_seeds(self):
        spec = TornSpec(fraction=0.5, seed=10, samples=3)
        pts = CrashPlan.at_step(4, torn=spec).resolve(self._Stub())
        assert [p.step for p in pts] == [4, 4, 4]
        assert all(p.torn for p in pts)
        assert [p.survival.seed for p in pts] == [10, 11, 12]
        assert len({p.survival.describe() for p in pts}) == 3

    def test_every_step_with_samples_is_step_major(self):
        spec = TornSpec(fraction=0.25, seed=0, samples=2)
        pts = CrashPlan.at_every_step(torn=spec).resolve(self._Stub())
        assert [p.step for p in pts] == [s for s in range(6) for _ in "ab"]
        again = CrashPlan.at_every_step(torn=spec).resolve(self._Stub())
        assert [(p.step, p.survival) for p in pts] == \
            [(p.step, p.survival) for p in again]

    def test_describe_keys_are_extended_and_stable(self):
        spec = TornSpec(fraction=0.5, seed=3, mode="eviction", samples=2)
        plan = CrashPlan.at_fraction(0.8, torn=spec)
        assert plan.describe() == "frac:0.8:torn[eviction:f0.5:s3:x2]"
        (p0, p1) = plan.resolve(self._Stub())
        assert p0.describe() == "step=4:torn[eviction:f0.5:s3]"
        assert p1.describe() == "step=4:torn[eviction:f0.5:s4]"
        # bare-bool spellings unchanged (backward compatibility)
        assert CrashPlan.at_step(4, torn=True).describe() == "step:4:torn"
        assert CrashPlan.at_step(4).resolve(self._Stub())[0].survival is None

    def test_zero_fraction_spec_cells_match_bare_torn_cells(self):
        bare = run_scenario(CG, "undo_log", CrashPlan.at_step(5, torn=True),
                            cfg=SMALL)
        spec = run_scenario(CG, "undo_log",
                            CrashPlan.at_step(5, torn=TornSpec(0.0, seed=1)),
                            cfg=SMALL)
        db, ds = deterministic_cell_dict(bare), deterministic_cell_dict(spec)
        # the spec opts into the torn class vocabulary (torn_detected
        # instead of consistent_rollback); every execution-derived
        # field — recovery, traffic, overheads, correctness — is
        # bit-identical to the bare torn=True crash
        assert db.pop("correctness_class") == "consistent_rollback"
        assert ds.pop("correctness_class") == "torn_detected"
        for d in (db, ds):
            d.pop("plan")
            d.pop("torn_survival", None)
        assert db == ds


# ---------------------------------------------------------------------------
# engine/mode invariance on torn survival cells
# ---------------------------------------------------------------------------

class TestTornEngineInvariance:
    WLS = (("cg", {"n": 512, "iters": 8, "seed": 3}),
           ("xsbench", {"lookups": 200, "grid_points": 400, "n_nuclides": 8,
                        "n_materials": 6, "max_nuclides_per_material": 4,
                        "flush_every_frac": 0.05, "seed": 7}))
    ALL_STRATS = ("none", "adcc", "undo_log", "checkpoint_hdd",
                  "checkpoint_nvm", "checkpoint_nvm_dram")
    PLANS = (
        CrashPlan.at_fraction(0.5, torn=TornSpec(0.5, seed=4, samples=2)),
        CrashPlan.at_fraction(0.9, torn=TornSpec(0.75, seed=9,
                                                 mode="eviction")),
        CrashPlan.random(count=2, seed=1, torn=TornSpec(1.0, seed=2)),
    )

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_fork_equals_rerun_equals_measure_cell_for_cell(self, backend):
        cfg = NVMConfig(cache_bytes=512 * 1024, backend=backend)
        kw = dict(workloads=self.WLS, strategies=self.ALL_STRATS,
                  plans=self.PLANS, cfg=cfg)
        fork = sweep(engine="fork", **kw)
        rerun = sweep(engine="rerun", **kw)
        meas_fork = sweep(engine="fork", mode="measure", **kw)
        meas_rerun = sweep(engine="rerun", mode="measure", **kw)
        assert len(fork) == len(rerun) == len(meas_fork) > 0
        for a, b in zip(fork, rerun):
            assert deterministic_cell_dict(a) == deterministic_cell_dict(b), \
                (a.workload, a.strategy, a.plan, a.crash_step, a.torn_survival)
        for m, f in zip(meas_fork, fork):
            assert measure_divergence_fields(m, f) == [], \
                (m.workload, m.strategy, m.plan, m.crash_step, m.torn_survival)
        assert [deterministic_cell_dict(c) for c in meas_fork] == \
            [deterministic_cell_dict(c) for c in meas_rerun]

    def test_workers_match_serial_on_torn_plans(self):
        kw = dict(workloads=self.WLS, strategies=("adcc", "undo_log@2"),
                  plans=self.PLANS[:1], cfg=SMALL, mode="measure")
        serial = sweep(workers=1, **kw)
        sharded = sweep(workers=2, **kw)
        assert [deterministic_cell_dict(c) for c in sharded] == \
            [deterministic_cell_dict(c) for c in serial]

    def test_multi_sample_cells_are_distinct_and_traffic_tracked(self):
        cells = sweep(workloads=(CG,), strategies=("checkpoint_nvm@2",),
                      plans=(CrashPlan.at_step(
                          5, torn=TornSpec(0.5, seed=0, samples=3)),),
                      cfg=SMALL)
        assert len(cells) == 3
        assert len({c.torn_survival for c in cells}) == 3
        for c in cells:
            assert c.traffic["torn_bytes_persisted"] > 0
            assert c.crash_step == 5 and c.torn


# ---------------------------------------------------------------------------
# torn correctness classes
# ---------------------------------------------------------------------------

class TestTornClasses:
    def test_undo_log_detects_open_tx_and_rolls_back(self):
        res = run_scenario(CG, "undo_log",
                           CrashPlan.at_step(5, torn=TornSpec(0.5, seed=2)),
                           cfg=SMALL)
        assert res.correctness_class == "torn_detected"
        assert res.info["rolled_back"] is True
        assert res.info["log_entries_rejected"] == 0  # fenced appends
        assert res.correct

    def test_checkpoint_tolerates_torn_state_wholesale(self):
        res = run_scenario(CG, "checkpoint_nvm@2",
                           CrashPlan.at_step(5, torn=TornSpec(0.5, seed=2)),
                           cfg=SMALL)
        assert res.correctness_class == "consistent_rollback"
        assert res.correct

    def test_cg_invariant_scan_accepts_fully_survived_state(self):
        res = run_scenario(CG, "adcc",
                           CrashPlan.at_step(5, torn=TornSpec(1.0, seed=2)),
                           cfg=SMALL)
        # everything persisted: the newest version IS consistent, the
        # scan accepts it without rejecting a candidate
        assert res.correctness_class == "consistent_rollback"
        assert res.correct

    def test_xsbench_surviving_counters_are_torn_corrupt(self):
        res = run_scenario(XS, "adcc",
                           CrashPlan.at_fraction(
                               0.6, torn=TornSpec(1.0, seed=2)),
                           cfg=SMALL)
        # counter increments past the persisted index survived; replay
        # double-counts them — detected as positively corrupt state
        assert res.correctness_class == "torn_corrupt"
        assert res.correct is False
        assert res.info["state_corrupt"] is True

    def test_torn_classes_require_a_survival_spec(self):
        res = run_scenario(CG, "undo_log", CrashPlan.at_step(5, torn=True),
                           cfg=SMALL)
        # bare torn keeps the pre-TornSpec class vocabulary
        assert res.correctness_class == "consistent_rollback"


# ---------------------------------------------------------------------------
# undo-log torn log-tail rejection
# ---------------------------------------------------------------------------

class TestTornLogTail:
    def test_corrupt_tail_entry_is_rejected_not_applied(self):
        emu = CrashEmulator(NVMConfig(cache_bytes=4096))
        r = emu.alloc("x", (16,), np.float64)
        r[...] = np.arange(16.0)
        r.flush()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.snapshot(r, slice(0, 8))
        r[0:8] = 100.0
        tx.snapshot(r, slice(8, 16))
        r[8:16] = 200.0
        r.flush()     # uncommitted values reach NVM
        # tear the newest log entry: payload no longer matches its crc
        name, lo, hi, old, crc = tx._log[1]
        tx._log[1] = (name, lo, hi, old + 1.0, crc)
        emu.crash()
        report = mgr.recover()
        assert report is not None
        assert report.entries_rejected == 1
        assert report.entries_applied == 1
        # the valid prefix rolled back; the torn tail was discarded
        assert np.array_equal(r.nvm[0:8], np.arange(8.0))
        assert np.all(r.nvm[8:16] == 200.0)

    def test_intact_log_rolls_back_fully(self):
        emu = CrashEmulator(NVMConfig(cache_bytes=4096))
        r = emu.alloc("x", (16,), np.float64)
        r[...] = np.arange(16.0)
        r.flush()
        mgr = TxManager(emu)
        tx = mgr.begin()
        tx.snapshot(r)
        r[...] = -5.0
        r.flush()
        emu.crash(LineSurvival(0.5, seed=1))
        report = mgr.recover()
        assert report.entries_rejected == 0 and report.entries_applied == 1
        assert np.array_equal(r.nvm, np.arange(16.0))
        assert mgr.recover() is None    # nothing left open


# ---------------------------------------------------------------------------
# measure-mode byte-certification
# ---------------------------------------------------------------------------

class TestStateCertified:
    PLAN = CrashPlan.at_every_step(torn=TornSpec(0.5, seed=6))

    def test_fork_measure_certifies_consistent_recoveries(self):
        cells = sweep(workloads=(CG,), strategies=("checkpoint_nvm@2",),
                      plans=(self.PLAN,), cfg=SMALL,
                      engine="fork", mode="measure")
        certified = [c for c in cells if c.restart_point is not None
                     and c.restart_point >= 0]
        assert certified, "expected checkpointed restarts"
        assert all(c.state_certified is True for c in certified)
        # scratch restarts certify against the pre-step-0 snapshot
        assert all(c.state_certified is True for c in cells
                   if c.restart_point is not None and c.restart_point < 0)
        # only uncrashed cells have nothing to certify
        assert all(c.state_certified is None for c in cells
                   if c.restart_point is None)

    def test_corrupt_recovery_fails_certification(self):
        cells = sweep(workloads=(XS,), strategies=("adcc",),
                      plans=(CrashPlan.at_fraction(
                          0.6, torn=TornSpec(1.0, seed=2)),),
                      cfg=SMALL, engine="fork", mode="measure")
        (c,) = cells
        assert c.correctness_class == "torn_corrupt"
        assert c.state_certified is False

    def test_rerun_measure_cannot_certify(self):
        cells = sweep(workloads=(CG,), strategies=("checkpoint_nvm@2",),
                      plans=(self.PLAN,), cfg=SMALL,
                      engine="rerun", mode="measure")
        assert all(c.state_certified is None for c in cells)

    def test_certification_is_outside_the_engine_contract(self):
        kw = dict(workloads=(CG,), strategies=("checkpoint_nvm@2",),
                  plans=(self.PLAN,), cfg=SMALL, mode="measure")
        fork = sweep(engine="fork", **kw)
        rerun = sweep(engine="rerun", **kw)
        for f, r in zip(fork, rerun):
            df, dr = deterministic_cell_dict(f), deterministic_cell_dict(r)
            assert "state_certified" not in df
            assert df == dr

    def test_full_mode_cells_do_not_certify(self):
        cells = sweep(workloads=(CG,), strategies=("checkpoint_nvm@2",),
                      plans=(CrashPlan.at_step(5),), cfg=SMALL,
                      engine="fork", mode="full")
        assert cells[0].state_certified is None
        assert "state_certified" not in cells[0].to_json_dict()


# ---------------------------------------------------------------------------
# sweep-trend comparison rule (CI tooling)
# ---------------------------------------------------------------------------

class TestSweepTrend:
    def test_compare_speedups(self):
        import os
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        try:
            from benchmarks.sweep_trend import compare_speedups
        finally:
            sys.path.pop(0)
        prev = {"speedup": 4.0, "measure_speedup": 10.0,
                "total_speedup": 40.0}
        ok = {"speedup": 3.0, "measure_speedup": 9.0, "total_speedup": 27.0}
        assert compare_speedups(prev, ok) == []
        bad = {"speedup": 1.5, "measure_speedup": 9.0, "total_speedup": 27.0}
        assert len(compare_speedups(prev, bad)) == 1
        assert "speedup" in compare_speedups(prev, bad)[0]
        # older-schema BASELINE is skipped; a metric that vanishes from
        # the NEW artifact is a failure (it would silently disable the
        # gate forever otherwise)
        assert compare_speedups({}, ok) == []
        dropped = {"speedup": 4.0, "total_speedup": 40.0}
        fails = compare_speedups(prev, dropped)
        assert len(fails) == 1 and "measure_speedup" in fails[0]
        assert compare_speedups(prev, ok, max_regression=1.05) != []
