"""The narrow protocol every NVM-cache emulation backend implements.

A backend models *which bytes of each registered region would still be
sitting dirty in a volatile CPU cache* — i.e. which bytes have NOT yet
reached the NVM image held by :class:`repro.core.nvm.NVMStore`. The
program's latest values always live in the registered truth arrays;
backends only track occupancy/dirtiness metadata and copy truth spans
into the store's image on writeback.

Granularity: an *entry* covers ``sector_lines`` consecutive cache lines
of a region's flattened buffer (``sector_lines=1`` is exact per-line
tracking). Entries are weighted by their line count against the cache
capacity, so coarse sectors keep emulation cheap without losing the
capacity pressure that drives eviction behavior.

Cost-model invariants (every backend MUST uphold these so that modeled
mechanism overheads are backend-independent — see the paper §II/§III.A
and backends/README.md):

* evicting a dirty entry persists its clipped byte span at NVM write
  bandwidth and bumps ``lines_evicted`` by the entry's line weight,
  dirty or clean;
* a read miss charges one full entry (``elems_per_entry * itemsize``)
  at NVM read bandwidth;
* ``flush`` charges the CLFLUSH issue latency for every line in the
  range unconditionally (flushing clean or absent lines costs the same
  order as dirty ones), writes back dirty entries, and charges clean or
  absent entries one entry's bytes of write-pipeline occupancy;
* ``drain`` is a full eviction sweep: writebacks are charged and
  ``lines_evicted`` counts every drained entry;
* ``crash`` is free in modeled seconds: volatile contents simply
  vanish. A :class:`LineSurvival` spec makes the crash *torn* instead
  of all-or-nothing — a deterministic subset of the dirty entries is
  written back to the NVM image first (the writebacks that were
  already in flight when power failed), recorded via
  ``TrafficStats.note_torn_persist`` but never charged to
  ``modeled_seconds``;
* all charges for one program-visible operation are aggregated and
  applied through :meth:`TrafficStats.charge_batch` exactly once, so
  two backends replaying the same trace produce *identical* stats.

Line-survival selection is shared code (:func:`select_survivors`), so
the surviving subset — and therefore the post-crash NVM image — is
byte-identical between the reference and vectorized backends for the
same spec and dirty state (tests/test_torn_crashes.py enforces it on
randomized traces).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

__all__ = ["MemoryBackend", "OpAccumulator", "LineSurvival",
           "MediaFault", "corrupt_image_words",
           "select_survivors", "select_survivor_words", "entry_span",
           "word_spans", "WORD_BYTES"]

SURVIVAL_MODES = ("random", "eviction")
SURVIVAL_GRANULARITIES = ("line", "word")

# Sub-entry torn-write granularity: an 8-byte store is the natural
# failure-atomicity unit on persistent-memory hardware (WITCHER's
# sub-line crash states tear at machine-word boundaries, not cache-line
# boundaries).
WORD_BYTES = 8


@dataclasses.dataclass(frozen=True)
class LineSurvival:
    """Which dirty cache entries persist at a torn crash.

    ``fraction`` of the dirty entries (rounded to the nearest count)
    reach NVM before the lights go out; the rest vanish with the cache.

      mode="random"    a seeded uniform subset over the canonical
                       (region name, entry index) ordering — the
                       EasyCrash-style sampled crash state;
      mode="eviction"  the replacement-queue front persists first: the
                       entries the cache would have written back next
                       are exactly the ones that made it (WITCHER's
                       ordering-consistent crash states).

    Resolution is a pure function of (spec, dirty state): both backends
    derive the same survivor set from the same spec.

    ``granularity="word"`` tears at :data:`WORD_BYTES` boundaries inside
    each dirty entry instead of whole entries: the unit population
    becomes every machine word of every dirty entry (still in eviction
    order — an entry's words persist front-to-back within it), so the
    crash image can persist half a cache line (the WITCHER sub-line
    states a line-granularity model cannot produce).
    """

    fraction: float
    seed: int = 0
    mode: str = "random"
    granularity: str = "line"

    def __post_init__(self):
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError("survival fraction must be in [0, 1]")
        if self.mode not in SURVIVAL_MODES:
            raise ValueError(f"unknown survival mode {self.mode!r} "
                             f"(choose from {SURVIVAL_MODES})")
        if self.granularity not in SURVIVAL_GRANULARITIES:
            raise ValueError(
                f"unknown survival granularity {self.granularity!r} "
                f"(choose from {SURVIVAL_GRANULARITIES})")

    def describe(self) -> str:
        base = f"{self.mode}:f{self.fraction:g}:s{self.seed}"
        # line granularity keeps the historical spelling byte-identical
        # (pinned by tests and serialized torn_survival fields)
        return base + (":word" if self.granularity == "word" else "")


def _select_units(units: Sequence[tuple],
                  survival: Optional[LineSurvival]) -> List[tuple]:
    """Survivor selection over an abstract unit population (dirty
    entries at line granularity, their words at word granularity).

    ``units`` is the population in replacement-queue order (front first
    — the next-to-be-written-back unit leads). ``survival=None`` (the
    classic all-or-nothing crash) selects nothing. The survivor count is
    ``round(fraction * n)`` (banker's rounding, as python's ``round``);
    "eviction" mode takes the queue-front prefix, "random" draws a
    seeded uniform subset over the canonical sorted unit ordering so the
    choice is independent of replacement state.
    """
    if survival is None or not units:
        return []
    n = len(units)
    k = int(round(survival.fraction * n))
    if k <= 0:
        return []
    if survival.mode == "eviction":
        return list(units[:k])
    canon = sorted(units)
    rng = np.random.default_rng(survival.seed)
    idx = rng.choice(n, size=k, replace=False)
    return [canon[i] for i in np.sort(idx)]


def select_survivors(eviction_order: Sequence[Tuple[str, int]],
                     survival: Optional[LineSurvival]
                     ) -> List[Tuple[str, int]]:
    """The one place the surviving dirty *entry* subset is chosen.

    ``eviction_order`` is every dirty entry as ``(region, entry)`` in
    replacement-queue order (front first — the next-to-be-evicted
    entry leads). See :func:`_select_units` for the selection rule;
    this is the ``granularity="line"`` path both backends call.
    """
    return _select_units(eviction_order, survival)


MEDIA_FAULT_KINDS = ("poison", "bitflip")


@dataclasses.dataclass(frozen=True)
class MediaFault:
    """Seeded silent media corruption of the persistent image.

    Models post-crash data corruption (EasyCrash's observation that
    restart safety is decided by *corrupted*, not merely truncated,
    state): ``words`` machine words (:data:`WORD_BYTES`-sized units of
    the NVM image) are corrupted after the crash image forms, with no
    traffic charged and no dirty-state interaction — the hardware lied,
    and nothing in the cache model saw it happen.

      kind="poison"   each selected word is overwritten with seeded
                      random bytes (a dead/poisoned line returning
                      garbage);
      kind="bitflip"  one seeded bit of each selected word flips (the
                      classic retention/ECC-escape fault).

    Selection and payloads are pure functions of (spec, image shape):
    :func:`corrupt_image_words` operates on the backend-independent
    image dict, so the corrupted image is byte-identical under the
    reference and vectorized backends by construction — the same
    contract ``select_survivors`` gives torn crashes.
    """

    words: int = 1
    seed: int = 0
    kind: str = "poison"

    def __post_init__(self):
        if self.words < 1:
            raise ValueError("fault words must be >= 1")
        if self.kind not in MEDIA_FAULT_KINDS:
            raise ValueError(f"unknown media-fault kind {self.kind!r} "
                             f"(choose from {MEDIA_FAULT_KINDS})")

    def describe(self) -> str:
        return f"{self.kind}:w{self.words}:s{self.seed}"


def corrupt_image_words(image, fault: MediaFault,
                        region_names: Optional[Sequence[str]] = None
                        ) -> List[Tuple[str, int, int]]:
    """Apply ``fault`` to the NVM image dict in place; returns the
    corrupted ``(name, lo, hi)`` byte spans (sorted canonical order).

    The unit population is every :data:`WORD_BYTES`-aligned byte span of
    every targeted region (``region_names`` restricts it; default = all
    regions), enumerated in sorted-name order so the selection — like
    :func:`_select_units`'s random mode — is canonical and
    backend-independent. When ``fault.words`` exceeds the population,
    every word is corrupted. Poison payloads are seeded random bytes,
    XORed with 0xFF if they happen to equal the current contents (a
    fault must *change* the word — a silent no-op would make detection
    gates vacuous); bitflips flip one seeded bit per word.
    """
    names = sorted(image) if region_names is None else sorted(region_names)
    units: List[Tuple[str, int, int]] = []
    for name in names:
        nbytes = image[name].nbytes
        for lo in range(0, nbytes, WORD_BYTES):
            units.append((name, lo, min(lo + WORD_BYTES, nbytes)))
    if not units:
        return []
    rng = np.random.default_rng(fault.seed)
    k = min(fault.words, len(units))
    idx = np.sort(rng.choice(len(units), size=k, replace=False))
    chosen = [units[i] for i in idx]
    for name, lo, hi in chosen:
        view = image[name].view(np.uint8)[lo:hi]
        if fault.kind == "poison":
            payload = rng.integers(0, 256, size=hi - lo, dtype=np.uint8)
            if np.array_equal(payload, view):
                payload = payload ^ np.uint8(0xFF)
            view[:] = payload
        else:  # bitflip
            byte = int(rng.integers(0, hi - lo))
            bit = int(rng.integers(0, 8))
            view[byte] ^= np.uint8(1 << bit)
    return chosen


def entry_span(entry: int, elems_per_entry: int, n_elems: int
               ) -> Tuple[int, int]:
    """Clipped [lo, hi) element span of one cache entry of a flattened
    region — the span a writeback persists (shared by both backends and
    the batched evaluators, so torn-byte accounting can never drift)."""
    lo = entry * elems_per_entry
    return lo, min(lo + elems_per_entry, n_elems)


def word_spans(entry: int, elems_per_entry: int, n_elems: int,
               itemsize: int) -> List[Tuple[int, int]]:
    """The :data:`WORD_BYTES`-sized element spans tiling one entry's
    clipped span, front first. Elements wider than a word get one span
    per element (a word can never split an element — region dtypes are
    at most 8 bytes wide)."""
    lo, hi = entry_span(entry, elems_per_entry, n_elems)
    epw = max(1, WORD_BYTES // itemsize)
    return [(w, min(w + epw, hi)) for w in range(lo, hi, epw)]


def select_survivor_words(eviction_order: Sequence[Tuple[str, int]],
                          survival: Optional[LineSurvival],
                          geometry) -> List[Tuple[str, int, int, int]]:
    """Word-granularity survivor selection: expand every dirty entry
    into its word spans (eviction order outer, front-to-back within an
    entry) and select over that population.

    ``geometry(name)`` returns ``(elems_per_entry, n_elems, itemsize)``
    for a region. Returns surviving ``(name, entry, lo, hi)`` element
    spans; the per-entry word index ordering makes random-mode
    selection canonical (sorted by (name, entry, lo))."""
    if survival is None or not eviction_order:
        return []
    units = []
    for name, entry in eviction_order:
        epe, n_elems, itemsize = geometry(name)
        for lo, hi in word_spans(entry, epe, n_elems, itemsize):
            units.append((name, entry, lo, hi))
    return _select_units(units, survival)


class OpAccumulator:
    """Per-operation charge accumulator (integers only).

    Backends fill one of these per program-visible operation and apply
    it through ``TrafficStats.charge_batch`` exactly once — keeping the
    charge arithmetic (and so the float ``modeled_seconds``) identical
    across backends.
    """

    __slots__ = ("wb_bytes", "evict_lines", "read_entries")

    def __init__(self):
        self.wb_bytes = 0
        self.evict_lines = 0
        self.read_entries = 0


@runtime_checkable
class MemoryBackend(Protocol):
    """Volatile-cache-over-NVM emulation strategy.

    Constructed as ``Backend(store, cfg)`` where ``store`` is the
    :class:`~repro.core.nvm.NVMStore` holding the persistent image and
    traffic stats, and ``cfg`` the :class:`~repro.core.nvm.NVMConfig`.
    """

    # -- region lifecycle --------------------------------------------------
    def register(self, name: str, truth_flat: np.ndarray,
                 sector_lines: int = 1) -> None:
        """Start tracking ``name``; ``truth_flat`` is the program-truth
        buffer whose spans will be persisted on writeback."""
        ...

    def unregister(self, name: str) -> None:
        """Drop all state for ``name`` without writing anything back."""
        ...

    # -- program-visible operations ---------------------------------------
    def write(self, name: str, lo: int, hi: int) -> None:
        """Program stored truth[lo:hi): allocate entries, mark dirty."""
        ...

    def read(self, name: str, lo: int, hi: int) -> None:
        """Program loaded truth[lo:hi): allocate entries (miss charges an
        NVM read), do not dirty."""
        ...

    def flush(self, name: str, lo: int = 0, hi=None) -> None:
        """CLFLUSH truth[lo:hi): write back dirty entries, invalidate."""
        ...

    def drain(self) -> None:
        """Write back everything (normal program termination)."""
        ...

    def crash(self, survival: Optional[LineSurvival] = None) -> int:
        """Power loss: volatile contents vanish. With a
        :class:`LineSurvival` spec, the selected dirty entries are
        written back to the NVM image first (torn crash) and reported
        through ``TrafficStats.note_torn_persist``. Returns #dirty
        entries lost (dirty minus survivors)."""
        ...

    # -- snapshot / fork ----------------------------------------------------
    def snapshot(self) -> object:
        """Capture the backend's volatile-cache state (occupancy, dirty
        sets, replacement order) as an opaque, immutable value.

        The snapshot must be restorable any number of times into the
        *same* backend instance (same registered regions), and a
        restored backend must replay any subsequent trace with charges,
        images, and eviction decisions bit-identical to a from-scratch
        run of prefix+trace — the contract the fork sweep engine and
        tests/test_backend_equivalence.py rely on."""
        ...

    def restore(self, snap: object) -> None:
        """Reset the cache state to a value captured by :meth:`snapshot`
        on this instance. Registered truth arrays are NOT touched —
        callers restore them separately (see CrashEmulator.restore)."""
        ...

    # -- introspection ------------------------------------------------------
    @property
    def occupancy_lines(self) -> int:
        """Line-weighted cache occupancy."""
        ...

    def dirty_entries(self, name: str) -> np.ndarray:
        """Sorted entry indices of ``name`` currently dirty in cache."""
        ...

    def has_dirty(self, name: str) -> bool:
        """Whether ANY entry of ``name`` is dirty in cache — the cheap
        predicate crash() uses per region per cell (dense measure-mode
        sweeps crash thousands of times; materializing the index array
        of every clean region there is pure waste)."""
        ...

    def dirty_eviction_order(self) -> List[Tuple[str, int]]:
        """Every dirty entry as ``(region, entry)`` in replacement-queue
        order (front = next victim) — the exact ``eviction_order`` input
        :func:`select_survivors` consumes at crash time. The batched
        sweep engine captures this alongside snapshots so survivor
        selection can replay host-side without re-running ``crash()``."""
        ...

    def entry_geometry(self, name: str) -> Tuple[int, int, int]:
        """``(elems_per_entry, n_elems, itemsize)`` for a registered
        region — the span arithmetic shared with :func:`entry_span` /
        :func:`word_spans`."""
        ...
