"""Pure-SSM language model (mamba2-130m): attention-free Mamba2 stack."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import mamba2 as M
from .lm import cross_entropy, stack_axes, stacked_init

__all__ = ["init", "forward", "loss_fn", "init_cache", "decode_step",
           "abstract_init"]


def _layer_init(cfg: ModelConfig, key):
    km, _ = jax.random.split(key)
    p, a = {}, {}
    p["mamba"], a["mamba"] = M.mamba2_init(cfg, km)
    p["norm"], a["norm"] = L.rmsnorm_init(cfg.d_model,
                                          jnp.dtype(cfg.param_dtype))
    return p, a


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    p, a = {}, {}
    p["embed"], a["embed"] = L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                          jnp.dtype(cfg.param_dtype))
    p["layers"], a["layers"] = stacked_init(
        lambda k: _layer_init(cfg, k), cfg.n_layers, k_layers)
    p["norm_f"], a["norm_f"] = L.rmsnorm_init(cfg.d_model,
                                              jnp.dtype(cfg.param_dtype))
    if not cfg.tie_embeddings:
        p["head"], a["head"] = L.dense_init(k_head, cfg.d_model,
                                            cfg.padded_vocab, "embed",
                                            "vocab",
                                            jnp.dtype(cfg.param_dtype))
    return p, a


def abstract_init(cfg: ModelConfig, key):
    box = {}

    def params_only(k):
        prms, axes = init(cfg, k)
        box["axes"] = axes
        return prms

    return jax.eval_shape(params_only, key), box["axes"]


def _head(cfg, params, h):
    logits = (h @ params["embed"].T.astype(h.dtype) if cfg.tie_embeddings
              else h @ params["head"].astype(h.dtype))
    return logits[..., :cfg.vocab_size]  # tables padded for TP


def forward(cfg: ModelConfig, params: Dict, batch: Dict, mesh=None,
            remat: str = "none") -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)

    def body(h, lp):
        h = L.shard_act(h, mesh)
        out = h + M.mamba2_apply(cfg, lp["mamba"],
                                 L.rmsnorm(h, lp["norm"], cfg.norm_eps))
        return L.shard_act(out, mesh), None

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return _head(cfg, params, L.rmsnorm(h, params["norm_f"], cfg.norm_eps))


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict, mesh=None,
            remat: str = "none") -> jax.Array:
    return cross_entropy(forward(cfg, params, batch, mesh, remat=remat),
                         batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    one, one_axes = M.mamba2_cache_init(cfg, batch)
    cache = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
    return cache, stack_axes(one_axes)


def decode_step(cfg: ModelConfig, params: Dict, cache, tokens: jax.Array,
                pos: jax.Array, mesh=None):
    dt = jnp.dtype(cfg.compute_dtype)
    h = jnp.take(params["embed"], tokens, axis=0).astype(dt)

    def body(h, xs):
        lp, lc = xs
        out, new_lc = M.mamba2_decode_step(
            cfg, lp["mamba"], L.rmsnorm(h, lp["norm"], cfg.norm_eps), lc)
        return h + out, new_lc

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    return _head(cfg, params, L.rmsnorm(h, params["norm_f"], cfg.norm_eps)), \
        new_cache
