"""Batched sweep engine (``sweep(mode="batched")``) and its device math.

Covers:

  * cell identity — batched cells equal measure-mode cells on every
    deterministic field, per (workload, strategy) pair, over no-crash,
    dense torn-survival (line AND word granularity), eviction-mode and
    multi-sample plans;
  * mode validation — batched requires the fork engine;
  * word-granularity refinement properties (the TornSpec
    ``granularity="word"`` axis): at fraction 1.0 the word survivor
    spans tile exactly the line survivor spans (same persisted bytes,
    same crash image); at fraction 0.0 word mode is bit-identical to
    the bare all-or-nothing crash — on the shared selection routines,
    on both emulator backends, and through the sweep;
  * device-math kernels — ``gemm_batch``/``tile_sums_batch`` Pallas
    (interpret=True) vs jnp oracles, and ``cg_invariant_errors`` /
    ``mm_chunk_stats`` dense-vs-sparse-route and vs numpy oracles.
"""

import numpy as np
import pytest

from repro.core.backends import LineSurvival, select_survivors
from repro.core.backends.base import (
    entry_span,
    select_survivor_words,
    word_spans,
)
from repro.core.backends.batched import (
    cache_op_update,
    cg_invariant_errors,
    cg_route,
    have_jax,
    kv_row_checksums,
    kv_value_match,
    mm_chunk_stats,
    queue_validity,
)
from repro.core.nvm import CrashEmulator, NVMConfig
from repro.scenarios import (
    CrashPlan,
    TornSpec,
    deterministic_cell_dict,
    sweep,
)

SMALL = NVMConfig(cache_bytes=512 * 1024)

CG = ("cg", {"n": 512, "iters": 8, "seed": 5})
MM = ("mm", {"n": 32, "k": 8, "seed": 2})
XS = ("xsbench", {"lookups": 80, "grid_points": 600, "n_nuclides": 8,
                  "n_materials": 6, "max_nuclides_per_material": 4,
                  "flush_every_frac": 0.1, "seed": 7})


def _cell_key(c):
    return (c.workload, c.strategy, c.plan, c.crash_step, c.torn_survival)


# ---------------------------------------------------------------------------
# batched == measure cell identity
# ---------------------------------------------------------------------------

class TestBatchedEqualsMeasure:
    """The tentpole contract: every deterministic field of a batched
    cell equals the measure-mode cell, across every analytic evaluator
    (scratch, checkpoint, undo-log, per-workload adcc) and the
    measure-fallback pairs alike."""

    PLANS = (
        CrashPlan.no_crash(),
        CrashPlan.at_every_step(torn=TornSpec(0.5, seed=4, samples=2)),
        CrashPlan.at_every_step(torn=TornSpec(1.0, seed=2)),
        CrashPlan.at_fraction(0.6, torn=TornSpec(0.5, seed=3,
                                                 mode="eviction")),
        # sub-line torn images: the word-granularity axis
        CrashPlan.at_every_step(
            torn=TornSpec(0.5, seed=6, granularity="word")),
        CrashPlan.at_fraction(0.8, torn=TornSpec(0.25, seed=8,
                                                 granularity="word",
                                                 samples=2)),
    )
    STRATS = ("none", "adcc", "undo_log", "checkpoint_nvm@2")

    @pytest.mark.parametrize("wl", (CG, MM, XS), ids=lambda w: w[0])
    def test_batched_equals_measure_per_pair(self, wl):
        kw = dict(workloads=(wl,), strategies=self.STRATS,
                  plans=self.PLANS, cfg=SMALL)
        meas = sweep(engine="fork", mode="measure", **kw)
        batch = sweep(engine="fork", mode="batched", **kw)
        assert len(meas) == len(batch) > 0
        for m, b in zip(meas, batch):
            assert deterministic_cell_dict(b) == \
                deterministic_cell_dict(m), _cell_key(m)

    def test_batched_requires_fork_engine(self):
        with pytest.raises(ValueError):
            sweep(workloads=(CG,), strategies=("none",),
                  engine="rerun", mode="batched")

    def test_batched_workers_match_serial(self):
        kw = dict(workloads=(CG,), strategies=("adcc", "undo_log"),
                  plans=(CrashPlan.at_every_step(
                      torn=TornSpec(0.5, seed=4)),),
                  cfg=SMALL, mode="batched")
        serial = sweep(workers=1, **kw)
        sharded = sweep(workers=2, **kw)
        assert [deterministic_cell_dict(c) for c in sharded] == \
            [deterministic_cell_dict(c) for c in serial]

    def test_batched_cells_do_not_certify(self):
        # state_certified is a fork-measure extra; the analytic engine
        # never replays the golden tail, so it must stay None (and out
        # of the serialized dict), not False
        cells = sweep(workloads=(CG,), strategies=("checkpoint_nvm@2",),
                      plans=(CrashPlan.at_step(5,
                                               torn=TornSpec(0.5, seed=6)),),
                      cfg=SMALL, engine="fork", mode="batched")
        (c,) = cells
        assert c.state_certified is None
        assert "state_certified" not in c.to_json_dict()


class TestKVBatchedEqualsMeasure:
    """The KV family's analytic evaluators (PR 10): state-restoring
    strategies audited from the request oracle, adcc replayed from the
    crash image with stacked SplitMix64 checksum launches. Every cell
    must be byte-identical to measure mode AND actually take the
    analytic route (zero ``batched_fallback`` markers)."""

    PLANS = (
        CrashPlan.no_crash(),
        CrashPlan.at_every_step(torn=TornSpec(0.5, seed=4, samples=2)),
        CrashPlan.at_every_step(
            torn=TornSpec(0.5, seed=6, granularity="word")),
        CrashPlan.at_fraction(0.6, torn=TornSpec(0.25, seed=3,
                                                 mode="eviction")),
    )
    STRATS = ("none", "adcc", "shadow_snapshot", "undo_log",
              "checkpoint_nvm@2")

    @pytest.mark.parametrize("profile", ["etc", "udb"])
    def test_kv_batched_equals_measure(self, profile):
        wl = ("kv", {"profile": profile, "n_steps": 10, "seed": 11})
        kw = dict(workloads=(wl,), strategies=self.STRATS,
                  plans=self.PLANS, cfg=SMALL)
        meas = sweep(engine="fork", mode="measure", **kw)
        batch = sweep(engine="fork", mode="batched", **kw)
        assert len(meas) == len(batch) > 0
        for m, b in zip(meas, batch):
            assert deterministic_cell_dict(b) == \
                deterministic_cell_dict(m), _cell_key(m)
            assert "batched_fallback" not in b.info, _cell_key(b)

    def test_kv_blind_policy_batched_equals_measure(self):
        # blind adcc adopts the rawest root and serves torn state: the
        # image-side audit must reproduce the violation counts exactly
        wl = ("kv", {"profile": "udb", "n_steps": 10, "seed": 11,
                     "policy": "blind"})
        kw = dict(workloads=(wl,), strategies=("adcc",),
                  plans=(CrashPlan.at_every_step(
                      torn=TornSpec(0.5, seed=9, samples=2)),), cfg=SMALL)
        meas = sweep(engine="fork", mode="measure", **kw)
        batch = sweep(engine="fork", mode="batched", **kw)
        assert len(meas) == len(batch) > 0
        # the torn matrix must exercise real violations or the audit
        # replication is vacuous
        assert any(c.info.get("durability_violations", 0) > 0
                   or c.info.get("atomicity_violations", 0) > 0
                   for c in meas)
        for m, b in zip(meas, batch):
            assert deterministic_cell_dict(b) == \
                deterministic_cell_dict(m), _cell_key(m)
            assert "batched_fallback" not in b.info, _cell_key(b)

    def test_unsupported_strategy_cells_carry_fallback_reason(self):
        from repro.scenarios.strategies import (CheckpointStrategy,
                                                register_strategy)

        class _OddCheckpoint(CheckpointStrategy):
            pass

        register_strategy("odd_ckpt_pr10", _OddCheckpoint, override=True)
        cells = sweep(workloads=(CG,), strategies=("odd_ckpt_pr10",),
                      plans=(CrashPlan.at_step(3),), cfg=SMALL,
                      engine="fork", mode="batched")
        (c,) = cells
        assert c.info["batched_fallback"].startswith("unsupported")


# ---------------------------------------------------------------------------
# word-granularity refinement properties (satellite: TornSpec word axis)
# ---------------------------------------------------------------------------

class TestWordGranularityRefinement:
    """``granularity="word"`` refines the line model, it does not
    change its envelope: at fraction 1.0 the selected word spans tile
    exactly the full entry spans, and at fraction 0.0 nothing
    survives — so the two endpoints must reproduce the line-mode and
    bare-crash images bit for bit."""

    ORDER = [("b", 3), ("a", 0), ("a", 2), ("b", 1), ("a", 1)]
    GEOM = {"a": (8, 70, 8), "b": (8, 32, 8)}   # (epe, n_elems, itemsize)

    def _geometry(self, name):
        return self.GEOM[name]

    @pytest.mark.parametrize("mode", ["random", "eviction"])
    def test_full_fraction_word_spans_tile_line_spans(self, mode):
        words = select_survivor_words(
            self.ORDER, LineSurvival(1.0, seed=3, mode=mode,
                                     granularity="word"), self._geometry)
        lines = select_survivors(self.ORDER,
                                 LineSurvival(1.0, seed=3, mode=mode))
        assert sorted(lines) == sorted(self.ORDER)
        by_entry = {}
        for name, entry, lo, hi in words:
            assert hi > lo
            by_entry.setdefault((name, entry), []).append((lo, hi))
        assert set(by_entry) == set(self.ORDER)
        for name, entry in lines:
            epe, n_elems, _item = self.GEOM[name]
            spans = sorted(by_entry[(name, entry)])
            # contiguous, non-overlapping, covering the clipped span
            assert spans[0][0] == entry_span(entry, epe, n_elems)[0]
            assert spans[-1][1] == entry_span(entry, epe, n_elems)[1]
            for (_, h), (l2, _) in zip(spans, spans[1:]):
                assert h == l2
        # equal persisted element count -> equal persisted bytes
        n_word_elems = sum(hi - lo for _, _, lo, hi in words)
        n_line_elems = sum(
            entry_span(e, *self.GEOM[n][:2])[1]
            - entry_span(e, *self.GEOM[n][:2])[0] for n, e in lines)
        assert n_word_elems == n_line_elems

    def test_zero_fraction_selects_nothing(self):
        for mode in ("random", "eviction"):
            assert select_survivor_words(
                self.ORDER, LineSurvival(0.0, seed=1, mode=mode,
                                         granularity="word"),
                self._geometry) == []
        assert select_survivor_words(self.ORDER, None, self._geometry) == []

    def test_word_spans_respect_itemsize_and_clipping(self):
        # 8-byte words over f64 (itemsize 8): one element per word;
        # the last entry of a 70-element region clips at 70
        assert word_spans(8, 8, 70, 8) == [(i, i + 1) for i in range(64, 70)]
        # 4-byte items: two elements per word
        assert word_spans(0, 8, 32, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]
        # an element wider than a word can never split
        assert word_spans(0, 4, 16, 16) == [(0, 1), (1, 2), (2, 3), (3, 4)]

    def _traced_emu(self, backend, seed):
        emu = CrashEmulator(NVMConfig(backend=backend, cache_bytes=16 * 64,
                                      line_bytes=64))
        r = emu.alloc("x", (300,), np.float64)
        rng = np.random.default_rng(seed)
        for lo, w in zip(rng.integers(0, 250, 25), rng.integers(1, 40, 25)):
            r[int(lo):int(lo) + int(w)] = rng.uniform(size=int(w))
        return emu, r

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_emulator_full_fraction_word_image_equals_line_image(
            self, backend, seed):
        a, ra = self._traced_emu(backend, seed)
        b, rb = self._traced_emu(backend, seed)
        a.crash(LineSurvival(1.0, seed=9, granularity="line"))
        b.crash(LineSurvival(1.0, seed=9, granularity="word"))
        assert np.array_equal(ra.nvm, rb.nvm)
        assert (a.stats.torn_bytes_persisted
                == b.stats.torn_bytes_persisted > 0)

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_emulator_zero_fraction_word_equals_bare_crash(self, backend):
        a, ra = self._traced_emu(backend, 7)
        b, rb = self._traced_emu(backend, 7)
        lost_a = a.crash()
        lost_b = b.crash(LineSurvival(0.0, seed=3, granularity="word"))
        assert lost_a == lost_b
        assert np.array_equal(ra.nvm, rb.nvm)
        assert b.stats.torn_bytes_persisted == 0

    @pytest.mark.parametrize("seed", range(6))
    def test_backends_agree_on_word_granularity_crashes(self, seed):
        rng = np.random.default_rng(400 + seed)
        frac = float(rng.choice([0.25, 0.5, 0.75]))
        mode = str(rng.choice(["random", "eviction"]))
        surv = LineSurvival(frac, seed=int(rng.integers(1 << 16)),
                            mode=mode, granularity="word")
        ref, r_ref = self._traced_emu("reference", seed)
        vec, r_vec = self._traced_emu("vectorized", seed)
        assert ref.crash(surv) == vec.crash(surv)
        assert np.array_equal(r_ref.nvm, r_vec.nvm)
        assert (ref.stats.torn_bytes_persisted
                == vec.stats.torn_bytes_persisted)

    def test_word_survival_describe_is_tagged(self):
        assert LineSurvival(0.5, 3).describe() == "random:f0.5:s3"
        assert LineSurvival(0.5, 3, granularity="word").describe() == \
            "random:f0.5:s3:word"
        with pytest.raises(ValueError):
            LineSurvival(0.5, granularity="byte")

    def test_sweep_word_fraction_endpoints_match_line_model(self):
        # through the full stack: fraction-1.0 word cells carry the
        # same recovery outcome as fraction-1.0 line cells; fraction
        # 0.0 matches the line 0.0 cells (both == bare torn crash)
        for frac in (0.0, 1.0):
            kw = dict(workloads=(CG,), strategies=("undo_log",),
                      cfg=SMALL, mode="measure")
            (line,) = sweep(plans=(CrashPlan.at_step(
                5, torn=TornSpec(frac, seed=2)),), **kw)
            (word,) = sweep(plans=(CrashPlan.at_step(
                5, torn=TornSpec(frac, seed=2, granularity="word")),), **kw)
            dl = deterministic_cell_dict(line)
            dw = deterministic_cell_dict(word)
            for d in (dl, dw):
                d.pop("plan")
                d.pop("torn_survival", None)
            assert dl == dw, frac


# ---------------------------------------------------------------------------
# device math vs oracles
# ---------------------------------------------------------------------------

pytestmark_jax = pytest.mark.skipif(not have_jax(),
                                    reason="jax unavailable")


@pytestmark_jax
class TestBatchedDeviceMath:
    def _cg_batch(self, seed, T=5, n=24):
        rng = np.random.default_rng(seed)
        P, Q, R, Z = (rng.normal(size=(T, n)) for _ in range(4))
        b = rng.normal(size=n)
        S = rng.normal(size=(n, n))
        S = 0.5 * (S + S.T)
        return P, Q, R, Z, b, S

    def _sparse_of(self, S):
        # dense matrix as full-width slabs: every column is a "nonzero"
        n = S.shape[0]
        cols = np.tile(np.arange(n, dtype=np.int32), (n, 1))
        return "sparse", S.copy(), cols

    def test_cg_errors_match_numpy_oracle_both_routes(self):
        P, Q, R, Z, b, S = self._cg_batch(0)
        want_orth = (np.abs(np.sum(P * Q, axis=1))
                     / (np.linalg.norm(P, axis=1)
                        * np.linalg.norm(Q, axis=1) + 1e-300))
        want_rel = (np.linalg.norm(R - (b[None, :] - Z @ S), axis=1)
                    / (np.linalg.norm(b) + 1e-300))
        for op in (("dense", S), self._sparse_of(S)):
            orth, rel = cg_invariant_errors(P, Q, R, Z, b, op,
                                            use_pallas=False)
            np.testing.assert_allclose(orth, want_orth, rtol=1e-12)
            np.testing.assert_allclose(rel, want_rel, rtol=1e-10)

    def test_cg_errors_dense_route_through_pallas_interpret(self):
        P, Q, R, Z, b, S = self._cg_batch(1, T=3, n=16)
        xla = cg_invariant_errors(P, Q, R, Z, b, ("dense", S),
                                  use_pallas=False)
        pal = cg_invariant_errors(P, Q, R, Z, b, ("dense", S),
                                  use_pallas=True, interpret=True)
        for a, p in zip(xla, pal):
            np.testing.assert_allclose(p, a, rtol=1e-9)

    def test_cg_errors_unknown_operator_kind_raises(self):
        P, Q, R, Z, b, S = self._cg_batch(2, T=2, n=8)
        with pytest.raises(ValueError):
            cg_invariant_errors(P, Q, R, Z, b, ("csr", S))

    def test_cg_route_spellings(self):
        assert cg_route(use_pallas=True) == "dense"
        assert cg_route(use_pallas=False) == "sparse"
        assert cg_route() in ("dense", "sparse")

    def _mm_batch(self, seed, B=4, m=17):
        rng = np.random.default_rng(seed)
        V = np.zeros((B, m, m))
        V[:, :-1, :-1] = rng.normal(size=(B, m - 1, m - 1))
        V[:, :-1, -1] = V[:, :-1, :-1].sum(axis=2)
        V[:, -1, :-1] = V[:, :-1, :-1].sum(axis=1)
        return V

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_mm_stats_match_numpy_oracle(self, use_pallas):
        V = self._mm_batch(3)
        V[1] = 0.0                    # an all-lost chunk image
        V[2, 4, 5] += 7.5             # one corrupted element
        nonzero, absmax, rowmax, colmax = mm_chunk_stats(
            V, use_pallas=use_pallas, interpret=use_pallas)
        np.testing.assert_array_equal(nonzero, V.any(axis=(1, 2)))
        np.testing.assert_allclose(absmax, np.abs(V).max(axis=(1, 2)))
        want_row = np.abs(V[:, :-1, -1]
                          - V[:, :-1, :-1].sum(axis=2)).max(axis=1)
        want_col = np.abs(V[:, -1, :-1]
                          - V[:, :-1, :-1].sum(axis=1)).max(axis=1)
        np.testing.assert_allclose(rowmax, want_row, atol=1e-9)
        np.testing.assert_allclose(colmax, want_col, atol=1e-9)
        # intact slabs have ~0 residual; the corrupted one stands out
        assert rowmax[0] < 1e-9 and rowmax[2] > 1.0

    def test_gemm_batch_pallas_interpret_matches_jnp(self):
        import jax.numpy as jnp
        from repro.kernels.abft_matmul.ops import gemm_batch

        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.normal(size=(9, 33)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(33, 21)), jnp.float32)
        got = gemm_batch(a, b, acc_dtype=jnp.float32,
                         use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-4)

    def test_kv_row_checksums_match_host_mixer(self):
        from repro.scenarios.kv import _mix_words

        rng = np.random.default_rng(6)
        rows = rng.integers(-(1 << 40), 1 << 40, size=(37, 7),
                            dtype=np.int64)
        got = kv_row_checksums(rows)
        want = np.array([_mix_words(r) for r in rows], dtype=np.int64)
        np.testing.assert_array_equal(got, want)
        assert kv_row_checksums(np.empty((0, 7), np.int64)).shape == (0,)

    def test_kv_value_match_matches_host_values(self):
        from repro.scenarios.kv import _value_words

        rng = np.random.default_rng(7)
        keys = rng.integers(0, 40, size=12).astype(np.int64)
        seqs = rng.integers(1, 99, size=12).astype(np.int64)
        nws = rng.integers(1, 9, size=12).astype(np.int64)
        got = np.zeros((12, 8), np.int64)
        for i in range(12):
            got[i, :nws[i]] = _value_words(int(keys[i]), int(seqs[i]),
                                           int(nws[i]))
        got[3, 0] ^= 1                     # one corrupted word
        ok = kv_value_match(keys, seqs, got, nws)
        want = np.ones(12, bool)
        want[3] = False
        np.testing.assert_array_equal(ok, want)

    @pytest.mark.parametrize("fifo", [False, True])
    @pytest.mark.parametrize("is_write", [False, True])
    def test_cache_op_update_matches_naive_transition(self, fifo, is_write):
        rng = np.random.default_rng(8)
        m = 23
        present = rng.random(m) < 0.6
        dirty = present & (rng.random(m) < 0.5)
        stamp = rng.integers(1, 50, size=m).astype(np.int64)
        t0 = 100
        new_p, new_d, new_s, miss, n_miss = cache_op_update(
            present.copy(), dirty.copy(), stamp.copy(), t0, is_write, fifo)
        assert new_p.all()
        np.testing.assert_array_equal(miss, ~present)
        assert n_miss == int((~present).sum())
        pos = np.arange(m, dtype=np.int64)
        if fifo:                           # hits keep their stamp
            np.testing.assert_array_equal(
                new_s, np.where(~present, t0 + pos, stamp))
        else:                              # LRU: every touch restamps
            np.testing.assert_array_equal(new_s, t0 + pos)
        want_d = np.ones(m, bool) if is_write else (dirty & present)
        np.testing.assert_array_equal(new_d, want_d)

    def test_queue_validity_matches_naive_scan(self):
        rng = np.random.default_rng(9)
        n = 40
        present = rng.random(n) < 0.7
        stamp = rng.integers(1, 30, size=n).astype(np.int64)
        ents = rng.integers(0, n, size=17).astype(np.int64)
        stamps = np.where(rng.random(17) < 0.5, stamp[ents],
                          stamp[ents] - 1).astype(np.int64)
        valid, wts = queue_validity(present, stamp, ents, stamps, 3)
        want_valid = present[ents] & (stamp[ents] == stamps)
        np.testing.assert_array_equal(valid, want_valid)
        np.testing.assert_array_equal(wts, np.where(want_valid, 3, 0))

    def test_tile_sums_batch_pallas_interpret_matches_jnp(self):
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        from repro.kernels.checksum_verify.ops import tile_sums_batch

        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(3, 18, 26)), jnp.float32)
        # f64 accumulation needs the x64 context the engine runs under
        with enable_x64():
            rows, cols = tile_sums_batch(x, acc_dtype=jnp.float64,
                                         use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(rows),
                                   np.asarray(x, np.float64).sum(2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cols),
                                   np.asarray(x, np.float64).sum(1),
                                   rtol=1e-5, atol=1e-5)
