"""Pallas TPU kernel: blocked MXU matmul with fused ABFT-checksum epilogue.

TPU adaptation of the paper's §III.C mechanism (see DESIGN.md §2): on
x86/NVM the algorithm computes the product and then *selectively flushes
checksum cache lines*; on TPU the idiomatic equivalent is to generate the
checksums in the matmul epilogue while the accumulator tile is still in
VMEM, so the checksums ride the same HBM write stream as the result tile
— zero extra passes over C.

Grid is (m/bm, n/bn, k/bk) with the contraction dimension innermost; a
float32 VMEM scratch accumulates partial products across the k blocks
(MXU-aligned 128x128x128 default tiles). At the last k step the epilogue
writes, per (i, j) tile:

  * the C tile itself (cast to the output dtype),
  * a (bm, 1) row partial sum    -> row_partials[:, j]
  * a (1, bn) column partial sum -> col_partials[i, :]

The tiny cross-tile reductions (summing partials over j / i) happen in
ops.py as jnp ops — XLA fuses them, and keeping the kernel free of
cross-tile accumulation avoids revisit-ordering hazards in the Mosaic
pipeline.

VMEM budget at the default 128-tile: a(64KB f32) + b(64KB) + acc(64KB) +
c(64KB) + partials(~1KB) ≈ 256KB double-buffered ≈ 512KB — comfortably
inside the 16MB/core VMEM of v5e, leaving room for the pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["abft_matmul_pallas", "DEFAULT_BM", "DEFAULT_BN", "DEFAULT_BK"]

# MXU-native tile sizes (v5e systolic array is 128x128)
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _abft_mm_kernel(a_ref, b_ref, c_ref, rowp_ref, colp_ref, acc_ref):
    """One (i, j, kk) grid step."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # accumulate in the scratch dtype (acc_dtype below): f32 feeds the
    # MXU fast path, f64 the batched sweep's bit-stable CG invariants
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        c_ref[...] = acc.astype(c_ref.dtype)
        # fused ABFT epilogue: checksum partials leave VMEM with the tile
        rowp_ref[...] = jnp.sum(acc, axis=1, keepdims=True)
        colp_ref[...] = jnp.sum(acc, axis=0, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "acc_dtype", "interpret"),
)
def abft_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=None,
    acc_dtype=jnp.float32,
    interpret: bool = False,
):
    """C = a @ b with fused row/col checksum partials.

    a: (m, k), b: (k, n); m % bm == k % bk == n % bn == 0 (ops.py pads).
    Returns (C (m,n) out_dtype, row_partials (m, n/bn) acc_dtype,
             col_partials (m/bm, n) acc_dtype); the VMEM accumulator is
    ``acc_dtype`` too (default f32 — the historical behavior).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"unpadded shapes ({m},{k},{n}) vs blocks ({bm},{bk},{bn})")
    out_dtype = out_dtype or a.dtype
    mi, nj = m // bm, n // bn

    return pl.pallas_call(
        _abft_mm_kernel,
        grid=(mi, nj, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((m, nj), acc_dtype),
            jax.ShapeDtypeStruct((mi, n), acc_dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a, b)
