"""Paper Figs. 10 + 12: XSBench result correctness after crash+restart.

Three runs on identical counter-based random inputs:
  no crash                       -> ground truth counts
  basic restart (index flush)    -> loses counts (Fig. 10's failure)
  selective flush (Fig. 11)      -> bitwise-identical counts (Fig. 12)
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.algorithms.xsbench import ADCC_XSBench, XSBenchConfig
from repro.core.nvm import NVMConfig

from .common import Row, emit

CFG = XSBenchConfig(lookups=60_000, grid_points=20_000)
NVM = NVMConfig(cache_bytes=2 * 1024 * 1024, replacement="fifo")
CRASH_AT = 6_000   # 10% of lookups, as in the paper


def run() -> List[Row]:
    rows = []
    ok = ADCC_XSBench(CFG, NVM, policy="selective").run()
    basic = ADCC_XSBench(CFG, NVM, policy="basic").run(crash_at=CRASH_AT)
    sel = ADCC_XSBench(CFG, NVM, policy="selective").run(crash_at=CRASH_AT)

    for t in range(5):
        rows.append(Row(f"fig10/type{t+1}/no_crash_pct",
                        100 * ok.fractions[t]))
        rows.append(Row(f"fig10/type{t+1}/basic_restart_pct",
                        100 * basic.fractions[t]))
        rows.append(Row(f"fig12/type{t+1}/selective_restart_pct",
                        100 * sel.fractions[t]))
    rows.append(Row("fig10/basic_restart/counts_lost",
                    CFG.lookups - int(basic.counts.sum()),
                    f"iterations_lost={basic.iterations_lost}"))
    rows.append(Row("fig12/selective_restart/exact_match",
                    float(np.array_equal(sel.counts, ok.counts)),
                    "counts bitwise-identical to no-crash run"))
    rows.append(Row("fig12/selective_restart/iterations_lost",
                    sel.iterations_lost,
                    f"bound={int(CFG.lookups*CFG.flush_every_frac)}"))
    return rows


def main() -> None:
    emit(run(), save_as="fig10_12_mc_correctness.json")


if __name__ == "__main__":
    main()
