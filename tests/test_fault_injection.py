"""Tests for the fault-injection layer: media faults (seeded
poisoned-line / bit-flip corruption of the post-crash image),
nested-crash traps (power fails again *during* recovery), the
golden-compare recovery harness and its correctness classes
(``recovery_idempotent`` / ``recovery_diverged`` / ``fault_detected``
/ ``fault_silent``), and the pre-step-0 scratch-restart certification.

The behavioral pins here are deliberate: every class assertion below
was observed on the seeded cell it names, so a refactor that changes
*which* class a cell lands in (not just whether the gates hold in
aggregate) fails loudly with the exact cell in hand.
"""

import numpy as np
import pytest

from repro.core.backends import MediaFault, corrupt_image_words
from repro.core.nvm import CrashEmulator, NestedCrashFault, NVMConfig
from repro.scenarios import (
    CrashPlan,
    FaultSpec,
    deterministic_cell_dict,
    measure_divergence_fields,
    run_scenario,
    sweep,
)

SMALL = NVMConfig(cache_bytes=512 * 1024)

CG = ("cg", {"n": 1024, "iters": 8, "seed": 3})
MM = ("mm", {"n": 64, "k": 16, "seed": 1})
XS = ("xsbench", {"lookups": 600, "grid_points": 800, "n_nuclides": 8,
                  "n_materials": 6, "max_nuclides_per_material": 4,
                  "flush_every_frac": 0.02, "seed": 7})
KV = ("kv", {"profile": "etc", "n_steps": 24, "seed": 11})

NEST1 = FaultSpec(nested_after=1, seed=7)
NEST3 = FaultSpec(nested_after=3, nested_fraction=0.5, seed=8)


class TestMediaFault:
    def test_validation(self):
        with pytest.raises(ValueError):
            MediaFault(words=0)
        with pytest.raises(ValueError):
            MediaFault(kind="rowhammer")

    def test_describe(self):
        assert MediaFault(words=3, seed=9).describe() == "poison:w3:s9"
        assert MediaFault(kind="bitflip").describe() == "bitflip:w1:s0"

    def _image(self):
        return {"a": np.arange(64.0), "b": np.ones(32)}

    def test_corrupt_is_seeded_and_counts_words(self):
        img1, img2 = self._image(), self._image()
        spans1 = corrupt_image_words(img1, MediaFault(words=4, seed=5))
        spans2 = corrupt_image_words(img2, MediaFault(words=4, seed=5))
        assert spans1 == spans2 and len(spans1) == 4
        assert np.array_equal(img1["a"], img2["a"])
        assert np.array_equal(img1["b"], img2["b"])
        spans3 = corrupt_image_words(self._image(),
                                     MediaFault(words=4, seed=6))
        assert spans3 != spans1

    def test_corrupt_always_changes_the_word(self):
        # every corrupted span must differ from the clean image — a
        # silent no-op would make the detection gates vacuous
        for kind in ("poison", "bitflip"):
            img, clean = self._image(), self._image()
            spans = corrupt_image_words(img, MediaFault(words=6, seed=0,
                                                        kind=kind))
            for name, lo, hi in spans:
                assert not np.array_equal(
                    img[name].view(np.uint8)[lo:hi],
                    clean[name].view(np.uint8)[lo:hi]), (kind, name, lo)

    def test_region_restriction(self):
        img, clean = self._image(), self._image()
        spans = corrupt_image_words(img, MediaFault(words=3, seed=1),
                                    region_names=["b"])
        assert {name for name, _, _ in spans} == {"b"}
        assert np.array_equal(img["a"], clean["a"])

    def test_words_capped_at_population(self):
        img = {"a": np.arange(4.0)}      # 4 words of 8 bytes
        spans = corrupt_image_words(img, MediaFault(words=99, seed=2))
        assert len(spans) == 4

    def test_byte_identical_across_backends(self, monkeypatch):
        """The emulator-level injection contract: same fault, same
        post-crash image bytes, under the reference oracle and the
        vectorized backend."""
        views = {}
        for backend in ("reference", "vectorized"):
            monkeypatch.setenv("REPRO_NVM_BACKEND", backend)
            emu = CrashEmulator(NVMConfig(cache_bytes=256, line_bytes=64))
            r = emu.alloc("x", (64,))
            r[...] = np.arange(64.0)
            r.flush()
            emu.crash()
            spans = emu.inject_media_fault(MediaFault(words=5, seed=3))
            views[backend] = (spans, np.array(r.view))
        ref_spans, ref_view = views["reference"]
        vec_spans, vec_view = views["vectorized"]
        assert ref_spans == vec_spans
        assert np.array_equal(ref_view, vec_view)

    def test_injection_requires_crashed_emulator(self):
        emu = CrashEmulator(NVMConfig(cache_bytes=256))
        emu.alloc("x", (8,))
        with pytest.raises(RuntimeError, match="crashed"):
            emu.inject_media_fault(MediaFault())


class TestFaultSpec:
    def test_requires_a_fault_axis(self):
        with pytest.raises(ValueError):
            FaultSpec()

    def test_nested_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(nested_after=0)
        with pytest.raises(ValueError):
            FaultSpec(nested_after=1, nested_crashes=0)
        # the final attempt must be allowed to complete: a spec whose
        # budget the nested crashes exhaust can never certify anything
        with pytest.raises(ValueError):
            FaultSpec(nested_after=1, nested_crashes=3, max_attempts=3)

    def test_poison_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(poison_words=1, poison_kind="rowhammer")

    def test_describe_is_stable(self):
        assert FaultSpec(nested_after=2, seed=7).describe() == \
            FaultSpec(nested_after=2, seed=7).describe()
        assert FaultSpec(nested_after=2).describe() != \
            FaultSpec(poison_words=2).describe()

    def test_nested_survival_is_seeded_per_firing(self):
        fs = FaultSpec(nested_after=1, nested_fraction=0.5, seed=4)
        a, b = fs.nested_survival(0), fs.nested_survival(0)
        assert (a.fraction, a.seed) == (b.fraction, b.seed)
        assert fs.nested_survival(1).seed != a.seed

    def test_resolve_poison_regions_glob(self):
        fs = FaultSpec(poison_words=1, poison_regions=("C_s*",))
        live = ["C_s0", "C_s1", "C_temp"]
        assert fs.resolve_poison_regions(live) == ["C_s0", "C_s1"]
        assert FaultSpec(poison_words=1).resolve_poison_regions(live) \
            == live

    def test_resolve_poison_regions_unknown_matches_nothing(self):
        # a scope that matches no live region injects nothing (the
        # fig_faults gates flag injected==0 as a mis-scoped campaign)
        fs = FaultSpec(poison_words=1, poison_regions=("nope", "als*"))
        assert fs.resolve_poison_regions(["C"]) == []


class TestNestedTrap:
    def _emu(self):
        emu = CrashEmulator(NVMConfig(cache_bytes=4096, line_bytes=64))
        emu.alloc("x", (64,))
        return emu

    def test_trap_fires_after_k_actions(self):
        emu = self._emu()
        emu.arm_nested_crash(3)
        emu.write("x", 0, 8)
        emu.write("x", 8, 16)
        with pytest.raises(NestedCrashFault):
            emu.write("x", 16, 24)
        # the trap is one-shot: it disarmed itself when it fired
        emu.write("x", 24, 32)

    def test_reads_count_as_actions(self):
        emu = self._emu()
        emu.arm_nested_crash(1)
        with pytest.raises(NestedCrashFault):
            emu.read("x", 0, 8)

    def test_disarm(self):
        emu = self._emu()
        emu.arm_nested_crash(1)
        emu.disarm_nested_crash()
        emu.write("x", 0, 8)

    def test_arm_validation(self):
        with pytest.raises(ValueError):
            self._emu().arm_nested_crash(0)


class TestNestedRecovery:
    """Pinned golden-compare outcomes for seeded nested-crash cells.
    ``recovery_idempotent`` certifies the retried recovery reached the
    single-crash golden state (same restart point AND same digest);
    ``consistent_rollback`` on a nested plan means the trap never fired
    (that recovery performs too few counted actions)."""

    @pytest.mark.parametrize("strategy", ["adcc", "undo_log",
                                          "checkpoint_nvm@2",
                                          "shadow_snapshot@2"])
    def test_cg_torn_nested_is_idempotent(self, strategy):
        res = run_scenario(CG, strategy,
                           CrashPlan.at_fraction(0.6, torn=True, fault=NEST1),
                           cfg=SMALL)
        assert res.correctness_class == "recovery_idempotent"
        assert res.correct
        assert res.info["nested_crashes"] == 1
        assert res.info["recovery_attempts"] == 2
        assert res.fault == NEST1.describe()

    def test_mm_adcc_deep_nested_is_idempotent(self):
        """Retired standing finding, same seeded cell: ABFT-MM's ADCC
        recovery used to advance its persisted progress counter while
        re-executing chunks mid-recovery, so a deep re-crash stranded
        progress the data didn't back (``recovery_diverged``). Recovery
        now replays chunks with the counter pinned at its crash-time
        value (``replay=True``), so the retried recovery provably lands
        on the golden state — and fig_faults gates MM-adcc on zero
        ``recovery_diverged`` alongside the wholesale mechanisms."""
        res = run_scenario(MM, "adcc",
                           CrashPlan.at_fraction(0.7, fault=NEST3),
                           cfg=SMALL)
        assert res.correctness_class == "recovery_idempotent"
        assert res.correct
        assert res.info["recovery_golden_match"] is True
        assert res.info["nested_crashes"] == 1

    def test_mm_adcc_shallow_nested_is_idempotent(self):
        res = run_scenario(MM, "adcc",
                           CrashPlan.at_fraction(0.5, fault=NEST1),
                           cfg=SMALL)
        assert res.correctness_class == "recovery_idempotent"
        assert res.correct

    def test_undo_log_untorn_recovery_fires_no_trap(self):
        # an untorn crash leaves the undo log with nothing to roll back
        # at these points: recovery completes before one counted action
        res = run_scenario(CG, "undo_log",
                           CrashPlan.at_fraction(0.5, fault=NEST1),
                           cfg=SMALL)
        assert res.correctness_class == "consistent_rollback"
        assert res.info["nested_crashes"] == 0
        assert res.info["recovery_attempts"] == 1

    def test_kv_blind_recovery_fires_no_trap(self):
        # KV ADCC recovery is a read-mostly scan over host-side views —
        # zero counted emulator actions, so the trap cannot fire
        res = run_scenario(KV, "adcc",
                           CrashPlan.at_fraction(0.5, fault=NEST1),
                           cfg=SMALL)
        assert res.correctness_class == "consistent_rollback"
        assert res.info["nested_crashes"] == 0

    def test_kv_shadow_nested_is_idempotent(self):
        res = run_scenario(KV, "shadow_snapshot@2",
                           CrashPlan.at_fraction(0.5, fault=NEST1),
                           cfg=SMALL)
        assert res.correctness_class == "recovery_idempotent"
        assert res.correct

    def test_multiple_nested_crashes(self):
        fs = FaultSpec(nested_after=1, nested_crashes=2, max_attempts=4,
                       seed=7)
        res = run_scenario(XS, "checkpoint_nvm@2",
                           CrashPlan.at_fraction(0.5, fault=fs), cfg=SMALL)
        assert res.correctness_class == "recovery_idempotent"
        assert res.info["nested_crashes"] == 2
        assert res.info["recovery_attempts"] == 3


class TestPoisonDetection:
    """Pinned detect/miss outcomes for seeded poisoned-line cells."""

    CASES = [
        # (workload, poison_words, poison_regions)
        (CG, 2, None),
        (MM, 2, ("C", "C_s*")),
        (XS, 2, ("type_counter_*",)),
        (KV, 8, ("kv.index",)),
    ]

    @pytest.mark.parametrize("wl,words,regions", CASES,
                             ids=["cg", "mm", "xs", "kv"])
    def test_adcc_detects_poison(self, wl, words, regions):
        fp = FaultSpec(poison_words=words, seed=40, poison_regions=regions)
        res = run_scenario(wl, "adcc",
                           CrashPlan.at_fraction(0.5, fault=fp), cfg=SMALL)
        assert res.correctness_class == "fault_detected"
        assert res.info["fault_words_injected"] == words

    def test_undo_log_coverage_hole_is_detected(self):
        """Retired coverage hole, same seeded cell: this boundary crash
        leaves no open transaction, so rollback never ran and poison on
        committed spans used to sail through silently (the old pinned
        ``fault_silent``). Commits now stamp a crc32 per committed span
        and recovery validates the post-crash image against them, so the
        poisoned word is flagged — detection, not repair: the resumed
        run still finalizes wrong, but with a signal."""
        fp = FaultSpec(poison_words=2, seed=40)
        res = run_scenario(CG, "undo_log",
                           CrashPlan.at_fraction(0.5, fault=fp), cfg=SMALL)
        assert res.correctness_class == "fault_detected"
        assert res.info["payload_crc_mismatches"] > 0
        assert res.correct is False
        assert res.info["recovery_golden_match"] is False

    def test_checkpoint_restore_heals_poison(self):
        # wholesale restore rewrites every poisoned word from the
        # checkpoint: injected but harmless, ordinary class applies
        fp = FaultSpec(poison_words=2, seed=40)
        res = run_scenario(CG, "checkpoint_nvm@2",
                           CrashPlan.at_fraction(0.5, fault=fp), cfg=SMALL)
        assert res.correctness_class in ("consistent_rollback",
                                         "scratch_restart")
        assert res.correct
        assert res.info["fault_words_injected"] == 2

    def test_fault_field_round_trips_to_json(self):
        fp = FaultSpec(poison_words=2, seed=40)
        res = run_scenario(CG, "adcc",
                           CrashPlan.at_fraction(0.5, fault=fp), cfg=SMALL)
        assert res.fault == fp.describe()
        assert res.to_json_dict()["fault"] == fp.describe()
        clean = run_scenario(CG, "adcc", CrashPlan.at_fraction(0.5),
                             cfg=SMALL)
        assert clean.fault is None
        assert "fault" not in clean.to_json_dict()


class TestFaultSweepEngines:
    """Fault cells must stay engine- and mode-invariant like every
    other cell: fork == rerun on the deterministic payload, measure
    and batched emit nothing a full-execution cell contradicts."""

    KW = dict(
        workloads=(CG,),
        strategies=("adcc", "undo_log"),
        plans=(CrashPlan.at_fraction(0.6, torn=True, fault=NEST1),
               CrashPlan.at_fraction(0.5, fault=FaultSpec(poison_words=2,
                                                          seed=40))),
    )

    def test_fork_equals_rerun(self):
        fork = sweep(engine="fork", cfg=SMALL, **self.KW)
        rerun = sweep(engine="rerun", cfg=SMALL, **self.KW)
        # repr-compare: the silently-poisoned undo_log cell finalizes
        # with NaN error metrics on BOTH engines, and NaN != NaN would
        # fail dict equality on cells that actually agree
        assert [repr(deterministic_cell_dict(c)) for c in fork] == \
            [repr(deterministic_cell_dict(c)) for c in rerun]

    def test_measure_and_batched_match_full(self):
        full = sweep(engine="fork", cfg=SMALL, **self.KW)
        measure = sweep(mode="measure", cfg=SMALL, **self.KW)
        batched = sweep(mode="batched", cfg=SMALL, **self.KW)
        for got in (measure, batched):
            assert len(got) == len(full)
            for g, f in zip(got, full):
                assert measure_divergence_fields(g, f) == []


class TestScratchCertification:
    """Scratch restarts (restart_point < 0) certify against the
    pre-step-0 snapshot: the 'none' strategy's from-scratch restart is
    now a *certified* class, not an uncheckable one."""

    @pytest.mark.parametrize("wl", [CG, MM, XS, KV],
                             ids=["cg", "mm", "xs", "kv"])
    def test_none_strategy_scratch_is_certified(self, wl):
        cells = sweep(workloads=(wl,), strategies=("none",),
                      plans=(CrashPlan.at_fraction(0.5),), cfg=SMALL,
                      mode="measure")
        (cell,) = [c for c in cells if c.crash_step is not None]
        assert cell.correctness_class == "scratch_restart"
        assert cell.restart_point == -1
        assert cell.state_certified is True
