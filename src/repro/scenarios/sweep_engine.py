"""Prefix-sharing fork engine for crash-point sweeps.

All crash points of one (workload, strategy) pair share an identical
execution prefix — re-running it per cell is what made dense
recompute-vs-crash-point curves (paper figs 3/7/10-12, EasyCrash-style
batches of thousands of crash instances) O(cells × full run). This
engine is the WITCHER-style record/fork alternative: run the pair
forward ONCE, capture a snapshot at the sorted union of every plan's
crash points, then evaluate each cell by restoring its snapshot —
crash, recover, and execute only the tail. Cost per cell drops from
O(setup + prefix + tail) to O(restore + tail).

Snapshots capture the whole observable state: the emulator (truth
arrays, NVM image, volatile-cache occupancy/dirtiness/recency, traffic
stats incl. the float ``modeled_seconds``), host-side workload scalars,
and mechanism state (open undo-log transaction, checkpoint area, commit
counters). A forked tail therefore replays the exact trace the rerun
engine's tail would, and cells come out identical field-for-field
(``wall_seconds`` aside) — enforced by tests/test_scenarios.py and the
``sweep_timing`` benchmark's divergence check.

Correctness requirement: ``Workload.step(i)`` must be deterministic in
(state, i) — true for all three adapters (XSBench sampling is
counter-based SplitMix64 precisely so restarted runs replay the same
lookups, matching the paper's methodology).

Dense ladders (measure mode snapshots EVERY step) can outgrow RAM on
big workloads, so the snapshot dictionary optionally runs under an LRU
byte budget (:class:`SnapshotTier`, ``sweep(snapshot_budget_bytes=...)``
or ``REPRO_SNAPSHOT_BUDGET``). Over budget, the least-recently-used
snapshot's heavy payload is evicted under one of two policies:

  policy="spill"     serialize the payload to a per-run tempdir and
                     reload it byte-identical on the next access;
  policy="recompute" drop the payload and, on the next access, re-run
                     the golden prefix from the nearest retained
                     boundary snapshot (the pinned pre-step-0 snapshot
                     is the tier-0 root that always remains).

Either way the per-key metadata (step timings, footprint) stays
resident, the pinned pre-step-0 / completed-run snapshots are never
evicted, and every evaluated cell is byte-identical to the unbudgeted
sweep (tests/test_snapshot_tiering.py pins this cell-for-cell).
:class:`SnapshotTierStats` counts hits/spills/reloads/recomputes/bytes
and rides the results as ``info["snapshot_tier"]``.

Not public API — use ``repro.scenarios.sweep(engine="fork")``.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import shutil
import tempfile
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .crashplan import CrashPlan, CrashPoint
from .driver import ScenarioResult, _digests_equal, _finish, _measure
from .strategies import ConsistencyStrategy
from .workloads import Workload

__all__ = ["run_pair_forked", "SnapshotTier", "SnapshotTierStats",
           "SNAPSHOT_POLICIES"]

SNAPSHOT_POLICIES = ("spill", "recompute")


class _CellSnapshot:
    """State at one potential crash position, plus the timing of its
    (possibly partial — torn) final step."""

    __slots__ = ("wl_snap", "strat_snap", "wall_last", "modeled_last")

    def __init__(self, wl: Workload, strat: ConsistencyStrategy,
                 wall_last: float, modeled_last: float):
        self.wl_snap = wl.snapshot()
        self.strat_snap = strat.snapshot()
        self.wall_last = wall_last
        self.modeled_last = modeled_last

    @classmethod
    def from_parts(cls, wl_snap, strat_snap, wall_last: float,
                   modeled_last: float) -> "_CellSnapshot":
        """Reassemble from an already-captured payload (tier reload /
        recompute) without re-snapshotting the live workload."""
        snap = cls.__new__(cls)
        snap.wl_snap = wl_snap
        snap.strat_snap = strat_snap
        snap.wall_last = wall_last
        snap.modeled_last = modeled_last
        return snap

    def restore(self, wl: Workload, strat: ConsistencyStrategy) -> None:
        wl.restore_snapshot(self.wl_snap)
        strat.restore_snapshot(self.strat_snap)


def _payload_nbytes(obj) -> int:
    """Nominal byte footprint of a snapshot payload: the sum of every
    ndarray's nbytes in the nested dict/sequence/dataclass structure.
    Copy-on-write sharing across ladder snapshots is deliberately NOT
    discounted — the budget bounds what one restore materializes, and
    double-counting shared arrays only makes eviction conservative."""
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, dict):
        return sum(_payload_nbytes(v) for v in obj.values())
    if isinstance(obj, (list, tuple, set, frozenset)):
        return sum(_payload_nbytes(v) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(_payload_nbytes(getattr(obj, f.name))
                   for f in dataclasses.fields(obj))
    return 0


def _freeze_arrays(obj) -> None:
    """Re-mark a reloaded payload's arrays read-only — pickle does not
    round-trip the writeable flag, and live snapshots are immutable by
    contract (nvm.EmuSnapshot)."""
    if isinstance(obj, np.ndarray):
        if obj.flags.owndata:
            obj.flags.writeable = False
        return
    if isinstance(obj, dict):
        for v in obj.values():
            _freeze_arrays(v)
    elif isinstance(obj, (list, tuple, set, frozenset)):
        for v in obj:
            _freeze_arrays(v)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclasses.fields(obj):
            _freeze_arrays(getattr(obj, f.name))


@dataclasses.dataclass
class SnapshotTierStats:
    """One pair's snapshot-tier bookkeeping. Attached to every cell of
    the pair as ``info["snapshot_tier"]`` (info is excluded from cell
    dicts, so the stats never perturb engine-identity gates) and
    surfaced by the ``sweep_timing`` benchmark into BENCH_sweep.json."""

    policy: str = "spill"
    budget_bytes: int = 0
    hits: int = 0                  # payload was resident on access
    spills: int = 0                # payloads serialized to disk
    reloads: int = 0               # payloads deserialized back
    recomputes: int = 0            # payloads re-derived by prefix replay
    spilled_bytes: int = 0         # total bytes written to the spill dir
    resident_bytes: int = 0        # current in-RAM payload footprint
    resident_peak_bytes: int = 0   # high-water mark of resident_bytes

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class _TierEntry:
    __slots__ = ("payload", "wall_last", "modeled_last", "footprint",
                 "pinned", "path")


class SnapshotTier:
    """LRU byte-budget over the fork/measure snapshot ladder.

    Keys are the engine's ``(step, torn)`` snapshot positions. The
    heavy payload — the (workload, strategy) snapshot pair — is what
    the budget governs; per-key metadata (step timings, footprint)
    always stays resident, so an evicted key is still *known*, just
    not materialized. ``policy="spill"`` serializes evicted payloads
    to a per-run tempdir and reloads them byte-identical;
    ``policy="recompute"`` drops them and re-derives on miss through
    the ``regen`` callback (the engine's golden-prefix replay from the
    nearest retained boundary). Pinned keys — the pre-step-0 tier-0
    snapshot and the completed-run state — are never evicted: they are
    the recompute roots everything else can be re-derived from."""

    def __init__(self, budget_bytes: int, policy: str = "spill"):
        if policy not in SNAPSHOT_POLICIES:
            raise ValueError(f"unknown snapshot policy {policy!r}; "
                             f"choose from {SNAPSHOT_POLICIES}")
        self._budget = max(0, int(budget_bytes))
        self._policy = policy
        self._entries: "OrderedDict" = OrderedDict()
        self._regen: Optional[Callable] = None
        self._dir: Optional[str] = None
        self._seq = 0
        self.stats = SnapshotTierStats(policy=policy,
                                       budget_bytes=self._budget)

    def set_regen(self, fn: Callable) -> None:
        """Install the recompute-on-miss callback ``key -> (wl_snap,
        strat_snap)`` (the engine builds it after the golden pass)."""
        self._regen = fn

    def put(self, key, snap: _CellSnapshot, pin: bool = False) -> None:
        entry = _TierEntry()
        entry.payload = (snap.wl_snap, snap.strat_snap)
        entry.wall_last = snap.wall_last
        entry.modeled_last = snap.modeled_last
        entry.footprint = _payload_nbytes(entry.payload)
        entry.pinned = pin
        entry.path = None
        self._entries[key] = entry
        self._entries.move_to_end(key)
        self._admit(entry.footprint)
        self._shrink()

    def get(self, key) -> Optional[_CellSnapshot]:
        """The snapshot at ``key`` (None if never captured), reloading
        or recomputing an evicted payload transparently."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        self._entries.move_to_end(key)
        if entry.payload is not None:
            self.stats.hits += 1
        elif entry.path is not None:
            with open(entry.path, "rb") as fh:
                entry.payload = pickle.load(fh)
            _freeze_arrays(entry.payload)
            self.stats.reloads += 1
            self._admit(entry.footprint)
            self._shrink(keep=key)
        else:
            if self._regen is None:
                raise RuntimeError(
                    f"snapshot {key} was evicted and no regenerator is "
                    f"installed (engine bug)")
            entry.payload = tuple(self._regen(key))
            self.stats.recomputes += 1
            self._admit(entry.footprint)
            self._shrink(keep=key)
        wl_snap, strat_snap = entry.payload
        return _CellSnapshot.from_parts(wl_snap, strat_snap,
                                        entry.wall_last, entry.modeled_last)

    def nearest_boundary(self, bound: int) -> Tuple[int, bool]:
        """Greatest *materialized* (resident or spilled) boundary key
        ``(s, False)`` with ``s <= bound`` — the replay root a
        recompute-on-miss restores from. The pinned pre-step-0
        snapshot guarantees one always exists."""
        best = -1
        for (s, torn), entry in self._entries.items():
            if torn or s is None or s > bound or s <= best:
                continue
            if entry.payload is None and entry.path is None:
                continue
            best = s
        return (best, False)

    def close(self) -> None:
        """Delete the spill directory (idempotent)."""
        if self._dir is not None:
            shutil.rmtree(self._dir, ignore_errors=True)
            self._dir = None

    # -- internals ---------------------------------------------------------

    def _admit(self, nbytes: int) -> None:
        self.stats.resident_bytes += nbytes
        self.stats.resident_peak_bytes = max(self.stats.resident_peak_bytes,
                                             self.stats.resident_bytes)

    def _shrink(self, keep=None) -> None:
        """Evict LRU-first until the resident payload footprint fits
        the budget. ``keep`` (the key being returned right now) and
        pinned keys are skipped."""
        if self.stats.resident_bytes <= self._budget:
            return
        for key in list(self._entries):
            if self.stats.resident_bytes <= self._budget:
                break
            entry = self._entries[key]
            if entry.pinned or entry.payload is None or key == keep:
                continue
            if self._policy == "spill" and entry.path is None:
                # a payload spilled once never needs rewriting —
                # snapshots are immutable, so the file stays valid
                # across any number of reload/evict cycles
                entry.path = self._spill_path()
                with open(entry.path, "wb") as fh:
                    pickle.dump(entry.payload, fh,
                                protocol=pickle.HIGHEST_PROTOCOL)
                self.stats.spills += 1
                self.stats.spilled_bytes += os.path.getsize(entry.path)
            entry.payload = None
            self.stats.resident_bytes -= entry.footprint

    def _spill_path(self) -> str:
        if self._dir is None:
            self._dir = tempfile.mkdtemp(prefix="repro-snaptier-")
        self._seq += 1
        return os.path.join(self._dir, f"snap{self._seq:06d}.pkl")


def _make_regen(tier: SnapshotTier, wl: Workload,
                strat: ConsistencyStrategy) -> Callable:
    """Recompute-on-miss for one pair: restore the nearest retained
    boundary snapshot and re-run the golden prefix up to the evicted
    key's position. ``Workload.step`` is deterministic in (state, i)
    and boundary snapshots carry the traffic stats, so the recomputed
    payload is byte-identical to the evicted one (pinned by
    tests/test_snapshot_tiering.py)."""
    n = wl.n_steps

    def regen(key):
        step, torn = key
        bound = (n - 1) if step is None else step - 1
        root_key = tier.nearest_boundary(bound)
        tier.get(root_key).restore(wl, strat)
        # full steps up to the key's position; a torn key stops inside
        # its final step, before the strategy's persistence hook
        last_full = (n - 1) if step is None else (step - 1 if torn
                                                  else step)
        for i in range(root_key[0] + 1, last_full + 1):
            strat.before_step(i)
            wl.step(i)
            strat.after_step(i)
        if torn:
            strat.before_step(step)
            wl.step(step)
        return wl.snapshot(), strat.snapshot()

    return regen


def run_pair_forked(wl: Workload, strat: ConsistencyStrategy,
                    grounded: Sequence[Tuple[CrashPlan, List[CrashPoint]]],
                    progress=None, mode: str = "full",
                    snapshot_budget_bytes: Optional[int] = None,
                    snapshot_policy: str = "spill") -> List[ScenarioResult]:
    """Evaluate every cell of one set-up (workload, strategy) pair.

    ``grounded`` is the pre-resolved [(plan, [CrashPoint...]), ...] for
    this pair. Returns ScenarioResults in plan-major, point-minor order
    — the same order the rerun engine emits.

    ``mode="measure"`` evaluates each crashed cell as restore + crash +
    recover only — the recompute/restart fields are computed from the
    recovered state instead of executing the tail and ``finalize()``
    (see :func:`repro.scenarios.driver._measure`), dropping the
    per-cell cost from O(restore + tail) to O(restore + recover).
    no_crash cells always take the full path (it is already tail-free).

    Measure mode additionally captures a boundary snapshot at EVERY
    executed step (not just the wanted crash points): a recovered
    cell's restart point can land anywhere in the prefix, and the
    byte-certification closure (``state_certified``) needs the golden
    digest at exactly that step. Copy-on-write snapshots keep the
    ladder O(changed state) per step.

    ``snapshot_budget_bytes`` caps the ladder's resident footprint
    through a :class:`SnapshotTier` with the given ``snapshot_policy``
    (module docstring); the final tier stats ride every cell as
    ``info["snapshot_tier"]``. ``None`` (default) keeps the plain
    unbounded dictionary.
    """
    strat.attach(wl)
    emu = wl.emu
    n = wl.n_steps

    # the union of snapshot positions all plans need; (None, False) is
    # the completed-run state no_crash cells finalize from
    want = set()
    for _plan, points in grounded:
        for p in points:
            want.add((p.step, p.torn) if p.step is not None
                     else (None, False))

    # -- golden forward pass: one shared prefix execution -----------------
    need_full = (None, False) in want
    ladder = mode == "measure"   # boundary snapshot every step (certify)
    last_point = max((s for s, _ in want if s is not None), default=-1)
    snaps: Dict[Tuple[Optional[int], bool], _CellSnapshot] = {}
    tier: Optional[SnapshotTier] = None
    if snapshot_budget_bytes is not None:
        tier = SnapshotTier(snapshot_budget_bytes, snapshot_policy)

    def snap_put(key, snap: _CellSnapshot, pin: bool = False) -> None:
        if tier is None:
            snaps[key] = snap
        else:
            tier.put(key, snap, pin=pin)

    def snap_get(key) -> Optional[_CellSnapshot]:
        if tier is None:
            return snaps.get(key)
        return tier.get(key)

    wall: List[float] = []
    modeled: List[float] = []
    if ladder or tier is not None:
        # pre-step-0 snapshot: the golden state a scratch restart
        # (restart_point == -1) must reproduce — certifies that
        # ``Workload.reset()`` actually restores initial-state fidelity.
        # With a tier it is additionally the pinned tier-0 root every
        # recompute-on-miss can replay from
        snap_put((-1, False), _CellSnapshot(wl, strat, 0.0, 0.0), pin=True)
    for i in range(n):
        ts = time.perf_counter()
        m0 = emu.modeled_seconds()
        strat.before_step(i)
        wl.step(i)
        if (i, True) in want:   # torn: before the persistence hook
            torn_wall = time.perf_counter() - ts
            snap_put((i, True), _CellSnapshot(
                wl, strat, torn_wall, emu.modeled_seconds() - m0))
            # keep capture cost out of the step's recorded duration
            ts = time.perf_counter() - torn_wall
        strat.after_step(i)
        wall.append(time.perf_counter() - ts)
        modeled.append(emu.modeled_seconds() - m0)
        if (i, False) in want or ladder:
            snap_put((i, False), _CellSnapshot(wl, strat, wall[-1],
                                               modeled[-1]))
        if not need_full and i == last_point:
            break   # no plan needs the completed-run state
    if need_full:
        # captured BEFORE any finalize(): finalize may charge traffic
        # (CG reads z), and each no_crash cell must pay it exactly once
        snap_put((None, False), _CellSnapshot(wl, strat, 0.0, 0.0),
                 pin=True)
    if tier is not None:
        tier.set_regen(_make_regen(tier, wl, strat))

    def certify(rec) -> Optional[bool]:
        """Byte-certification: diff the recovered state's digest against
        the golden-prefix digest at the restart point. May leave ``wl``
        restored to the golden state — callers restore per cell."""
        r = rec.restart_point
        if r is None:
            return None
        if r < 0:
            r = -1               # scratch: certify against pre-step-0
        # the recovered digest FIRST: fetching the golden snapshot may
        # replay the prefix on ``wl`` (tier recompute-on-miss), which
        # would clobber the recovered state we are certifying
        recovered = wl.restart_digest(r)
        if recovered is None:
            return None
        golden_snap = snap_get((r, False))
        if golden_snap is None:
            return None
        wl.restore_snapshot(golden_snap.wl_snap)
        return _digests_equal(recovered, wl.restart_digest(r))

    # -- fork one cell per (plan, point) ----------------------------------
    results: List[ScenarioResult] = []
    for plan, points in grounded:
        for point in points:
            t0 = time.perf_counter()
            if point.step is None:
                snap = snap_get((None, False))
                snap.restore(wl, strat)
                res = _finish(wl, strat, point, plan.describe(),
                              recover=True, crashed=False,
                              wall_durs=wall, modeled_durs=modeled, t0=t0)
            else:
                snap = snap_get((point.step, point.torn))
                snap.restore(wl, strat)
                # prefix timings come from the golden run; the last
                # step's entry is partial for torn crashes, matching
                # what the rerun engine's broken-off loop records
                s = point.step
                durs = dict(wall_durs=wall[:s] + [snap.wall_last],
                            modeled_durs=modeled[:s] + [snap.modeled_last])
                if mode == "measure":
                    res = _measure(wl, strat, point, plan.describe(),
                                   t0=t0, certify=certify, **durs)
                else:
                    res = _finish(wl, strat, point, plan.describe(),
                                  recover=True, crashed=True, t0=t0, **durs)
            results.append(res)
            if progress is not None:
                progress(res)
    if tier is not None:
        tier_info = tier.stats.to_dict()
        for res in results:
            res.info["snapshot_tier"] = tier_info
        tier.close()
    return results
