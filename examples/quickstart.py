"""Quickstart: train a small LM with algorithm-directed crash consistence.

Runs a reduced llama3 config for 40 steps with the ADCC trainer, then
simulates a mid-run crash and shows bitwise-identical recovery.

    PYTHONPATH=src python examples/quickstart.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.launch.train import ADCCTrainer
from repro.models.registry import get_config


def main() -> None:
    cfg = get_config("llama3-8b").reduced()
    tcfg = TrainConfig(remat="none", total_steps=40, warmup_steps=4)
    workdir = tempfile.mkdtemp(prefix="quickstart_")
    print(f"== training {cfg.name} (reduced: {cfg.param_count()/1e6:.1f}M "
          f"params) with ADCC, workdir={workdir}")

    trainer = ADCCTrainer(cfg, tcfg, workdir, batch=8, seq=64, slot_every=8)
    res = trainer.run(steps=40, crash_at_step=25)
    print(f"\n!! simulated crash at step {res.final_step} "
          f"(async slot writes torn, process state lost)\n")

    resumed = ADCCTrainer(cfg, tcfg, workdir, batch=8, seq=64, slot_every=8)
    res2 = resumed.run(steps=40)
    print(f"\n== recovery: {res2.recovery_report}")
    print(f"== resumed from step {res2.resumed_from}, "
          f"final loss {res2.losses[-1]:.4f}")

    # prove bitwise equivalence against an uninterrupted run
    ref_dir = tempfile.mkdtemp(prefix="quickstart_ref_")
    ref = ADCCTrainer(cfg, tcfg, ref_dir, batch=8, seq=64, slot_every=8)
    ref_res = ref.run(steps=40, log_every=0)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        ref._final_params, resumed._final_params)))
    print(f"== max |param diff| vs uninterrupted run: {diff} "
          f"({'BITWISE IDENTICAL' if diff == 0 else 'MISMATCH'})")
    shutil.rmtree(workdir, ignore_errors=True)
    shutil.rmtree(ref_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
