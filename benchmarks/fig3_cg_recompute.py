"""Paper Fig. 3: CG recomputation cost vs input problem size.

A declarative scenario matrix over the unified driver: ADCC strategy,
crash at a fixed iteration, problem size swept. Reported: recomputation
time (detect + resume) normalized by the average per-iteration time, and
the number of iterations lost — small problems fit in cache and lose
everything, large problems lose ~1 iteration.
"""

from __future__ import annotations

from typing import List

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, run_scenario

from .common import Row, emit

ARTIFACT = "fig3_cg_recompute.json"

SIZES = [2048, 8192, 32768, 131072]   # paper: classes S, W, A, B/C
ITERS = 16
CRASH_AT = 14


def run() -> List[Row]:
    cfg = NVMConfig(cache_bytes=2 * 1024 * 1024)
    rows = []
    for n in SIZES:
        res = run_scenario(("cg", {"n": n, "iters": ITERS, "seed": n}),
                           "adcc", CrashPlan.at_step(CRASH_AT), cfg=cfg)
        norm = ((res.detect_seconds + res.resume_seconds)
                / max(res.avg_step_seconds, 1e-12))
        rows.append(Row(f"fig3/cg_recompute/n={n}/iters_lost",
                        res.steps_lost,
                        f"restart_iter={res.restart_point}"))
        rows.append(Row(f"fig3/cg_recompute/n={n}/normalized_recompute",
                        norm,
                        f"detect={res.detect_seconds:.4f}s "
                        f"resume={res.resume_seconds:.4f}s"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    main()
