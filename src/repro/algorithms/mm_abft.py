"""ABFT matrix multiplication with crash consistence (§III.C, Fig. 6).

The original ABFT rank-k-update loop (Fig. 5) cannot establish restartable
state: C_f is overwritten every iteration and its checksums only hold at
iteration boundaries. The paper's extension (Fig. 6) decomposes it into

  loop 1 — submatrix multiplications:  C_s_temp = A_c[:, s-block] @ B_r[s-block, :]
           each C_s_temp carries full row+column checksums; only the
           checksums are flushed (one row + one column per chunk);
  loop 2 — row-blocked additions into C_temp whose *row* checksums are
           established once per k-row block, flushed, and never
           overwritten afterwards.

After a crash, the checksum relationships (Eq. 6) identify exactly which
C_s_temp chunks / C_temp row blocks are consistent in NVM; torn ones are
recomputed (or, when the damage is a single element, corrected in place).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import abft
from ..core.nvm import CrashEmulator, NVMConfig
from ..core.regions import PersistentRegion
from ..core.versioned import FlushedCounter

__all__ = ["ABFTMatmul", "MMRunResult"]


@dataclasses.dataclass
class MMRunResult:
    C: np.ndarray                      # the (n, n) result (checksums stripped)
    crashed_in: Optional[str]          # None | "loop1" | "loop2"
    chunks_lost: int                   # inconsistent chunks / row-blocks
    corrected_elements: int            # fixed via checksums w/o recompute
    detect_seconds: float
    resume_seconds: float
    avg_chunk_seconds: float
    modeled_overhead_seconds: float
    max_error: float                   # vs numpy oracle


class ABFTMatmul:
    """C = A @ B with ABFT checksums and ADCC over the crash emulator."""

    def __init__(self, A: np.ndarray, B: np.ndarray, k: int,
                 cfg: Optional[NVMConfig] = None):
        n = A.shape[0]
        assert A.shape == (n, n) and B.shape == (n, n), "square matrices"
        assert n % k == 0, "contraction dim must be divisible by rank k"
        self.n, self.k = n, k
        self.nchunks = n // k
        self.A, self.B = np.asarray(A, np.float64), np.asarray(B, np.float64)
        self.Ac = abft.encode_cols(self.A)     # (n+1, n)
        self.Br = abft.encode_rows(self.B)     # (n, n+1)
        self.emu = CrashEmulator(cfg or NVMConfig())
        # inputs in NVM (read-mostly, coarse sectors), persisted up-front
        self._rAc = self.emu.alloc("Ac", self.Ac.shape, np.float64,
                                   init=self.Ac, sector_lines=16)
        self._rBr = self.emu.alloc("Br", self.Br.shape, np.float64,
                                   init=self.Br, sector_lines=16)
        self._rAc.flush(); self._rBr.flush()
        # per-chunk temporaries, each (n+1, n+1) with full checksums
        self.C_s: List[PersistentRegion] = [
            self.emu.alloc(f"C_s{s}", (n + 1, n + 1), np.float64, sector_lines=8)
            for s in range(self.nchunks)
        ]
        # accumulation target with row checksums
        self.C_temp = self.emu.alloc("C_temp", (n + 1, n + 1), np.float64,
                                     sector_lines=8)
        self.counter = FlushedCounter(self.emu, "mm_iter")
        # row-block decomposition of loop 2 over the n+1 rows
        self.row_blocks: List[Tuple[int, int]] = []
        r0 = 0
        while r0 < n + 1:
            self.row_blocks.append((r0, min(r0 + k, n + 1)))
            r0 = self.row_blocks[-1][1]

    # -- the two loops ------------------------------------------------------
    def _loop1_chunk(self, s: int, replay: bool = False) -> None:
        """C_s_temp = Ac[:, s*k:(s+1)*k] @ Br[s*k:(s+1)*k, :] + flush its
        checksum row and column. ``replay=True`` (recovery re-execution)
        must not advance the persisted progress counter: a nested crash
        mid-recovery would otherwise strand the counter past chunks whose
        data never persisted, shrinking the next attempt's scan range."""
        if not replay:
            self.counter.set(s)  # which chunk we are in (one line flush)
        k, n = self.k, self.n
        self.emu.read("Ac", 0, self.Ac.size)                 # stream inputs
        self.emu.read("Br", s * k * (n + 1), (s + 1) * k * (n + 1))
        block = self.Ac[:, s * k:(s + 1) * k] @ self.Br[s * k:(s + 1) * k, :]
        reg = self.C_s[s]
        reg[...] = block
        # flush row checksums (last column) and column checksums (last row):
        # the last row is contiguous; the last column is flushed per row
        # block to respect row-major line spans.
        reg.flush((n, slice(None)))                    # checksum row
        for (lo, hi) in self.row_blocks:               # checksum column cells
            for i in range(lo, min(hi, n)):
                reg.flush((i, slice(n, n + 1)))

    def _loop2_block(self, bi: int, replay: bool = False) -> None:
        """C_temp[rows] = sum_s C_s[rows]; flush the block's row checksums.
        ``replay=True``: see ``_loop1_chunk`` — recovery re-execution keeps
        the progress counter pinned at its crash-time value."""
        if not replay:
            self.counter.set(self.nchunks + bi)
        lo, hi = self.row_blocks[bi]
        acc = np.zeros((hi - lo, self.n + 1))
        for s in range(self.nchunks):
            self.emu.read(f"C_s{s}", lo * (self.n + 1), hi * (self.n + 1))
            acc += self.C_s[s].view[lo:hi, :]
        self.C_temp[lo:hi, :] = acc
        for i in range(lo, hi):                        # row checksum cells
            self.C_temp.flush((i, slice(self.n, self.n + 1)))

    # -- driver ---------------------------------------------------------------
    def run(self, crash_after: Optional[Tuple[str, int]] = None) -> MMRunResult:
        """Deprecated: run the two-loop ABFT MM. ``crash_after=("loop1",
        s)`` crashes right after chunk s of loop 1 completes (paper's
        crash test 1); ``("loop2", b)`` after row-block b of loop 2
        (crash test 2).

        This is a legacy shim over the unified scenario driver — use
        ``repro.scenarios.run_scenario(("mm", {...}), "adcc", plan)``.
        """
        warnings.warn(
            "ABFTMatmul.run() is deprecated; use repro.scenarios."
            "run_scenario(('mm', params), 'adcc', CrashPlan.at_phase(...))",
            DeprecationWarning, stacklevel=2)
        from ..scenarios import CrashPlan, run_scenario
        from ..scenarios.workloads import MMWorkload

        plan = CrashPlan.no_crash()
        if crash_after is not None:
            loop, idx = crash_after
            # old semantics: an out-of-range crash point simply never fires
            if (loop == "loop1" and 0 <= idx < self.nchunks) or (
                    loop == "loop2" and 0 <= idx < len(self.row_blocks)):
                plan = CrashPlan.at_phase(loop, idx)
        res = run_scenario(MMWorkload(impl=self), "adcc", plan)
        return MMRunResult(
            C=res.info["C"], crashed_in=res.info.get("crashed_in"),
            chunks_lost=res.info.get("chunks_lost", 0),
            corrected_elements=res.info.get("corrected_elements", 0),
            detect_seconds=res.detect_seconds,
            resume_seconds=res.resume_seconds,
            avg_chunk_seconds=res.avg_step_seconds,
            modeled_overhead_seconds=res.modeled_total_seconds,
            max_error=res.metrics["max_error"],
        )

    # -- recovery ---------------------------------------------------------------
    def _recover_loop1(self) -> Tuple[List[int], int, float]:
        """Verify every C_s_temp in NVM via its checksums; single-element
        damage is corrected in place, torn chunks are reported for
        recomputation. Returns (bad chunk ids, corrected count, seconds)."""
        bad: List[int] = []
        corrected = 0
        nbytes = 0
        upper = self.counter.nvm_value()  # chunks beyond this were never run
        for s in range(min(upper + 1, self.nchunks)):
            view = self.C_s[s].nvm
            nbytes += view.nbytes
            # an all-zero image means *nothing* of a started chunk reached
            # NVM — checksums hold trivially but the chunk is lost
            if np.any(view != 0) and abft.verify(view, rtol=1e-9, atol=1e-6):
                # consistent in NVM: reload it as truth
                self.C_s[s][...] = view
                continue
            fixed, nfix = abft.correct_single_error(view, rtol=1e-9, atol=1e-6)
            if fixed is not None:
                self.C_s[s][...] = fixed
                corrected += nfix
            else:
                bad.append(s)
        return bad, corrected, nbytes / self.emu.cfg.read_bw

    def _recover_loop2(self, blocks_started: int) -> Tuple[List[int], float]:
        """Row checksums of C_temp decide which row blocks are consistent."""
        view = self.C_temp.nvm
        n = self.n
        row_resid = view[:, n] - view[:, :n].sum(axis=1)
        scale = max(float(np.max(np.abs(view))), 1.0)
        tol = 1e-6 + 1e-9 * scale
        bad: List[int] = []
        for bi, (lo, hi) in enumerate(self.row_blocks[:blocks_started]):
            rows = row_resid[lo:hi]
            # all-zero row blocks of a *started* block are fully lost
            # (checksum relations hold trivially on zeros)
            if np.any(np.abs(rows) > tol) or not np.any(view[lo:hi, :] != 0):
                bad.append(bi)
            else:
                self.C_temp[lo:hi, :] = view[lo:hi, :]
        # (C_s chunk integrity is re-established by _recover_loop1 before
        # this runs — see run(); reloading them here would clobber chunks
        # that were just recomputed into truth.)
        return bad, view.nbytes / self.emu.cfg.read_bw
