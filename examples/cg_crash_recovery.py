"""Paper §III.B end to end: CG with algorithm-directed crash consistence,
driven through the unified scenario API.

Each run is one scenario cell — CG workload × ADCC strategy × a crash at
iteration 14. The driver kills the run, backward-scans the NVM image
with the two algorithm invariants (orthogonality p·q=0 and residual
r=b-Az), resumes, and reports the uniform ScenarioResult — comparing the
large-problem case (loses ~1 iteration) against the small-problem case
(cache holds everything: restart from scratch).

    PYTHONPATH=src python examples/cg_crash_recovery.py
"""

import numpy as np

from repro.algorithms.cg import make_spd_system, plain_cg
from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, run_scenario


def demo(n: int, label: str) -> None:
    print(f"\n== {label}: n={n} "
          f"(working set ≈ {(4 * n * 8 * 16) / 1e6:.1f} MB vs 2 MB cache)")
    res = run_scenario(("cg", {"n": n, "iters": 16, "seed": n}), "adcc",
                       CrashPlan.at_step(14),
                       cfg=NVMConfig(cache_bytes=2 * 1024 * 1024))
    print(f"   crash @ iter {res.crash_step}; invariant scan accepted "
          f"iteration {res.restart_point} "
          f"({res.steps_lost} iteration(s) lost)")
    recovery = res.info.get("recovery")
    if recovery is not None:
        for j, reports in zip(range(res.crash_step, -2, -1),
                              recovery.reports[:3]):
            line = ", ".join(f"{r.name}: {'OK' if r.ok else 'BAD'} "
                             f"({r.detail})" for r in reports)
            print(f"   iter {j}: {line}")
    A, b = make_spd_system(n, nnz_per_row=8, seed=n)
    err = float(np.max(np.abs(res.info["z"] - plain_cg(A, b, 16))))
    print(f"   resumed to completion; |z - z_ref|_max = {err:.2e} "
          f"({'CORRECT' if err < 1e-8 else 'WRONG'})")


def main() -> None:
    demo(65536, "large problem (paper: lose <= 1 iteration)")
    demo(1024, "small problem (paper: everything was cached -> restart)")


if __name__ == "__main__":
    main()
