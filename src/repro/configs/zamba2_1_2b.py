"""zamba2-1.2b — hybrid: 38 Mamba2 blocks + one shared attention+MLP
block invoked every 6 layers (Zamba weight-sharing), ssm_state=64.
[arXiv:2411.15242; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    rope_theta=10_000.0,
)
