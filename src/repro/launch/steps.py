"""Jittable train_step / serve_step builders with full sharding specs.

This is the single construction site used by the dry-run (lower+compile
against ShapeDtypeStructs), the real trainer (launch/train.py), and the
benchmarks — so what we roofline is exactly what we'd run.

train_step(params, opt_state, err_state, batch, rng) ->
    (new_params, new_opt_state, err_state, metrics, update_checksums)

The ``update_checksums`` output is the ADCC hook (paper §III.C adapted —
DESIGN.md §2): one f32 scalar per parameter tensor, the sum of the step's
applied update. Because optimizer updates are applied *additively*, the
persistent per-tensor checksum evolves as ``checksum += sum(update)`` — a
tiny synchronous write per step (the "flush one cache line" analogue)
that lets recovery verify which asynchronously-written state slots are
consistent (core/acc_state.py). Computing these sums costs one fused
reduction per tensor inside the already-jitted step: ignorable, exactly
as the paper requires.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import TrainConfig
from ..models.registry import ModelApi
from ..optim import compress_decompress, make_optimizer
from ..optim.adamw import AdafactorState, AdamWState
from ..sharding.partition import (PartitionRules, cache_shardings,
                                  params_shardings)

__all__ = ["build_train_step", "build_serve_step", "tree_checksums",
           "build_opt_shardings"]


def tree_checksums(tree) -> Any:
    """Per-leaf scalar checksums (f32 sums). Linear in the leaf, hence
    incrementally maintainable across additive updates."""
    return jax.tree.map(lambda x: jnp.sum(x.astype(jnp.float32)), tree)


def build_opt_shardings(tcfg: TrainConfig, rules: PartitionRules,
                        params_sh, axes):
    """Optimizer-state shardings. AdamW moments mirror their parameter's
    sharding exactly; Adafactor's factored stats drop the reduced logical
    dim (row stats lose the last axis, col stats the second-to-last)."""
    mesh = rules.mesh
    repl = NamedSharding(mesh, P())
    if tcfg.optimizer == "adafactor":
        is_axes = lambda t: (isinstance(t, tuple)
                             and all(isinstance(s, str) for s in t))

        def stat_sharding(ax):
            if len(ax) >= 2:
                return {
                    "row": NamedSharding(mesh, rules.spec(ax[:-1])),
                    "col": NamedSharding(mesh, rules.spec(ax[:-2] + ax[-1:])),
                }
            return {"v": NamedSharding(mesh, rules.spec(ax))}

        stats = jax.tree.map(stat_sharding, axes, is_leaf=is_axes)
        return AdafactorState(step=repl, stats=stats)
    return AdamWState(step=repl, m=params_sh, v=params_sh)


def build_train_step(api: ModelApi, tcfg: TrainConfig,
                     rules: PartitionRules, *, donate: bool = True,
                     batch_template=None):
    """Returns (jitted train_step, shardings dict, opt_init).

    ``batch_template``: pytree of arrays/ShapeDtypeStructs matching the
    batch — used to pin explicit DP input shardings (leaving the batch
    unannotated lets GSPMD replicate activations across the data axis)."""
    mesh = rules.mesh
    opt_init, opt_update = make_optimizer(tcfg)
    use_compression = tcfg.grad_compression == "int8"

    compute_dtype = jnp.dtype(api.cfg.compute_dtype)

    def to_compute(w):
        # bf16 compute copy of >=2D weights, cast *before* the layer scan
        # so FSDP all-gathers move bf16, not f32 masters (§Perf iter 3);
        # 1D params (norms, A_log, dt_bias) stay f32 for numerics.
        if w.dtype == jnp.float32 and w.ndim >= 2:
            return w.astype(compute_dtype)
        return w

    def train_step(params, opt_state, err_state, batch, rng):
        def loss_of(p):
            return api.loss_fn(jax.tree.map(to_compute, p), batch, mesh,
                               remat=tcfg.remat)

        loss, grads = jax.value_and_grad(loss_of)(params)
        if use_compression:
            grads, err_state = compress_decompress(grads, err_state, rng)
        updates, new_opt_state = opt_update(grads, opt_state, params)
        new_params = jax.tree.map(
            lambda p, u: p + u.astype(p.dtype), params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        # ADCC scalars: direct sums of the new state fuse into the update's
        # HBM pass (the tensors are already streaming through registers);
        # the update sums additionally give the *linearity chain*
        # cks_params[t] == cks_params[t-1] + cks_updates[t] used to verify
        # the ledger itself (core/acc_state.py).
        checksums = {
            "params": tree_checksums(new_params),
            "opt": tree_checksums(new_opt_state),
            "updates": tree_checksums(updates),
        }
        return new_params, new_opt_state, err_state, metrics, checksums

    # --- shardings -----------------------------------------------------------
    params_shapes, axes = api.abstract_init(jax.random.PRNGKey(0))
    params_sh = params_shardings(rules, axes)
    opt_sh = build_opt_shardings(tcfg, rules, params_sh, axes)
    err_sh = params_sh  # error-feedback buffers mirror params
    repl = NamedSharding(mesh, P())
    metrics_sh = {"loss": repl, "grad_norm": repl}
    checksums_sh = {
        "params": jax.tree.map(lambda _: repl, params_sh),
        "opt": jax.tree.map(lambda _: repl, opt_sh),
        "updates": jax.tree.map(lambda _: repl, params_sh),
    }
    from ..sharding.partition import batch_shardings
    batch_sh = (batch_shardings(rules, batch_template)
                if batch_template is not None else None)

    jitted = jax.jit(
        train_step,
        in_shardings=(params_sh, opt_sh, err_sh, batch_sh, repl),
        out_shardings=(params_sh, opt_sh, err_sh, metrics_sh, checksums_sh),
        donate_argnums=(0, 1, 2) if donate else (),
    )
    shardings = {"params": params_sh, "opt": opt_sh, "err": err_sh,
                 "axes": axes, "params_shapes": params_shapes}
    return jitted, shardings, opt_init


def build_serve_step(api: ModelApi, rules: PartitionRules, *,
                     batch: int, max_len: int, donate: bool = True):
    """One-token decode step builder. Returns (jitted serve_step,
    shardings dict)."""
    cfg = api.cfg
    mesh = rules.mesh
    assert api.decode_step is not None, f"{cfg.name} has no decode step"

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = api.decode_step(params, cache, tokens, pos, mesh)
        return logits, new_cache

    params_shapes, axes = api.abstract_init(jax.random.PRNGKey(0))
    params_sh = params_shardings(rules, axes)

    box = {}

    def cache_only():
        c, a = api.init_cache(batch, max_len)
        box["axes"] = a
        return c

    cache_shapes = jax.eval_shape(cache_only)
    cache_sh = cache_shardings(rules, box["axes"])
    dp = rules.table["batch"]
    repl = NamedSharding(mesh, P())
    tok_sh = NamedSharding(mesh, P(dp, None)) if dp is not None else repl
    # decode_step slices logits back to the *true* vocab (tables are
    # padded); keep the vocab dim sharded only when it still divides TP
    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logits_sh = NamedSharding(mesh, P(dp, None, vocab_ax))

    jitted = jax.jit(
        serve_step,
        in_shardings=(params_sh, cache_sh, tok_sh, repl),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,) if donate else (),
    )
    shardings = {"params": params_sh, "cache": cache_sh,
                 "params_shapes": params_shapes,
                 "cache_shapes": cache_shapes, "axes": axes,
                 "cache_axes": box["axes"]}
    return jitted, shardings
