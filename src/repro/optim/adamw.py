"""Optimizers: AdamW (fp32 state) and Adafactor (factored second moment).

Functional, pytree-native (no optax dependency in this environment).
AdamW is the default; Adafactor is the footprint option that makes the
trillion-parameter kimi-k2 optimizer state feasible (DESIGN.md §7) —
factored (row, col) second-moment statistics instead of a full fp32
tensor, no first moment.

Both expose the same interface:
  init(params)                       -> opt_state
  update(grads, opt_state, params)   -> (updates, new_opt_state)
and updates are *applied steps* (add to params), so the ADCC layer can
checksum them incrementally (core/acc_state.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig

__all__ = ["AdamWState", "make_optimizer", "adamw_init", "adamw_update",
           "adafactor_init", "adafactor_update", "lr_schedule"]


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(cfg: TrainConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = -(lr * (m_hat / (jnp.sqrt(v_hat) + cfg.eps)
                        + cfg.weight_decay * p.astype(jnp.float32)))
        return delta.astype(p.dtype), m_new, v_new

    leaves_g, treedef = jax.tree.flatten(grads)
    out = [upd(g, m, v, p) for g, m, v, p in zip(
        leaves_g, treedef.flatten_up_to(state.m),
        treedef.flatten_up_to(state.v), treedef.flatten_up_to(params))]
    updates = treedef.unflatten([o[0] for o in out])
    m_new = treedef.unflatten([o[1] for o in out])
    v_new = treedef.unflatten([o[2] for o in out])
    return updates, AdamWState(step=step, m=m_new, v=v_new)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; Shazeer & Stern 2018, simplified)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    stats: Any   # per-leaf: dict(row=, col=) for >=2D, dict(v=) for <2D


def adafactor_init(params) -> AdafactorState:
    def init_one(p):
        if p.ndim >= 2:
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          stats=jax.tree.map(init_one, params))


def adafactor_update(cfg: TrainConfig, grads, state: AdafactorState, params
                     ) -> Tuple[Any, AdafactorState]:
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
    eps = 1e-30

    def upd(g, s, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if p.ndim >= 2:
            row = decay * s["row"] + (1 - decay) * jnp.mean(g2, axis=-1)
            col = decay * s["col"] + (1 - decay) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True) + eps
            v_hat = (row / row_mean)[..., :, None] * col[..., None, :]
            new_s = {"row": row, "col": col}
        else:
            v_hat = decay * s["v"] + (1 - decay) * g2
            new_s = {"v": v_hat}
        update = g32 / jnp.sqrt(v_hat + eps)
        # update clipping (RMS <= 1) stabilizes warmup
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + eps)
        update = update / jnp.maximum(1.0, rms)
        delta = -(lr * (update + cfg.weight_decay * p.astype(jnp.float32)))
        return delta.astype(p.dtype), new_s

    leaves_g, treedef = jax.tree.flatten(grads)
    out = [upd(g, s, p) for g, s, p in zip(
        leaves_g, treedef.flatten_up_to(state.stats),
        treedef.flatten_up_to(params))]
    updates = treedef.unflatten([o[0] for o in out])
    stats = treedef.unflatten([o[1] for o in out])
    return updates, AdafactorState(step=step, stats=stats)


def make_optimizer(cfg: TrainConfig):
    """-> (init_fn, update_fn) per cfg.optimizer."""
    if cfg.optimizer == "adafactor":
        return adafactor_init, (lambda g, s, p: adafactor_update(cfg, g, s, p))
    return adamw_init, (lambda g, s, p: adamw_update(cfg, g, s, p))
