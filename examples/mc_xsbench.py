"""Paper §III.D end to end: Monte-Carlo XSBench with selective flushing,
as three scenario cells on identical counter-based random streams.

The flush policy is the algorithm-directed design choice, so it is a
*workload parameter*: "basic" (index-only flush) loses counts after a
crash+restart — the paper's Fig. 10 surprise — while "selective"
(Fig. 11) restarts bitwise-correct (Fig. 12).

    PYTHONPATH=src python examples/mc_xsbench.py
"""

import numpy as np

from repro.core.nvm import NVMConfig
from repro.scenarios import CrashPlan, run_scenario


def main() -> None:
    params = dict(lookups=60_000, grid_points=20_000, n_nuclides=34,
                  n_materials=12, max_nuclides_per_material=8,
                  flush_every_frac=1e-4, seed=7)
    nvm = NVMConfig(cache_bytes=2 * 1024 * 1024, replacement="fifo")
    crash = CrashPlan.at_step(params["lookups"] // 10 - 1)  # 10% in

    ok = run_scenario(("xsbench", {**params, "policy": "selective"}),
                      "adcc", CrashPlan.no_crash(), cfg=nvm)
    basic = run_scenario(("xsbench", {**params, "policy": "basic"}),
                         "adcc", crash, cfg=nvm)
    sel = run_scenario(("xsbench", {**params, "policy": "selective"}),
                       "adcc", crash, cfg=nvm)

    print("interaction-type fractions (%):")
    print(f"  {'type':>6s} {'no crash':>9s} {'basic':>9s} {'selective':>10s}")
    for t in range(5):
        print(f"  {t+1:>6d} {100*ok.info['fractions'][t]:>9.3f} "
              f"{100*basic.info['fractions'][t]:>9.3f} "
              f"{100*sel.info['fractions'][t]:>10.3f}")
    lookups = params["lookups"]
    print(f"\nbasic restart: lost "
          f"{lookups - int(basic.info['counts'].sum())} counts "
          f"({basic.steps_lost} iterations of stale counters)")
    print(f"selective flush: counts bitwise-identical to no-crash run: "
          f"{np.array_equal(sel.info['counts'], ok.info['counts'])} "
          f"(loss bound = {int(lookups * params['flush_every_frac'])} "
          f"lookups)")


if __name__ == "__main__":
    main()
