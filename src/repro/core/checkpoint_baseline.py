"""Traditional checkpoint baselines (paper test cases 2-4).

Checkpoint = copy every critical data object to a persistent target.
For memory-based targets (NVM-only / heterogeneous NVM+DRAM) checkpoint
is "data copy + cache flush" (paper §III.A); for the hard-drive target
it is a file-speed copy. Costs are charged through the emulator's
bandwidth model so the paper's Figure 4/8/13 comparisons reproduce.
"""

from __future__ import annotations

from typing import Dict, Iterable

import numpy as np

from .nvm import CrashEmulator
from .regions import PersistentRegion

__all__ = ["CheckpointBaseline", "CHECKPOINT_TARGETS"]

CHECKPOINT_TARGETS = ("hdd", "nvm_only", "nvm_dram")


class CheckpointBaseline:
    """Synchronous full-copy checkpoint of a set of regions."""

    def __init__(self, emu: CrashEmulator, target: str = "nvm_only"):
        if target not in CHECKPOINT_TARGETS:
            raise ValueError(f"target must be one of {CHECKPOINT_TARGETS}")
        self._emu = emu
        self.target = target
        # checkpoint area: name -> (step, array)
        self._area: Dict[str, np.ndarray] = {}
        self.last_step: int = -1

    def checkpoint(self, step: int, regions: Iterable[PersistentRegion]) -> float:
        """Copy all regions; returns modeled seconds charged."""
        cfg = self._emu.cfg
        stats = self._emu.store.stats
        before = stats.modeled_seconds
        for r in regions:
            data = r.view.copy()  # the copy itself (read side)
            nbytes = data.nbytes
            if self.target == "hdd":
                stats.modeled_seconds += nbytes / cfg.hdd_bw
            elif self.target == "nvm_only":
                # CPU-cache flush of the data object + copy into NVM area
                self._emu.flush(r.name)
                stats.charge_write(nbytes, cfg)
            else:  # nvm_dram: flush CPU caches AND copy through DRAM cache
                self._emu.flush(r.name)
                stats.charge_write(nbytes, cfg)
            self._area[r.name] = data
        if self.target == "nvm_dram":
            # the heterogeneous system must also flush its DRAM cache once
            # per checkpoint (memory copy of the DRAM-cache contents into
            # NVM — paper §III.A; this is what makes the small-object
            # XSBench checkpoints cost 13% on NVM/DRAM, Fig. 13)
            stats.modeled_seconds += cfg.dram_cache_bytes / cfg.dram_bw
            stats.charge_write(cfg.dram_cache_bytes, cfg)
        self.last_step = step
        return stats.modeled_seconds - before

    def restore(self) -> Dict[str, np.ndarray]:
        """Recovery: the checkpointed copies (always consistent)."""
        cfg = self._emu.cfg
        for data in self._area.values():
            self._emu.store.stats.charge_read(data.nbytes, cfg)
        return {k: v.copy() for k, v in self._area.items()}

    # -- snapshot / fork ------------------------------------------------------
    def state_snapshot(self) -> Dict[str, object]:
        # checkpoint() replaces area arrays wholesale and restore()
        # hands out copies, so a shallow dict copy is a true capture
        return {"last_step": self.last_step, "area": dict(self._area)}

    def restore_state(self, state: Dict[str, object]) -> None:
        self.last_step = state["last_step"]
        self._area = dict(state["area"])
