"""Undo-log transactions — the Intel PMEM (libpmemobj-style) baseline.

The paper's test case (5) uses the Intel NVM library's transaction
mechanism: before a tracked object is modified inside a transaction, its
old value is copied into a persistent undo log (log write + flush), the
modification is applied, and at commit the modified data is flushed and
the log discarded. On recovery, an open (uncommitted) transaction is
rolled back from the log, restoring the pre-transaction state.

This is the expensive path the paper measures at 4.3x (CG) / 5.5x (MM)
slowdown — every update pays old-value copy + two persist barriers.

Log integrity: every entry carries a checksum computed at append time
(libpmemobj stamps entries the same way). Recovery validates the log
oldest-to-newest and rejects everything from the first invalid entry on
— the *torn log-tail* rule: the log is sequential, so nothing after a
torn entry can be trusted. Because appends here are fenced (write +
flush charged per entry), every reachable crash leaves an intact log
and the rejection count is 0; the validator is the guard that makes
that a checked invariant rather than an assumption, and
tests/test_torn_crashes.py exercises the rejection path on a
hand-corrupted log.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .nvm import CrashEmulator
from .regions import PersistentRegion

__all__ = ["UndoLogTx", "TxManager", "RollbackReport"]


def _log_checksum(name: str, lo: int, hi: int, old: np.ndarray) -> int:
    h = zlib.crc32(name.encode())
    h = zlib.crc32(np.asarray([lo, hi], dtype=np.int64).tobytes(), h)
    return zlib.crc32(np.ascontiguousarray(old).tobytes(), h)


@dataclasses.dataclass(frozen=True)
class RollbackReport:
    """What rolling back an open transaction did."""

    entries_applied: int
    entries_rejected: int   # torn log-tail entries discarded unapplied


class UndoLogTx:
    """One transaction over a set of PersistentRegions."""

    def __init__(self, emu: CrashEmulator, tx_id: int):
        self._emu = emu
        self.tx_id = tx_id
        # persistent log: list of (region-name, lo, hi, old bytes, crc)
        self._log: List[Tuple[str, int, int, np.ndarray, int]] = []
        self._tracked: Dict[str, PersistentRegion] = {}
        self.committed = False

    def add(self, region: PersistentRegion) -> None:
        self._tracked[region.name] = region

    def snapshot(self, region: PersistentRegion, index=Ellipsis) -> None:
        """Copy-before-write: persist the old value of region[index] into
        the undo log. Charged as an NVM write of the old bytes plus the
        flush of the log entry (this is what makes PMEM transactions
        expensive for frequently-updated HPC arrays)."""
        from .regions import _flat_span

        lo, hi = _flat_span(region.shape, index)
        old = region._emu.truth_flat(region.name)[lo:hi].copy()
        self._log.append((region.name, lo, hi, old,
                          _log_checksum(region.name, lo, hi, old)))
        # log append is a persistent write + fence
        self._emu.store.stats.charge_write(old.nbytes, self._emu.cfg)
        self._emu.store.stats.charge_flush_issue(
            max(1, old.nbytes // self._emu.cfg.line_bytes), self._emu.cfg
        )

    def write(self, region: PersistentRegion, index, value) -> None:
        """Transactional store: snapshot old value, then write new."""
        self.snapshot(region, index)
        region[index] = value

    def commit(self) -> Dict[Tuple[str, int, int], int]:
        """Flush every region touched in the tx, then drop the log.

        Returns a crc32 per committed span, computed over the truth
        bytes the flush just persisted — the payload checksum recovery
        validates against the post-crash image so a media fault on a
        log-covered span cannot sail through silently (libpmemobj
        stamps committed object payloads the same way)."""
        crcs: Dict[Tuple[str, int, int], int] = {}
        for name, lo, hi, _old, _crc in self._log:
            self._emu.flush(name, lo, hi)
            span = self._emu.truth_flat(name)[lo:hi]
            crcs[(name, lo, hi)] = zlib.crc32(
                np.ascontiguousarray(span).tobytes())
        self._log.clear()
        self.committed = True
        return crcs

    def validate_log(self) -> int:
        """Index of the first invalid entry (== len(log) when the whole
        log checks out). The log is sequential, so entries past the
        first invalid one are unreachable — recovery must discard them
        (the torn log-tail rule)."""
        for k, (name, lo, hi, old, crc) in enumerate(self._log):
            if _log_checksum(name, lo, hi, old) != crc:
                return k
        return len(self._log)

    def rollback_after_crash(self) -> "RollbackReport":
        """Recovery path: validate the log, reject any torn tail, then
        apply the valid undo records (newest first) to the NVM image,
        restoring pre-transaction values.

        Re-entrant under nested crashes: each record routes through
        ``CrashEmulator.apply_undo`` (where the nested-crash trap can
        fire between records), and the log is cleared only after every
        record applied — a retry re-applies all of them, which is
        idempotent because undo records hold absolute old values."""
        valid = self.validate_log()
        rejected = len(self._log) - valid
        for name, lo, hi, old, _crc in reversed(self._log[:valid]):
            self._emu.apply_undo(name, lo, hi, old)
        self._log.clear()
        return RollbackReport(entries_applied=valid,
                              entries_rejected=rejected)

    # -- snapshot / fork ------------------------------------------------------
    def state_snapshot(self) -> Dict[str, object]:
        # log entries are write-once (old-value arrays are only ever
        # read after append), so a shallow list copy is a true capture
        return {"tx_id": self.tx_id, "committed": self.committed,
                "log": list(self._log)}

    @classmethod
    def from_state(cls, emu: CrashEmulator,
                   state: Dict[str, object]) -> "UndoLogTx":
        tx = cls(emu, state["tx_id"])
        tx._log = list(state["log"])
        tx.committed = state["committed"]
        return tx


class TxManager:
    """Issues transactions; remembers the open one for crash recovery.

    The undo log itself lives in NVM in a real PMEM system; we keep the
    entries in host memory but persist-charge every append, and replay
    them against the surviving NVM image on recovery — observationally
    equivalent for both cost and crash semantics.
    """

    def __init__(self, emu: CrashEmulator):
        self._emu = emu
        self._next_id = 0
        self.open_tx: UndoLogTx | None = None

    def begin(self) -> UndoLogTx:
        if self.open_tx is not None and not self.open_tx.committed:
            raise RuntimeError("nested transactions unsupported")
        tx = UndoLogTx(self._emu, self._next_id)
        self._next_id += 1
        self.open_tx = tx
        return tx

    def commit(self) -> Dict[Tuple[str, int, int], int]:
        assert self.open_tx is not None
        crcs = self.open_tx.commit()
        self.open_tx = None
        return crcs

    def recover(self) -> Optional[RollbackReport]:
        """Post-crash: roll back the open transaction, if any. Returns
        the :class:`RollbackReport` (truthy) if a rollback happened,
        ``None`` otherwise — so existing ``if mgr.recover():`` callers
        keep working while recovery code can see the torn-tail count."""
        if self.open_tx is not None and not self.open_tx.committed:
            report = self.open_tx.rollback_after_crash()
            self.open_tx = None
            return report
        return None

    # -- snapshot / fork ------------------------------------------------------
    def state_snapshot(self) -> Dict[str, object]:
        return {"next_id": self._next_id,
                "open_tx": (None if self.open_tx is None
                            else self.open_tx.state_snapshot())}

    def restore_state(self, state: Dict[str, object]) -> None:
        self._next_id = state["next_id"]
        self.open_tx = (None if state["open_tx"] is None
                        else UndoLogTx.from_state(self._emu,
                                                  state["open_tx"]))
