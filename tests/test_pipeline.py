"""Pipeline-parallelism tests: GPipe schedule == sequential execution."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_mesh
from repro.sharding.pipeline import pipeline_apply, stage_params


def _layer(w, h):
    return jnp.tanh(h @ w)


def _stage_fn(p_stage, act):
    h, _ = jax.lax.scan(lambda h, w: (_layer(w, h), None), act, p_stage)
    return h


def _sequential(W, x_all):
    h, _ = jax.lax.scan(lambda h, w: (_layer(w, h), None), x_all, W)
    return h


def test_single_stage_identity():
    L, D, n_micro, mb = 4, 16, 6, 2
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, D)), jnp.float32)
    mesh = make_mesh((1,), ("stage",))
    out = pipeline_apply(_stage_fn, stage_params(W, 1), x, mesh)
    ref = jax.vmap(lambda xx: _sequential(W, xx))(x)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0


def test_stage_params_split():
    W = jnp.arange(24.0).reshape(8, 3)
    s = stage_params(W, 4)
    assert s.shape == (4, 2, 3)
    assert np.array_equal(np.asarray(s[1, 0]), np.asarray(W[2]))


def test_four_stage_matches_sequential_subprocess():
    """Real multi-device GPipe (4 fake devices need their own process so
    the main test session keeps seeing 1 device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.sharding.pipeline import pipeline_apply, stage_params
        L, D, n_micro, mb = 8, 16, 6, 2
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.normal(size=(n_micro, mb, D)), jnp.float32)
        def layer(w, h): return jnp.tanh(h @ w)
        def stage_fn(p, act):
            h, _ = jax.lax.scan(lambda h, w: (layer(w, h), None), act, p)
            return h
        def seq(xx):
            h, _ = jax.lax.scan(lambda h, w: (layer(w, h), None), xx, W)
            return h
        mesh = make_mesh((4,), ("stage",))
        out = pipeline_apply(stage_fn, stage_params(W, 4), x, mesh)
        ref = jax.vmap(seq)(x)
        assert float(jnp.max(jnp.abs(out - ref))) == 0.0, "mismatch"
        print("OK")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=420)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
