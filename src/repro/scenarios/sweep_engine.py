"""Prefix-sharing fork engine for crash-point sweeps.

All crash points of one (workload, strategy) pair share an identical
execution prefix — re-running it per cell is what made dense
recompute-vs-crash-point curves (paper figs 3/7/10-12, EasyCrash-style
batches of thousands of crash instances) O(cells × full run). This
engine is the WITCHER-style record/fork alternative: run the pair
forward ONCE, capture a snapshot at the sorted union of every plan's
crash points, then evaluate each cell by restoring its snapshot —
crash, recover, and execute only the tail. Cost per cell drops from
O(setup + prefix + tail) to O(restore + tail).

Snapshots capture the whole observable state: the emulator (truth
arrays, NVM image, volatile-cache occupancy/dirtiness/recency, traffic
stats incl. the float ``modeled_seconds``), host-side workload scalars,
and mechanism state (open undo-log transaction, checkpoint area, commit
counters). A forked tail therefore replays the exact trace the rerun
engine's tail would, and cells come out identical field-for-field
(``wall_seconds`` aside) — enforced by tests/test_scenarios.py and the
``sweep_timing`` benchmark's divergence check.

Correctness requirement: ``Workload.step(i)`` must be deterministic in
(state, i) — true for all three adapters (XSBench sampling is
counter-based SplitMix64 precisely so restarted runs replay the same
lookups, matching the paper's methodology).

Not public API — use ``repro.scenarios.sweep(engine="fork")``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

from .crashplan import CrashPlan, CrashPoint
from .driver import ScenarioResult, _digests_equal, _finish, _measure
from .strategies import ConsistencyStrategy
from .workloads import Workload

__all__ = ["run_pair_forked"]


class _CellSnapshot:
    """State at one potential crash position, plus the timing of its
    (possibly partial — torn) final step."""

    __slots__ = ("wl_snap", "strat_snap", "wall_last", "modeled_last")

    def __init__(self, wl: Workload, strat: ConsistencyStrategy,
                 wall_last: float, modeled_last: float):
        self.wl_snap = wl.snapshot()
        self.strat_snap = strat.snapshot()
        self.wall_last = wall_last
        self.modeled_last = modeled_last

    def restore(self, wl: Workload, strat: ConsistencyStrategy) -> None:
        wl.restore_snapshot(self.wl_snap)
        strat.restore_snapshot(self.strat_snap)


def run_pair_forked(wl: Workload, strat: ConsistencyStrategy,
                    grounded: Sequence[Tuple[CrashPlan, List[CrashPoint]]],
                    progress=None, mode: str = "full") -> List[ScenarioResult]:
    """Evaluate every cell of one set-up (workload, strategy) pair.

    ``grounded`` is the pre-resolved [(plan, [CrashPoint...]), ...] for
    this pair. Returns ScenarioResults in plan-major, point-minor order
    — the same order the rerun engine emits.

    ``mode="measure"`` evaluates each crashed cell as restore + crash +
    recover only — the recompute/restart fields are computed from the
    recovered state instead of executing the tail and ``finalize()``
    (see :func:`repro.scenarios.driver._measure`), dropping the
    per-cell cost from O(restore + tail) to O(restore + recover).
    no_crash cells always take the full path (it is already tail-free).

    Measure mode additionally captures a boundary snapshot at EVERY
    executed step (not just the wanted crash points): a recovered
    cell's restart point can land anywhere in the prefix, and the
    byte-certification closure (``state_certified``) needs the golden
    digest at exactly that step. Copy-on-write snapshots keep the
    ladder O(changed state) per step.
    """
    strat.attach(wl)
    emu = wl.emu
    n = wl.n_steps

    # the union of snapshot positions all plans need; (None, False) is
    # the completed-run state no_crash cells finalize from
    want = set()
    for _plan, points in grounded:
        for p in points:
            want.add((p.step, p.torn) if p.step is not None
                     else (None, False))

    # -- golden forward pass: one shared prefix execution -----------------
    need_full = (None, False) in want
    ladder = mode == "measure"   # boundary snapshot every step (certify)
    last_point = max((s for s, _ in want if s is not None), default=-1)
    snaps: Dict[Tuple[Optional[int], bool], _CellSnapshot] = {}
    wall: List[float] = []
    modeled: List[float] = []
    if ladder:
        # pre-step-0 snapshot: the golden state a scratch restart
        # (restart_point == -1) must reproduce — certifies that
        # ``Workload.reset()`` actually restores initial-state fidelity
        snaps[(-1, False)] = _CellSnapshot(wl, strat, 0.0, 0.0)
    for i in range(n):
        ts = time.perf_counter()
        m0 = emu.modeled_seconds()
        strat.before_step(i)
        wl.step(i)
        if (i, True) in want:   # torn: before the persistence hook
            torn_wall = time.perf_counter() - ts
            snaps[(i, True)] = _CellSnapshot(
                wl, strat, torn_wall, emu.modeled_seconds() - m0)
            # keep capture cost out of the step's recorded duration
            ts = time.perf_counter() - torn_wall
        strat.after_step(i)
        wall.append(time.perf_counter() - ts)
        modeled.append(emu.modeled_seconds() - m0)
        if (i, False) in want or ladder:
            snaps[(i, False)] = _CellSnapshot(wl, strat, wall[-1],
                                              modeled[-1])
        if not need_full and i == last_point:
            break   # no plan needs the completed-run state
    if need_full:
        # captured BEFORE any finalize(): finalize may charge traffic
        # (CG reads z), and each no_crash cell must pay it exactly once
        snaps[(None, False)] = _CellSnapshot(wl, strat, 0.0, 0.0)

    def certify(rec) -> Optional[bool]:
        """Byte-certification: diff the recovered state's digest against
        the golden-prefix digest at the restart point. May leave ``wl``
        restored to the golden state — callers restore per cell."""
        r = rec.restart_point
        if r is None:
            return None
        if r < 0:
            r = -1               # scratch: certify against pre-step-0
        golden_snap = snaps.get((r, False))
        if golden_snap is None:
            return None
        recovered = wl.restart_digest(r)
        if recovered is None:
            return None
        wl.restore_snapshot(golden_snap.wl_snap)
        return _digests_equal(recovered, wl.restart_digest(r))

    # -- fork one cell per (plan, point) ----------------------------------
    results: List[ScenarioResult] = []
    for plan, points in grounded:
        for point in points:
            t0 = time.perf_counter()
            if point.step is None:
                snap = snaps[(None, False)]
                snap.restore(wl, strat)
                res = _finish(wl, strat, point, plan.describe(),
                              recover=True, crashed=False,
                              wall_durs=wall, modeled_durs=modeled, t0=t0)
            else:
                snap = snaps[(point.step, point.torn)]
                snap.restore(wl, strat)
                # prefix timings come from the golden run; the last
                # step's entry is partial for torn crashes, matching
                # what the rerun engine's broken-off loop records
                s = point.step
                durs = dict(wall_durs=wall[:s] + [snap.wall_last],
                            modeled_durs=modeled[:s] + [snap.modeled_last])
                if mode == "measure":
                    res = _measure(wl, strat, point, plan.describe(),
                                   t0=t0, certify=certify, **durs)
                else:
                    res = _finish(wl, strat, point, plan.describe(),
                                  recover=True, crashed=True, t0=t0, **durs)
            results.append(res)
            if progress is not None:
                progress(res)
    return results
