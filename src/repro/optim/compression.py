"""Gradient compression for cross-pod data parallelism.

int8 stochastic-rounding quantization with **error feedback**: the
quantization residual of step t is added back into the gradient at step
t+1, so compression error does not bias the long-run update direction
(Karimireddy et al., 2019). At 1000+ node scale the cross-pod (DCN)
all-reduce is the scarce resource — int8 cuts its bytes 4x vs f32 (2x vs
bf16); the roofline collective term measures exactly this.

The quantize/dequantize pair runs *inside* the jitted train step so XLA
fuses it around the all-reduce.

Scope note: under pjit/GSPMD the gradient all-reduce is inserted by the
partitioner inside autodiff, upstream of this hook — so this module
validates the *numerics* (stochastic rounding + error feedback
convergence, tested) while the wire payload stays at the native dtype.
Carrying int8 over the wire needs the gradient reduction pulled into an
explicit shard_map (quantize per-shard -> all_to_all int8 -> dequantize
-> local reduce), which is the designed follow-up; the interface here is
already shaped for it.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_decompress", "quantize_int8",
           "dequantize_int8"]


def quantize_int8(x: jax.Array, key) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor scale, stochastic rounding. -> (int8 values, f32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    scaled = x32 / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, error_state, key):
    """Error-feedback compression round trip: g' = deq(quant(g + e));
    e' = (g + e) - g'. Returns (g', e'). In the distributed step the
    int8 tensors are what cross the DCN all-reduce."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = treedef.flatten_up_to(error_state)
    keys = jax.random.split(key, len(leaves))
    outs, errs = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target, k)
        deq = dequantize_int8(q, scale)
        outs.append(deq.astype(g.dtype))
        errs.append(target - deq)
    return treedef.unflatten(outs), treedef.unflatten(errs)
