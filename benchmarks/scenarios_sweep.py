"""Scenario sweep: the full workloads × strategies × crash-points matrix
through ``repro.scenarios.sweep()`` in one call, on the vectorized
emulation backend. Emits one row per cell plus the machine-readable
``BENCH_scenarios.json`` artifact (the EasyCrash-style systematic
characterization of post-crash consistence).

Default matrix: 3 workloads × 6 strategies × 4 crash points = 72 cells.
``--smoke`` (or REPRO_SCENARIOS_SMOKE=1) shrinks it to the CI matrix:
3 workloads × 3 strategies × 2 crash plans. ``--engine fork|rerun``
selects the sweep engine (fork default).

This module also hosts the engine/mode comparison
(:func:`engine_timing` / :func:`run_timing`, surfaced as the ``sweep``
suite in benchmarks/run.py and benchmarks/sweep_timing.py): a dense
one-crash-point-per-step matrix timed under rerun, fork, and
fork+measure execution, plus the fig_torn dense torn matrix timed
under measure vs batched, plus a dense torn KV serving matrix timed in
measure mode (the ``kv_cells_per_second`` trend metric) AND re-timed in
batched mode against its analytic KV evaluators (the
``kv_batched_speedup`` trend metric, gated >= 3x with zero per-cell
fallbacks), plus a streaming-prefix emulator trace timed on the device
backend vs the vectorized host (the ``device_prefix_speedup`` trend
metric — the regime where the jit forward pass wins), plus a dense
fault-injection matrix — nested re-crash and poisoned-line plans —
timed in measure mode (the ``fault_cells_per_second`` trend metric),
plus a single-pair dense matrix point-sharded across workers (the
``pointshard_speedup`` trend metric) and re-swept under a 1-byte
snapshot budget in both tier policies (the ``snapshot_spill`` stats),
emitted to ``BENCH_sweep.json`` (the batched section also standalone
as ``BENCH_batched.json``), with the hard gates CI relies on:

  * fork vs rerun — identical deterministic payload cell-for-cell;
  * measure vs fork — every field a measure-mode cell emits equals the
    full-execution fork cell (``measure_divergence_fields``);
  * workers>1 vs workers=1 — the sharded sweep merges to the identical
    cell list;
  * batched vs measure — identical deterministic payload cell-for-cell
    on the torn matrix (and batched vs its own warm-up run —
    determinism across jit compilation states);
  * kv measure vs fork — every field the timed KV measure cells emit
    equals the full-execution cell;
  * kv batched vs measure — the analytic KV evaluators reproduce every
    measure cell of the timed KV matrix exactly (and agree with their
    own jit warm-up run), with ZERO cells falling back to per-cell
    measure (``info["batched_fallback"]``) and the batched sweep at
    least 3x faster than measure;
  * device prefix — the device backend's streaming trace ends with the
    byte-identical NVM image and traffic stats of the vectorized host;
  * fault measure vs fork — every field the timed fault-injection
    measure cells emit equals the full-execution cell;
  * point-sharded vs serial — splitting ONE pair's crash points across
    workers merges to the identical cell list (and, full-size on a
    host with >= POINTSHARD_WORKERS usable CPUs, runs >= 2x faster);
  * snapshot tiering — a budget that evicts every non-pinned snapshot
    (spill-to-disk AND recompute-on-miss) still merges to the
    unbudgeted cells exactly, with the tier counters proving the
    eviction paths actually ran.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List

import numpy as np

from repro.core.nvm import NVMConfig
from repro.scenarios import (DEFAULT_SWEEP_PLANS, CrashPlan, FaultSpec,
                             TornSpec, deterministic_cell_dict,
                             measure_divergence_fields, sweep)

from .common import ART, Row, emit, write_json

ARTIFACT = "scenarios_sweep.json"
BENCH_JSON = os.path.join(ART, "BENCH_scenarios.json")
BENCH_SWEEP_JSON = os.path.join(ART, "BENCH_sweep.json")
BENCH_BATCHED_JSON = os.path.join(ART, "BENCH_batched.json")

WORKLOADS = (
    ("cg", {"n": 4096, "iters": 12}),
    ("mm", {"n": 128, "k": 32}),
    ("xsbench", {"lookups": 1500, "grid_points": 2000,
                 "flush_every_frac": 0.01}),
)
STRATEGIES = ("none", "adcc", "undo_log", "checkpoint_hdd",
              "checkpoint_nvm", "checkpoint_nvm_dram")
PLANS = DEFAULT_SWEEP_PLANS

SMOKE_WORKLOADS = (
    ("cg", {"n": 1024, "iters": 8}),
    ("mm", {"n": 64, "k": 16}),
    ("xsbench", {"lookups": 400, "grid_points": 800,
                 "flush_every_frac": 0.02}),
)
SMOKE_STRATEGIES = ("none", "adcc", "checkpoint_nvm")
SMOKE_PLANS = (CrashPlan.no_crash(), CrashPlan.at_fraction(0.5))


# -- fork-vs-rerun engine comparison (BENCH_sweep.json) ----------------------
#
# The dense matrix exercises the fork engine's reason to exist: ONE
# crash point per step (exhaustive fig 3/7-style recompute curves), so
# the rerun baseline pays O(setup + prefix + tail) per cell while fork
# pays O(restore + tail) off a single shared forward pass, and
# mode="measure" pays only O(restore + recover). The step axes are long
# enough that per-cell tails dominate (that is the measure-vs-fork
# differential: average tail = half the run), and XSBench keeps its
# characteristic shape — large read-only cross-section tables (captured
# once by copy-on-write snapshots, skipped by crash()/restore since
# they are never dirty) in front of a long lookup loop.
TIMING_WORKLOADS = (
    ("cg", {"n": 4096, "iters": 32}),
    ("mm", {"n": 48, "k": 4}),
    ("xsbench", {"lookups": 120, "grid_points": 10_000, "n_nuclides": 40,
                 "n_materials": 12, "max_nuclides_per_material": 8,
                 "flush_every_frac": 0.05, "seed": 7}),
)
SMOKE_TIMING_WORKLOADS = (
    ("cg", {"n": 1024, "iters": 24}),
    ("mm", {"n": 48, "k": 4}),
    ("xsbench", {"lookups": 100, "grid_points": 1500, "n_nuclides": 8,
                 "n_materials": 6, "max_nuclides_per_material": 4,
                 "flush_every_frac": 0.1, "seed": 7}),
)
TIMING_STRATEGIES = ("adcc", "undo_log", "checkpoint_nvm")
TIMING_PLANS = (CrashPlan.no_crash(), CrashPlan.at_every_step())

# KV serving matrix for the throughput trend metric: a dense torn
# at_every_step plan over the write-heavy profile, under the strategies
# whose restore/recover/audit paths the fig_kv gates lean on. Sized so
# the measure sweep takes ~seconds, not minutes.
KV_TIMING_WORKLOAD = ("kv", {"profile": "udb", "n_steps": 24, "seed": 11})
SMOKE_KV_TIMING_WORKLOAD = ("kv", {"profile": "udb", "n_steps": 12,
                                   "seed": 11})
KV_TIMING_STRATEGIES = ("none", "adcc", "shadow_snapshot")

# fault-injection matrix for the resilience-throughput trend metric: a
# dense at_every_step plan per fault axis (one nested re-crash, one
# poisoned-line) over the two wholesale mechanisms whose recovery the
# fig_faults gates pin as idempotent. Every fault cell pays the full
# harness price — golden pass + restore + inject + retried recovery —
# so this is the metric that notices when that harness gets slower.
FAULT_TIMING_STRATEGIES = ("undo_log", "checkpoint_nvm")
FAULT_TIMING_PLANS = (
    CrashPlan.at_every_step(fault=FaultSpec(nested_after=2,
                                            nested_fraction=0.5, seed=13)),
    CrashPlan.at_every_step(fault=FaultSpec(poison_words=2, seed=14)),
)

# single-pair dense matrix for the point-sharding leg: ONE (workload,
# strategy) pair, so workers>1 can only help by splitting the pair's
# own crash points. Sized so per-cell restore + recover dominates the
# per-shard golden-prefix replay and the process spawn — the regime
# point-sharding exists for (the smoke size keeps CI fast; spawn
# overhead dominates there, so only cell identity is gated at smoke).
POINTSHARD_WORKLOAD = ("cg", {"n": 16384, "iters": 32, "seed": 9})
SMOKE_POINTSHARD_WORKLOAD = ("cg", {"n": 1024, "iters": 24, "seed": 9})
POINTSHARD_WORKERS = 4


def _usable_cpus() -> int:
    """CPUs this process may actually run on — the quantity that decides
    whether the point-shard wall-clock floor is physically meaningful
    (containers routinely expose 1 core to a many-core host)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:   # non-Linux
        return os.cpu_count() or 1


def default_workers() -> int:
    """Worker count for parallel sweeps: REPRO_SWEEP_WORKERS, default 2
    (the pair-sharding gate needs >1; benchmarks stay laptop-friendly)."""
    return max(1, int(os.environ.get("REPRO_SWEEP_WORKERS", "2")))


def resolve_sweep_env(smoke: bool = None, workers: int = None):
    """The shared smoke/workers fallback every sweep-driven suite uses:
    explicit argument > REPRO_SCENARIOS_SMOKE / REPRO_SWEEP_WORKERS env
    (exported by ``benchmarks.run --smoke/--workers``) > defaults
    (full matrix, :func:`default_workers`)."""
    if smoke is None:
        smoke = bool(int(os.environ.get("REPRO_SCENARIOS_SMOKE", "0")))
    if workers is None:
        workers = default_workers()
    return smoke, workers


def _cell_key(c) -> Dict:
    key = {"workload": c.workload, "strategy": c.strategy,
           "plan": c.plan, "crash_step": c.crash_step}
    if c.torn_survival is not None:
        # multi-sample TornSpec plans emit several cells per
        # (plan, crash_step); the survival spec disambiguates
        key["torn_survival"] = c.torn_survival
    return key


def full_divergences(cells_a, cells_b) -> List[Dict]:
    """Cell-for-cell deterministic-payload mismatches between two sweeps
    that must be identical (fork vs rerun, workers>1 vs workers=1)."""
    out = []
    for a, b in zip(cells_a, cells_b):
        da, db = deterministic_cell_dict(a), deterministic_cell_dict(b)
        if da != db:
            out.append({**_cell_key(a),
                        "fields": sorted(k for k in set(da) | set(db)
                                         if da.get(k) != db.get(k))})
    if len(cells_a) != len(cells_b):
        out.append({"reason": "cell count mismatch",
                    "a": len(cells_a), "b": len(cells_b)})
    return out


def measure_divergences(measure_cells, full_cells) -> List[Dict]:
    """Measure-mode contract violations: any field a measure cell emits
    that is missing from — or unequal to — the full-execution cell."""
    out = []
    for m, f in zip(measure_cells, full_cells):
        fields = measure_divergence_fields(m, f)
        if fields:
            out.append({**_cell_key(m), "fields": fields})
    if len(measure_cells) != len(full_cells):
        out.append({"reason": "cell count mismatch",
                    "measure": len(measure_cells), "full": len(full_cells)})
    return out


def run_dense_cross_checks(kw: Dict, cells, workers: int):
    """The gate core every dense measure-mode matrix shares (fig3/fig7
    via :func:`check_dense_gates`, fig_torn via its coherence gates):
    re-sweep with the OTHER worker count so the sharding comparison is
    never vacuous and assert cell-for-cell equality, then run the
    full-execution fork sweep and assert every measure-cell field
    matches it. Returns the full-execution cells for the caller's own
    correctness/coherence gates."""
    other = 1 if workers > 1 else 2
    alt = sweep(mode="measure", workers=other, **kw)
    div = full_divergences(cells, alt)
    if div:
        raise AssertionError(
            f"workers={workers} dense sweep diverged from "
            f"workers={other}: {div[:3]}")
    serial = cells if workers == 1 else alt
    full = sweep(mode="full", engine="fork", **kw)
    mdiv = measure_divergences(serial, full)
    if mdiv:
        raise AssertionError(
            f"measure-mode cells diverged from full execution: {mdiv[:3]}")
    return full


def check_dense_gates(kw: Dict, cells, workers: int,
                      strict_correct: bool = True,
                      expected_incorrect: int = None,
                      tolerance_class=None,
                      expected_tolerated: int = None):
    """The gates a dense measure-mode figure matrix (fig3/fig7) runs
    under at EVERY size: the sharded sweep must equal the serial one
    cell-for-cell, and every field a measure cell emits must match the
    full-execution fork engine. The full-execution sweep inside is also
    where crashed cells' end-of-run correctness gets checked (measure
    cells carry correct=None by design): with ``strict_correct`` any
    incorrect cell raises (the CI smoke gate); without it the incorrect
    cell keys are returned for the caller to report — ADCC CG's
    invariant-scan restart is APPROXIMATELY consistent (the paper's
    iterative-method tolerance argument), so at full sizes a handful of
    (size, crash-step) cells finalize ~1e-5 off the 1e-7 criterion, a
    property of the seed algorithm, not a sweep-engine defect.

    Deliberate cost tradeoff: the gate re-runs the matrix twice more
    (an alternate-workers measure sweep + the full-execution fork
    sweep), so a gated figure run costs ~3x its bare measure sweep.
    That is still far below the old per-cell rerun cost, and it is
    what catches recovery regressions the measure cells (correct=None)
    cannot — CI pays it at smoke sizes only; full runs pay seconds.

    ``tolerance_class`` is a documented reclassification predicate for
    the approximate-restart population: an off-criterion full cell the
    predicate accepts (e.g. its relative residual is within the ADCC
    invariant-scan tolerance that *admitted* the restart candidate) is
    counted as *tolerated*, not incorrect — the iterative-method
    tolerance argument, made explicit per cell instead of absorbed into
    a nonzero incorrect count. ``expected_tolerated`` pins that
    population exactly, and ``expected_incorrect`` pins the *exact*
    number of cells off the criterion AND outside the tolerance class a
    non-strict run may produce — both pins exist so neither population
    can silently grow (or shrink) under later changes (the fig3
    ``incorrect_full_cells`` / ``approx_consistent_full_cells`` gates).
    Returns ``(incorrect_keys, tolerated_keys)``."""
    full = run_dense_cross_checks(kw, cells, workers)
    off = [c for c in full if not c.correct]
    tol = [c for c in off if tolerance_class is not None
           and tolerance_class(c)]
    bad = [_cell_key(c) for c in off if c not in tol]
    tol_keys = [_cell_key(c) for c in tol]
    if (bad or tol_keys) and strict_correct:
        raise AssertionError(
            f"full-execution cells finalized INCORRECT: "
            f"{(bad + tol_keys)[:5]}")
    if expected_incorrect is not None and len(bad) != expected_incorrect:
        raise AssertionError(
            f"incorrect full-execution cell count changed: got {len(bad)}, "
            f"pinned {expected_incorrect} — the approximate-restart "
            f"population moved; inspect before re-pinning: {bad[:5]}")
    if expected_tolerated is not None and len(tol_keys) != expected_tolerated:
        raise AssertionError(
            f"tolerated (approx-consistent) full-execution cell count "
            f"changed: got {len(tol_keys)}, pinned {expected_tolerated} — "
            f"inspect before re-pinning: {tol_keys[:5]}")
    return bad, tol_keys


def engine_timing(smoke: bool = None, workers: int = None) -> Dict:
    """Time the dense matrix under rerun, fork, and fork+measure
    execution, plus a ``workers``-way sharded measure run, and
    cross-check every cell. Returns the BENCH_sweep.json payload
    (divergence lists included — callers decide whether to fail)."""
    smoke, workers = resolve_sweep_env(smoke, workers)
    # the sharding gate must never be vacuous: a requested workers=1
    # would compare the serial sweep against itself, so shard with >=2
    workers = max(2, workers)
    workloads = SMOKE_TIMING_WORKLOADS if smoke else TIMING_WORKLOADS
    cfg = NVMConfig(cache_bytes=1 * 1024 * 1024)
    kw = dict(workloads=workloads, strategies=TIMING_STRATEGIES,
              plans=TIMING_PLANS, cfg=cfg)
    runs = (("rerun", dict(engine="rerun")),
            ("fork", dict(engine="fork")),
            ("measure", dict(engine="fork", mode="measure")),
            ("parallel", dict(engine="fork", mode="measure",
                              workers=workers)))
    seconds = {}
    cells = {}
    for name, run_kw in runs:
        t0 = time.perf_counter()
        cells[name] = sweep(**kw, **run_kw)
        seconds[name] = time.perf_counter() - t0

    # -- batched mode, timed on the fig_torn dense torn matrix ------------
    # mode="batched" exists for exactly the matrix shape fig_torn sweeps
    # (crash step x survival fraction x seed sample), so that is the
    # matrix its headline speedup is recorded on. The first batched run
    # is untimed: it is the equivalence-gate sweep AND the jit warm-up,
    # so the one-time XLA compilation is not billed to the steady-state
    # batched_seconds (measure mode has no compilation to warm; its
    # timing is unaffected by run order).
    from .fig_torn import _sweep_kw as torn_sweep_kw
    tkw = torn_sweep_kw(smoke)
    batched_warm = sweep(engine="fork", mode="batched", **tkw)
    t0 = time.perf_counter()
    torn_measure = sweep(mode="measure", **tkw)
    torn_measure_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    torn_batched = sweep(engine="fork", mode="batched", **tkw)
    torn_batched_s = time.perf_counter() - t0
    batched_div = full_divergences(torn_batched, torn_measure)
    # the warm-up run also pins batched determinism: two batched sweeps
    # of the same matrix must agree cell-for-cell
    batched_div += full_divergences(torn_batched, batched_warm)

    # -- KV serving matrix, timed in measure mode -------------------------
    # The regression the speedup ratios above cannot see: a slip in the
    # KV restore/recover/audit path (the per-crash-cell cost the serving
    # figure pays thousands of times) changes no cell payload, so every
    # divergence gate stays green while fig_kv quietly gets slower.
    # Record the measure-mode cell throughput on a dense torn KV matrix
    # as its own trend metric, and cross-check the cells against full
    # execution so the timed sweep is never an unverified one.
    kv_wl = SMOKE_KV_TIMING_WORKLOAD if smoke else KV_TIMING_WORKLOAD
    kv_kw = dict(workloads=(kv_wl,), strategies=KV_TIMING_STRATEGIES,
                 plans=(CrashPlan.no_crash(),
                        CrashPlan.at_every_step(
                            torn=TornSpec(fraction=0.5, seed=9,
                                          samples=2))),
                 cfg=cfg)
    t0 = time.perf_counter()
    kv_cells = sweep(mode="measure", **kv_kw)
    kv_s = time.perf_counter() - t0
    kv_div = measure_divergences(kv_cells, sweep(engine="fork", **kv_kw))

    # -- KV serving matrix, re-timed in batched mode ----------------------
    # The same matrix through the analytic KV evaluators: restored-state
    # strategies reduce to oracle-map arithmetic and ADCC replays its
    # validation walk from the crash image via stacked checksum/value
    # launches, so no cell should take the per-cell measure fallback.
    # The warm run is the jit warm-up AND the determinism pin (same
    # convention as the torn batched leg above); the speedup it buys is
    # the tentpole number, so it is gated, not just recorded.
    kv_warm = sweep(engine="fork", mode="batched", **kv_kw)
    t0 = time.perf_counter()
    kv_batched = sweep(engine="fork", mode="batched", **kv_kw)
    kv_batched_s = time.perf_counter() - t0
    kv_bdiv = full_divergences(kv_batched, kv_cells)
    kv_bdiv += full_divergences(kv_batched, kv_warm)
    kv_fallbacks = sum(1 for c in kv_batched
                       if "batched_fallback" in c.info)

    # -- device backend, streaming-prefix trace ---------------------------
    # The regime the DeviceBackend exists for: long resident spans with
    # the cache covering the working set, so every op clears
    # MIN_DEVICE_ENTRIES and the whole forward pass stays on device (no
    # host round-trip per op). Eviction-pressure traces — where device
    # legitimately falls back to the vectorized host path — are covered
    # by emu_bench; this leg records the win on the streaming shape and
    # gates only correctness (byte-identical image + traffic stats),
    # because the wall-clock ratio depends on whether jax actually has
    # an accelerator under it.
    from .emu_bench import REGION, run_backend
    dp_elems = 262_144 if smoke else 2_000_000
    dp_passes = 4 if smoke else 6
    dp_cache = dp_elems * 8
    dp_trace = [(op, 0, dp_elems) for _ in range(dp_passes)
                for op in ("write", "read", "flush")]
    vec_emu, dp_vec_s = run_backend("vectorized", dp_elems, dp_cache,
                                    dp_trace, "lru")
    run_backend("device", dp_elems, dp_cache, dp_trace, "lru")  # jit warm
    dev_emu, dp_dev_s = run_backend("device", dp_elems, dp_cache,
                                    dp_trace, "lru")
    dp_images_equal = bool(np.array_equal(vec_emu.store.image[REGION],
                                          dev_emu.store.image[REGION]))
    dp_stats_equal = (dataclasses.asdict(vec_emu.stats)
                      == dataclasses.asdict(dev_emu.stats))

    # -- fault-injection matrix, timed in measure mode --------------------
    # Fault cells bypass every fast path (batched evaluation, shared
    # golden state): each pays snapshot + golden recovery + restore +
    # fault injection + retried recovery. None of the ratios above time
    # that harness, so record its cell throughput as its own trend
    # metric — and cross-check against full execution so the timed
    # sweep is gated like every other one.
    fkw = dict(workloads=(workloads[0], workloads[2]),
               strategies=FAULT_TIMING_STRATEGIES,
               plans=FAULT_TIMING_PLANS, cfg=cfg)
    t0 = time.perf_counter()
    fault_cells = sweep(mode="measure", **fkw)
    fault_s = time.perf_counter() - t0
    fault_div = measure_divergences(fault_cells,
                                    sweep(engine="fork", **fkw))

    # -- point-sharding, timed on a single-pair dense matrix --------------
    # workers>1 used to serialize any sweep with a single (workload,
    # strategy) pair; point-sharding splits that pair's grounded crash
    # points across the workers instead. Pin the sharded cells to the
    # serial ones and record the wall-clock ratio as its own trend
    # metric. Point shards are CPU-bound, so the >=2x floor (run_timing)
    # binds only full-size on a host with >= POINTSHARD_WORKERS usable
    # CPUs — on an underprovisioned runner the shards timeshare one
    # core and the recorded ratio documents the overhead instead of
    # gating on parallelism the host cannot deliver.
    ps_wl = SMOKE_POINTSHARD_WORKLOAD if smoke else POINTSHARD_WORKLOAD
    ps_kw = dict(workloads=(ps_wl,), strategies=("adcc",),
                 plans=TIMING_PLANS, cfg=cfg)
    t0 = time.perf_counter()
    ps_serial = sweep(mode="measure", workers=1, **ps_kw)
    ps_serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    ps_sharded = sweep(mode="measure", workers=POINTSHARD_WORKERS, **ps_kw)
    ps_sharded_s = time.perf_counter() - t0
    ps_div = full_divergences(ps_sharded, ps_serial)

    # -- snapshot tiering, forced-eviction leg ----------------------------
    # A 1-byte budget evicts every non-pinned ladder snapshot, so the
    # spill sweep pays serialize + reload on every cell and the
    # recompute sweep replays the golden prefix from the pre-step-0
    # pin. Both must still merge to the unbudgeted cells exactly, and
    # their tier counters prove the eviction paths actually ran (a
    # budget so generous nothing spills would gate nothing).
    tier_stats = {}
    tier_div = []
    for policy in ("spill", "recompute"):
        tc = sweep(mode="measure", workers=1, snapshot_budget_bytes=1,
                   snapshot_policy=policy, **ps_kw)
        tier_div += full_divergences(tc, ps_serial)
        tier_stats[policy] = tc[0].info["snapshot_tier"]

    return {
        "schema": "repro.scenarios.sweep_timing/v5",
        "smoke": bool(smoke),
        "matrix": {
            "workloads": [[w, p] for w, p in workloads],
            "strategies": list(TIMING_STRATEGIES),
            "plans": [p.describe() for p in TIMING_PLANS],
        },
        "cells": len(cells["fork"]),
        "rerun_seconds": seconds["rerun"],
        "fork_seconds": seconds["fork"],
        "measure_seconds": seconds["measure"],
        "speedup": seconds["rerun"] / max(seconds["fork"], 1e-12),
        "measure_speedup": seconds["fork"] / max(seconds["measure"], 1e-12),
        "total_speedup": seconds["rerun"] / max(seconds["measure"], 1e-12),
        "batched_speedup": torn_measure_s / max(torn_batched_s, 1e-12),
        "kv_cells_per_second": len(kv_cells) / max(kv_s, 1e-12),
        "kv_batched_speedup": kv_s / max(kv_batched_s, 1e-12),
        "device_prefix_speedup": dp_vec_s / max(dp_dev_s, 1e-12),
        "fault_cells_per_second": len(fault_cells) / max(fault_s, 1e-12),
        "pointshard_speedup": ps_serial_s / max(ps_sharded_s, 1e-12),
        "pointshard": {
            "matrix": "single-pair cg dense (no_crash + at_every_step)",
            "workload": list(ps_wl),
            "workers": POINTSHARD_WORKERS,
            "usable_cpus": _usable_cpus(),
            "cells": len(ps_sharded),
            "serial_seconds": ps_serial_s,
            "sharded_seconds": ps_sharded_s,
            "divergences": ps_div,
        },
        "snapshot_spill": {
            "budget_bytes": 1,
            "policies": tier_stats,
            "divergences": tier_div,
        },
        "fault": {
            "matrix": "cg+xsbench dense (nested at_every_step + poison "
                      "at_every_step)",
            "strategies": list(FAULT_TIMING_STRATEGIES),
            "cells": len(fault_cells),
            "measure_seconds": fault_s,
            "divergences": fault_div,
        },
        "kv": {
            "matrix": "kv dense (no_crash + torn at_every_step x 2 "
                      "samples)",
            "workload": list(kv_wl),
            "strategies": list(KV_TIMING_STRATEGIES),
            "cells": len(kv_cells),
            "measure_seconds": kv_s,
            "batched_seconds": kv_batched_s,
            "divergences": kv_div,
            "batched_divergences": kv_bdiv,
            "batched_fallback_cells": kv_fallbacks,
        },
        "device_prefix": {
            "matrix": "streaming full-region write/read/flush passes, "
                      "cache covers the working set",
            "elements": dp_elems,
            "passes": dp_passes,
            "cache_bytes": dp_cache,
            "vectorized_seconds": dp_vec_s,
            "device_seconds": dp_dev_s,
            "images_equal": dp_images_equal,
            "stats_equal": dp_stats_equal,
        },
        "batched": {
            "matrix": "fig_torn dense (crash step x survival fraction "
                      "x seed sample)",
            "cells": len(torn_batched),
            "measure_seconds": torn_measure_s,
            "batched_seconds": torn_batched_s,
            "divergences": batched_div,
        },
        "divergences": full_divergences(cells["rerun"], cells["fork"]),
        "measure_divergences": measure_divergences(cells["measure"],
                                                   cells["fork"]),
        "workers": {
            "n": workers,
            "seconds": seconds["parallel"],
            "divergences": full_divergences(cells["parallel"],
                                            cells["measure"]),
        },
    }


def run_timing(smoke: bool = None, workers: int = None) -> List[Row]:
    """The ``sweep`` suite: write BENCH_sweep.json, emit summary rows,
    and FAIL on any fork/rerun, measure/fork, or parallel/serial
    divergence (the CI gates)."""
    payload = engine_timing(smoke, workers)
    n_div = len(payload["divergences"])
    n_mdiv = len(payload["measure_divergences"])
    n_wdiv = len(payload["workers"]["divergences"])
    n_bdiv = len(payload["batched"]["divergences"])
    n_kdiv = len(payload["kv"]["divergences"])
    n_kbdiv = len(payload["kv"]["batched_divergences"])
    n_kfall = payload["kv"]["batched_fallback_cells"]
    n_fdiv = len(payload["fault"]["divergences"])
    n_pdiv = len(payload["pointshard"]["divergences"])
    n_tdiv = len(payload["snapshot_spill"]["divergences"])
    spill = payload["snapshot_spill"]["policies"]["spill"]
    recomp = payload["snapshot_spill"]["policies"]["recompute"]
    rows = [
        Row("sweep/cells", payload["cells"],
            f"plans={'+'.join(payload['matrix']['plans'])}"),
        Row("sweep/rerun_seconds", payload["rerun_seconds"],
            "every cell re-runs from step 0"),
        Row("sweep/fork_seconds", payload["fork_seconds"],
            "one forward pass per pair + per-cell tails"),
        Row("sweep/measure_seconds", payload["measure_seconds"],
            "per-cell restore + recover only; no tail, no finalize"),
        Row("sweep/speedup", payload["speedup"],
            "fork over rerun"),
        Row("sweep/measure_speedup", payload["measure_speedup"],
            "measure mode over fork (dense matrix)"),
        Row("sweep/total_speedup", payload["total_speedup"],
            f"artifact={BENCH_SWEEP_JSON}"),
        Row("sweep/parallel_seconds", payload["workers"]["seconds"],
            f"measure mode, workers={payload['workers']['n']}"),
        Row("sweep/batched_seconds", payload["batched"]["batched_seconds"],
            f"fig_torn dense matrix, {payload['batched']['cells']} cells, "
            "jit-warm"),
        Row("sweep/batched_speedup", payload["batched_speedup"],
            "batched mode over measure mode (fig_torn dense matrix)"),
        Row("sweep/kv_cells_per_second", payload["kv_cells_per_second"],
            f"measure mode, {payload['kv']['cells']} cells "
            "(kv dense torn matrix)"),
        Row("sweep/kv_batched_speedup", payload["kv_batched_speedup"],
            "batched analytic KV evaluation over measure mode "
            "(same matrix, jit-warm; floor: 3x)"),
        Row("sweep/kv_batched_divergences", n_kbdiv,
            "kv batched vs measure cell mismatches (must be 0)"),
        Row("sweep/kv_batched_fallbacks", n_kfall,
            "kv batched cells that fell back to per-cell measure "
            "(must be 0)"),
        Row("sweep/device_prefix_speedup",
            payload["device_prefix_speedup"],
            f"device backend over vectorized on the streaming prefix "
            f"trace ({payload['device_prefix']['elements']} elements, "
            f"images_equal={payload['device_prefix']['images_equal']})"),
        Row("sweep/divergences", n_div,
            "fork vs rerun deterministic payload mismatches (must be 0)"),
        Row("sweep/measure_divergences", n_mdiv,
            "measure-mode fields unequal to fork cells (must be 0)"),
        Row("sweep/worker_divergences", n_wdiv,
            "workers>1 vs workers=1 cell mismatches (must be 0)"),
        Row("sweep/batched_divergences", n_bdiv,
            "batched vs measure cell mismatches on the torn matrix "
            "(must be 0)"),
        Row("sweep/kv_divergences", n_kdiv,
            "kv measure-mode fields unequal to fork cells (must be 0)"),
        Row("sweep/fault_cells_per_second",
            payload["fault_cells_per_second"],
            f"measure mode, {payload['fault']['cells']} cells "
            "(nested + poison at_every_step)"),
        Row("sweep/fault_divergences", n_fdiv,
            "fault measure-mode fields unequal to fork cells (must be 0)"),
        Row("sweep/pointshard_speedup", payload["pointshard_speedup"],
            f"single-pair dense, workers={payload['pointshard']['workers']} "
            f"vs serial (usable_cpus={payload['pointshard']['usable_cpus']})"),
        Row("sweep/pointshard_divergences", n_pdiv,
            "point-sharded vs serial cell mismatches (must be 0)"),
        Row("sweep/snapshot_spills", spill["spills"],
            f"forced by a 1-byte budget; reloads={spill['reloads']} "
            f"spilled_bytes={spill['spilled_bytes']}"),
        Row("sweep/snapshot_recomputes", recomp["recomputes"],
            "recompute-on-miss cells replayed from the tier-0 pin"),
        Row("sweep/snapshot_tier_divergences", n_tdiv,
            "budgeted vs unbudgeted cell mismatches (must be 0)"),
    ]
    write_json(BENCH_SWEEP_JSON, payload)
    write_json(BENCH_BATCHED_JSON, {
        "schema": "repro.scenarios.batched_timing/v1",
        "smoke": payload["smoke"],
        "batched_speedup": payload["batched_speedup"],
        **payload["batched"],
    })
    if n_div:
        raise AssertionError(
            f"fork and rerun sweep engines diverged on {n_div} cells: "
            f"{payload['divergences'][:3]} (see {BENCH_SWEEP_JSON})")
    if n_mdiv:
        raise AssertionError(
            f"measure-mode cells diverged from fork cells on {n_mdiv} "
            f"cells: {payload['measure_divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    if n_wdiv:
        raise AssertionError(
            f"workers={payload['workers']['n']} sweep diverged from the "
            f"serial sweep on {n_wdiv} cells: "
            f"{payload['workers']['divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    if n_bdiv:
        raise AssertionError(
            f"batched-mode cells diverged from measure-mode cells on "
            f"{n_bdiv} cells of the torn matrix: "
            f"{payload['batched']['divergences'][:3]} "
            f"(see {BENCH_BATCHED_JSON})")
    if n_kdiv:
        raise AssertionError(
            f"kv measure-mode cells diverged from fork cells on "
            f"{n_kdiv} cells: {payload['kv']['divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    if n_kbdiv:
        raise AssertionError(
            f"kv batched-mode cells diverged from measure-mode cells on "
            f"{n_kbdiv} cells: {payload['kv']['batched_divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    if n_kfall:
        raise AssertionError(
            f"{n_kfall} kv batched cells fell back to per-cell measure "
            f"evaluation — the analytic KV evaluators no longer cover "
            f"the timed matrix (see {BENCH_SWEEP_JSON})")
    if payload["kv_batched_speedup"] < 3.0:
        raise AssertionError(
            f"kv batched sweep achieved only "
            f"{payload['kv_batched_speedup']:.2f}x over measure mode "
            f"(floor: 3x, jit-warm; see {BENCH_SWEEP_JSON})")
    dp = payload["device_prefix"]
    if not (dp["images_equal"] and dp["stats_equal"]):
        raise AssertionError(
            f"device backend diverged from the vectorized host on the "
            f"streaming prefix trace (images_equal={dp['images_equal']} "
            f"stats_equal={dp['stats_equal']}; see {BENCH_SWEEP_JSON})")
    if n_fdiv:
        raise AssertionError(
            f"fault-injection measure-mode cells diverged from fork "
            f"cells on {n_fdiv} cells: {payload['fault']['divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    if n_pdiv:
        raise AssertionError(
            f"point-sharded sweep diverged from the serial sweep on "
            f"{n_pdiv} cells: {payload['pointshard']['divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    ps = payload["pointshard"]
    if (not payload["smoke"] and ps["usable_cpus"] >= ps["workers"]
            and payload["pointshard_speedup"] < 2.0):
        # the wall-clock floor: full-size, on a host that actually has
        # the cores, splitting one pair's crash points across workers
        # must at least halve the sweep — anything less means the
        # per-shard overheads (golden-prefix replay, spawn, merge) are
        # eating the parallelism
        raise AssertionError(
            f"point-sharded sweep achieved only "
            f"{payload['pointshard_speedup']:.2f}x over serial with "
            f"{ps['workers']} workers on {ps['usable_cpus']} usable "
            f"CPUs (floor: 2x; see {BENCH_SWEEP_JSON})")
    if n_tdiv:
        raise AssertionError(
            f"budgeted snapshot-tier sweep diverged from the unbudgeted "
            f"one on {n_tdiv} cells: "
            f"{payload['snapshot_spill']['divergences'][:3]} "
            f"(see {BENCH_SWEEP_JSON})")
    if not (spill["spills"] and spill["reloads"]):
        raise AssertionError(
            f"spill-policy tier sweep evicted nothing under a 1-byte "
            f"budget (spills={spill['spills']} reloads={spill['reloads']}) "
            f"— the eviction path went unexercised")
    if not recomp["recomputes"]:
        raise AssertionError(
            "recompute-policy tier sweep regenerated nothing under a "
            f"1-byte budget (recomputes={recomp['recomputes']}) — the "
            "recompute-on-miss path went unexercised")
    return rows


def run(smoke: bool = None, engine: str = "fork",
        mode: str = "full") -> List[Row]:
    if smoke is None:
        smoke = bool(int(os.environ.get("REPRO_SCENARIOS_SMOKE", "0")))
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    strategies = SMOKE_STRATEGIES if smoke else STRATEGIES
    plans = SMOKE_PLANS if smoke else PLANS
    cfg = NVMConfig(cache_bytes=1 * 1024 * 1024)
    # non-full modes get their own artifact so the canonical full-mode
    # BENCH_scenarios.json is never clobbered by a measure/batched leg
    out_json = (BENCH_JSON if mode == "full"
                else os.path.join(ART, f"BENCH_scenarios_{mode}.json"))
    cells = sweep(workloads=workloads, strategies=strategies, plans=plans,
                  cfg=cfg, out_json=out_json, engine=engine, mode=mode)
    rows = []
    n_correct = 0
    for c in cells:
        cell = f"scenarios/{c.workload}/{c.strategy}/{c.plan}"
        if c.correct is not None:   # measure/batched cells skip the tail
            n_correct += int(c.correct)
            rows.append(Row(f"{cell}/correct", float(c.correct),
                            f"crash_step={c.crash_step}"))
        rows.append(Row(f"{cell}/steps_lost", c.steps_lost,
                        f"restart={c.restart_point}"))
        derived = (f"modeled_total={c.modeled_total_seconds:.3e}s"
                   if c.modeled_total_seconds is not None
                   else f"mode={mode}")
        rows.append(Row(f"{cell}/overhead_seconds", c.overhead_seconds,
                        derived))
    rows.append(Row("scenarios/summary/cells", len(cells),
                    f"matrix={len(workloads)}x{len(strategies)}x{len(plans)}"))
    if mode == "full":
        rows.append(Row("scenarios/summary/correct_cells", n_correct,
                        f"artifact={BENCH_JSON}"))
    return rows


def main() -> None:
    emit(run(), save_as=ARTIFACT)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI matrix: 3 workloads x 3 strategies x 2 plans")
    ap.add_argument("--engine", default="fork", choices=["fork", "rerun"],
                    help="sweep execution engine (default: fork)")
    ap.add_argument("--mode", default="full",
                    choices=["full", "measure", "batched"],
                    help="cell evaluation mode (batched requires "
                         "--engine fork)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke or None, engine=args.engine, mode=args.mode),
         save_as=ARTIFACT)
